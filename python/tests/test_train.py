"""Calibration-trainer machinery tests (fast: tiny data, few steps)."""

import numpy as np
import jax

from compile import model as M
from compile.arch import autorac_best
from compile.train import (
    FEATURE_NAMES,
    fit_surrogate,
    genome_features,
    train_model,
)


def _tiny_data(n=600, seed=0):
    from compile.datagen import Generator

    gen = Generator("kdd")
    dense, ids, y = gen.block(0, n)
    return dense, ids, y


def test_training_reduces_loss():
    g = autorac_best("kdd")
    dense, ids, y = _tiny_data()

    def loss_fn(p, d, i, yy):
        return M.bce_loss(M.forward_from_ids(p, g, d, i), yy)

    params = M.init_params(g, jax.random.PRNGKey(0))
    _, losses = train_model(loss_fn, params, dense, ids, y, steps=30, batch=128, seed=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) + 1e-6
    assert all(np.isfinite(l) for l in losses)


def test_gradient_clipping_prevents_blowup():
    g = autorac_best("kdd")
    dense, ids, y = _tiny_data()

    def loss_fn(p, d, i, yy):
        return M.bce_loss(M.forward_from_ids(p, g, d, i), yy)

    params = M.init_params(g, jax.random.PRNGKey(1))
    _, losses = train_model(
        loss_fn, params, dense, ids, y, steps=20, batch=128, seed=1, lr=0.1
    )
    assert max(losses) < 5.0, f"loss spiked: {max(losses)}"


def test_genome_features_are_fixed_length_and_match_rust_names():
    f = genome_features(autorac_best("criteo"))
    assert len(f) == len(FEATURE_NAMES) == 11
    assert f[0] == 1.0
    assert all(np.isfinite(v) for v in f)


def test_fit_surrogate_recovers_planted_linear_model():
    rng = np.random.default_rng(0)
    runs = []
    true_w = rng.normal(size=11) * 0.01
    for i in range(60):
        feats = [1.0] + list(rng.uniform(0, 1, size=10))
        ll = float(np.dot(true_w, feats)) + 0.45
        runs.append({
            "dataset": "criteo",
            "features": feats,
            "logloss": ll,
        })
    fit = fit_surrogate(runs)
    assert fit["rmse"] < 0.01, fit["rmse"]
    assert len(fit["weights"]) == 11 + 1  # features + one dataset intercept
