"""ATNS container round-trip (writer here, rust reader in runtime/atns.rs)."""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import atns


def test_roundtrip_mixed_dtypes():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.bin")
        tensors = {
            "emb/0": np.arange(12, dtype=np.float32).reshape(3, 4),
            "ids": np.array([[1, 2], [3, 4]], dtype=np.int32),
            "big": np.arange(10, dtype=np.int64),
        }
        atns.write(path, tensors)
        out = atns.read(path)
        assert list(out.keys()) == list(tensors.keys())
        for k in tensors:
            np.testing.assert_array_equal(out[k], tensors[k])
            assert out[k].dtype == tensors[k].dtype


@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(1, 8), min_size=1, max_size=4),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_random_f32(shape, seed):
    arr = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        atns.write(path, {"t": arr})
        np.testing.assert_array_equal(atns.read(path)["t"], arr)


def test_unsupported_dtype_raises():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(TypeError):
            atns.write(os.path.join(d, "x.bin"), {"b": np.zeros(2, np.float64)})
