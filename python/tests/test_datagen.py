"""Synthetic dataset system tests (python side of the parity contract)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.datagen import PROFILES, Generator, TruthModel, dataset_key


def test_profiles_mirror_real_benchmarks():
    assert PROFILES["criteo"].n_dense == 13
    assert PROFILES["criteo"].n_sparse == 26
    assert PROFILES["avazu"].n_dense == 0
    assert PROFILES["avazu"].n_sparse == 22
    assert PROFILES["kdd"].n_sparse == 10


def test_records_are_deterministic_and_random_access():
    g1 = Generator("criteo")
    g2 = Generator("criteo")
    _ = g2.record(7)  # out-of-order access must not matter
    a = g1.record(12345)
    b = g2.record(12345)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    assert a[2] == b[2]


def test_ids_respect_cardinalities():
    gen = Generator("kdd")
    p = PROFILES["kdd"]
    _, ids, _ = gen.block(0, 300)
    for j in range(p.n_sparse):
        assert ids[:, j].max() < p.cards[j]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(1, 1 << 32), index=st.integers(0, 1 << 20))
def test_record_shapes_hold_for_any_seed(seed, index):
    gen = Generator("avazu", seed)
    dense, ids, y = gen.record(index)
    assert dense.shape == (0,)
    assert ids.shape == (22,)
    assert y in (0, 1)


def test_ctr_is_near_target():
    for name, p in PROFILES.items():
        gen = Generator(name)
        _, _, y = gen.block(0, 2500)
        ctr = float(y.mean())
        assert p.base_ctr * 0.5 < ctr < p.base_ctr * 2.2, f"{name}: {ctr}"


def test_interactions_carry_signal():
    """Pairwise truth terms must move the logit — otherwise Table 2's
    FM/DP-vs-plain ordering has nothing to measure."""
    p = PROFILES["criteo"]
    t = TruthModel(p)
    gen = Generator("criteo")
    rng = np.random.default_rng(0)
    deltas = []
    for i in range(40):
        dense, ids, _ = gen.record(i)
        base = t.logit(dense.astype(np.float64), ids, 0.0)
        alt = ids.copy()
        j, l = p.pairs()[0]
        alt[j] = (alt[j] + 1 + rng.integers(0, p.cards[j] - 1)) % p.cards[j]
        moved = t.logit(dense.astype(np.float64), alt, 0.0)
        deltas.append(abs(moved - base))
    assert np.mean(deltas) > 0.05, f"interaction signal too weak: {np.mean(deltas)}"


def test_dataset_key_distinguishes_datasets_and_seeds():
    assert dataset_key(1, "criteo") != dataset_key(1, "avazu")
    assert dataset_key(1, "criteo") != dataset_key(2, "criteo")
    assert dataset_key(1, "criteo") == dataset_key(1, "criteo")
