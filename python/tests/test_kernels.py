"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Integer crossbar paths must match EXACTLY; float engines (FM/DP) to
tolerance. hypothesis sweeps shapes and the full ReRAM design space of
Table 1 (crossbar 16/32/64 × DAC 1/2 × cell 1/2 × ADC 4/6/8 × W 4/8).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    PimConfig,
    dp_gram,
    dp_triu,
    fm_interaction,
    pim_linear,
    pim_mvm_int,
)
from compile.kernels.ref import (
    adc_transfer,
    dp_gram_ref,
    dp_triu_ref,
    fake_quant_ref,
    fm_ref,
    pim_linear_ref,
    pim_mvm_int_ref,
    quant_act_u8,
    quant_sym,
)

# Keep hypothesis example counts modest: each example compiles a Pallas
# interpreter invocation (~100 ms).
FAST = settings(max_examples=12, deadline=None)

cfg_strategy = st.builds(
    PimConfig,
    xbar=st.sampled_from([16, 32, 64]),
    dac_bits=st.sampled_from([1, 2]),
    cell_bits=st.sampled_from([1, 2]),
    adc_bits=st.sampled_from([4, 6, 8]),
    x_bits=st.just(8),
    w_bits=st.sampled_from([4, 8]),
)


@FAST
@given(
    cfg=cfg_strategy,
    b=st.integers(1, 5),
    k=st.integers(1, 96),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_pim_mvm_int_matches_ref_exactly(cfg, b, k, n, seed):
    rng = np.random.default_rng(seed)
    k_pad = -(-k // cfg.xbar) * cfg.xbar
    x_u = rng.integers(0, 1 << cfg.x_bits, size=(b, k_pad)).astype(np.int32)
    wmax = (1 << (cfg.w_bits - 1)) - 1
    wq = rng.integers(-wmax, wmax + 1, size=(k_pad, n)).astype(np.int32)
    wp, wn = np.maximum(wq, 0), np.maximum(-wq, 0)
    got = pim_mvm_int(jnp.array(x_u), jnp.array(wp), jnp.array(wn), cfg)
    want = pim_mvm_int_ref(jnp.array(x_u), jnp.array(wp), jnp.array(wn), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@FAST
@given(
    cfg=cfg_strategy,
    b=st.integers(1, 4),
    k=st.integers(2, 70),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_pim_linear_matches_ref(cfg, b, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    got = pim_linear(jnp.array(x), jnp.array(w), cfg)
    want = pim_linear_ref(jnp.array(x), jnp.array(w), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("xbar", [16, 32, 64])
@pytest.mark.parametrize("dac,cell", [(1, 1), (1, 2), (2, 1), (2, 2)])
def test_feasible_configs_are_lossless_vs_int_matmul(xbar, dac, cell):
    """The paper's ADC feasibility rule: feasible ⇒ bit-exact integer MVM."""
    cfg = PimConfig(xbar=xbar, dac_bits=dac, cell_bits=cell, adc_bits=8, w_bits=8)
    if not cfg.feasible():
        pytest.skip("infeasible combo (excluded by the paper's rule)")
    rng = np.random.default_rng(xbar * 10 + dac * 2 + cell)
    x_u = rng.integers(0, 256, size=(3, 2 * xbar)).astype(np.int32)
    wq = rng.integers(-127, 128, size=(2 * xbar, 8)).astype(np.int32)
    wp, wn = np.maximum(wq, 0), np.maximum(-wq, 0)
    got = np.asarray(pim_mvm_int(jnp.array(x_u), jnp.array(wp), jnp.array(wn), cfg))
    np.testing.assert_array_equal(got, x_u @ wq)


def test_infeasible_config_is_lossy():
    """Sanity check of the exclusion rule: step>1 ADC loses information."""
    cfg = PimConfig(xbar=64, dac_bits=2, cell_bits=2, adc_bits=8, w_bits=8)
    assert not cfg.feasible()
    rng = np.random.default_rng(0)
    x_u = rng.integers(0, 256, size=(4, 64)).astype(np.int32)
    wq = rng.integers(-127, 128, size=(64, 8)).astype(np.int32)
    wp, wn = np.maximum(wq, 0), np.maximum(-wq, 0)
    got = np.asarray(pim_mvm_int(jnp.array(x_u), jnp.array(wp), jnp.array(wn), cfg))
    assert np.any(got != x_u @ wq)


def test_pim_linear_close_to_fp_matmul_for_8bit():
    """8-bit feasible config ≈ fp32 matmul within quantization error."""
    cfg = PimConfig(xbar=64, dac_bits=1, cell_bits=2, adc_bits=8, w_bits=8)
    assert cfg.feasible()
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 128)).astype(np.float32)
    w = rng.normal(size=(128, 32)).astype(np.float32)
    got = np.asarray(pim_linear(jnp.array(x), jnp.array(w), cfg))
    ref = x @ w
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 0.02, f"relative error {rel}"


@FAST
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 12),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, n, d)).astype(np.float32)
    got = fm_interaction(jnp.array(x))
    want = fm_ref(jnp.array(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fm_counts_each_pair_once():
    """FM output equals the explicit Σ_{i<j} x_i ⊙ x_j."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(2, 5, 7)).astype(np.float32)
    explicit = np.zeros((2, 7), dtype=np.float64)
    for i in range(5):
        for j in range(i + 1, 5):
            explicit += x[:, i, :] * x[:, j, :]
    got = np.asarray(fm_interaction(jnp.array(x)))
    np.testing.assert_allclose(got, explicit, rtol=1e-4, atol=1e-4)


@FAST
@given(
    b=st.integers(1, 3),
    m=st.integers(2, 10),
    d=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dp_matches_ref(b, m, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, m, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(dp_gram(jnp.array(x))),
        np.asarray(dp_gram_ref(jnp.array(x))),
        rtol=1e-5,
        atol=1e-5,
    )
    got = np.asarray(dp_triu(jnp.array(x)))
    want = np.asarray(dp_triu_ref(jnp.array(x)))
    assert got.shape == (b, m * (m - 1) // 2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dp_triu_is_strict_upper_triangle_row_major():
    x = np.eye(3, dtype=np.float32)[None]  # [1, 3, 3]; rows orthonormal
    got = np.asarray(dp_triu(jnp.array(x)))
    np.testing.assert_allclose(got, np.zeros((1, 3)), atol=1e-6)
    x2 = np.ones((1, 3, 2), dtype=np.float32)
    got2 = np.asarray(dp_triu(jnp.array(x2)))
    np.testing.assert_allclose(got2, np.full((1, 3), 2.0), atol=1e-6)


# ---------------------------------------------------------------------------
# Quantization periphery unit tests
# ---------------------------------------------------------------------------

def test_quant_sym_range_and_roundtrip():
    w = jnp.array([[-1.0, 0.5, 1.0]])
    wq, s = quant_sym(w, 8)
    assert int(jnp.max(jnp.abs(wq))) <= 127
    np.testing.assert_allclose(np.asarray(wq) * float(s), np.asarray(w), atol=float(s))


def test_quant_act_offset_binary():
    x = jnp.array([[-2.0, 0.0, 2.0]])
    xu, s, off = quant_act_u8(x, 8)
    assert off == 128
    got = np.asarray(xu)
    assert got.min() >= 0 and got.max() <= 255
    assert got[0, 1] == 128  # zero maps to the offset


def test_adc_transfer_identity_when_step_is_one():
    cfg = PimConfig(xbar=16, dac_bits=1, cell_bits=1, adc_bits=8)
    v = jnp.arange(0, 17)
    np.testing.assert_array_equal(np.asarray(adc_transfer(v, cfg)), np.arange(0, 17))


def test_fake_quant_grid():
    w = jnp.array([0.0, 0.1, -1.0, 1.0])
    q4 = np.asarray(fake_quant_ref(w, 4))
    grid = 1.0 / 7
    np.testing.assert_allclose(q4 / grid, np.round(q4 / grid), atol=1e-6)
