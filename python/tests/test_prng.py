"""Cross-language PRNG contract tests.

The golden vectors here are the SAME values pinned in
``rust/src/util/rng.rs::tests::golden_xoshiro_stream`` — if either side
drifts, dataset parity between the build-time trainer and the run-time
coordinator is broken.
"""

import math

from compile.prng import Rng, Zipf, seed_from_name, splitmix64


def test_golden_xoshiro_stream_matches_rust():
    r = Rng(42)
    got = [r.next_u64() for _ in range(4)]
    assert got == [
        1546998764402558742,
        6990951692964543102,
        12544586762248559009,
        17057574109182124193,
    ]


def test_splitmix_step():
    st, v = splitmix64(0)
    assert st == 0x9E3779B97F4A7C15
    assert v < (1 << 64)


def test_f64_unit_interval():
    r = Rng(7)
    for _ in range(2000):
        x = r.f64()
        assert 0.0 <= x < 1.0


def test_below_range_and_rough_uniformity():
    r = Rng(123)
    counts = [0] * 10
    for _ in range(20000):
        counts[r.below(10)] += 1
    for c in counts:
        assert 1700 < c < 2300


def test_normal_moments():
    r = Rng(99)
    n = 20000
    xs = [r.normal() for _ in range(n)]
    mean = sum(xs) / n
    var = sum(x * x for x in xs) / n - mean * mean
    assert abs(mean) < 0.05
    assert abs(var - 1.0) < 0.1


def test_substream_stability_and_independence():
    root = Rng(5)
    a1 = root.substream("alpha")
    a2 = root.substream("alpha")
    b = root.substream("beta")
    va1 = [a1.next_u64() for _ in range(8)]
    va2 = [a2.next_u64() for _ in range(8)]
    vb = [b.next_u64() for _ in range(8)]
    assert va1 == va2
    assert va1 != vb


def test_seed_from_name_is_stable():
    assert seed_from_name(1, "x") == seed_from_name(1, "x")
    assert seed_from_name(1, "x") != seed_from_name(2, "x")
    assert seed_from_name(1, "x") != seed_from_name(1, "y")


def test_zipf_skew():
    z = Zipf(1000, 1.1)
    r = Rng(1)
    head = sum(1 for _ in range(10000) if z.sample(r) < 10)
    assert head / 10000 > 0.3


def test_zipf_matches_manual_cdf_inversion():
    z = Zipf(50, 1.0)
    r1 = Rng(77)
    r2 = Rng(77)
    for _ in range(500):
        u = r1.f64()
        k = z.sample(r2)
        # k is the first index with cdf[k] >= u
        assert z.cdf[k] >= u
        if k > 0:
            assert z.cdf[k - 1] < u
        assert not math.isnan(z.cdf[k])
