"""L2 model tests: shapes, both backends, genomes, baselines, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.arch import (
    Genome,
    autorac_best,
    design_space_size,
    nasrec_like,
    random_genome,
)
from compile.baselines import BASELINES
from compile.prng import Rng


def _inputs(g, batch=3, seed=0):
    from compile.datagen import PROFILES

    prof = PROFILES[g.dataset]
    rng = np.random.default_rng(seed)
    dense = rng.normal(size=(batch, max(prof.n_dense, 1))).astype(np.float32)
    ids = np.stack(
        [rng.integers(0, c, size=batch) for c in prof.cards], axis=1
    ).astype(np.int32)
    return jnp.array(dense), jnp.array(ids)


def test_reference_genomes_validate_and_roundtrip():
    for ds in ("criteo", "avazu", "kdd"):
        for maker in (autorac_best, nasrec_like):
            g = maker(ds)
            g.validate()
            g2 = Genome.from_json(g.to_json())
            assert g2.to_json() == g.to_json()


def test_forward_shapes_all_datasets():
    for ds in ("criteo", "avazu", "kdd"):
        g = autorac_best(ds)
        params = M.init_params(g, jax.random.PRNGKey(0))
        dense, ids = _inputs(g)
        logits = M.forward_from_ids(params, g, dense, ids)
        assert logits.shape == (3,)
        assert np.all(np.isfinite(np.asarray(logits)))


def test_train_and_pim_backends_agree_within_quantization():
    g = autorac_best("criteo")
    params = M.init_params(g, jax.random.PRNGKey(1))
    dense, ids = _inputs(g, batch=4, seed=1)
    sparse = M.embed(params, g, ids)
    mlp = {k: v for k, v in params.items() if not k.startswith("emb/")}
    lt = np.asarray(M.forward(mlp, g, dense, sparse, backend="train"))
    lp = np.asarray(M.forward(mlp, g, dense, sparse, backend="pim"))
    # 8/4-bit quantization noise at init scale stays small
    assert np.max(np.abs(lt - lp)) < 0.05, f"{lt} vs {lp}"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_genomes_forward(seed):
    rng = Rng(seed)
    g = random_genome(rng, "kdd", f"r{seed}")
    params = M.init_params(g, jax.random.PRNGKey(0))
    dense, ids = _inputs(g, batch=2, seed=seed % 100)
    logits = M.forward_from_ids(params, g, dense, ids)
    assert logits.shape == (2,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_gradients_flow_through_every_operator_kind():
    g = autorac_best("criteo")  # contains FC, DP, EFC, FM, DSI
    params = M.init_params(g, jax.random.PRNGKey(2))
    dense, ids = _inputs(g, batch=4, seed=2)
    y = jnp.array([1.0, 0.0, 1.0, 0.0])

    def loss(p):
        return M.bce_loss(M.forward_from_ids(p, g, dense, ids), y)

    grads = jax.grad(loss)(params)
    nonzero = sum(
        1 for v in grads.values() if float(jnp.max(jnp.abs(v))) > 0
    )
    assert nonzero > len(grads) * 0.7, f"only {nonzero}/{len(grads)} grads flow"


def test_baselines_forward_all_datasets():
    for name, (init, forward) in BASELINES.items():
        for ds in ("criteo", "avazu"):
            params = init(jax.random.PRNGKey(0), ds)
            g = autorac_best(ds)  # reuse input builder
            dense, ids = _inputs(g, batch=2)
            logits = forward(params, ds, dense, ids)
            assert logits.shape == (2,), f"{name}/{ds}"
            assert np.all(np.isfinite(np.asarray(logits))), f"{name}/{ds}"


def test_auc_and_logloss():
    assert abs(M.auc(np.array([0.1, 0.9]), np.array([0, 1])) - 1.0) < 1e-12
    assert abs(M.auc(np.array([0.5, 0.5]), np.array([0, 1])) - 0.5) < 1e-12
    ll = M.logloss(np.array([0.8, 0.2]), np.array([1, 0]))
    assert abs(ll + np.log(0.8)) < 1e-9


def test_design_space_is_astronomical():
    assert design_space_size() > 1e40


def test_infer_shapes_tracks_dsi_extension():
    g = autorac_best("criteo")
    sh = M.infer_shapes(g)
    # block 4 has DSI → +2 sparse features
    assert sh[4]["nout"] == g.blocks[4].sparse_features + 2
