"""Architecture genome — the shared python⇔rust schema (Table 1).

A genome describes one point of AutoRAC's joint design space:

* **model genome** — N choice blocks, each with a dense-branch operator
  (FC or DP), a sparse-branch operator (EFC or identity), an optional
  dense↔sparse interaction (DSI or FM), per-operator weight bit-widths,
  branch dimensions, and block-to-block connections;
* **PIM genome** — crossbar size, DAC resolution, memristor (cell)
  precision, ADC resolution.

The JSON form produced by :func:`to_json` is byte-compatible with the
rust side (``rust/src/nas/space.rs``); `rust/tests/genome_parity.rs`
pins a golden genome. Shape semantics (what the rust hardware mapper
assumes) are documented per field below and MUST match model.py.

Shape conventions:
  dense tensors  [B, dim]            (dim ∈ DENSE_DIMS)
  sparse tensors [B, N, d_emb]       (d_emb ∈ SPARSE_DIMS, global per arch)
  EFC projects N (feature count); d_emb never changes inside a genome.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

from .prng import Rng

# Table 1 option sets.
DENSE_DIMS = [16, 32, 64, 128, 256, 512, 768, 1024]
SPARSE_DIMS = [16, 32, 48, 64]
WEIGHT_BITS = [4, 8]
XBAR_SIZES = [16, 32, 64]
DAC_BITS = [1, 2]
CELL_BITS = [1, 2]
ADC_BITS = [4, 6, 8]
DENSE_OPS = ["fc", "dp"]
SPARSE_OPS = ["efc", "identity"]
INTERACTIONS = ["none", "dsi", "fm"]
SPARSE_FEATURES = [4, 8, 16, 32]  # EFC output feature counts
NUM_BLOCKS = 7  # fixed, as in the paper (§3.1)
DSI_FEATURES = 2  # rows a DSI merger appends to the sparse branch


@dataclass
class Block:
    dense_op: str = "fc"  # "fc" | "dp"
    dense_dim: int = 128
    dense_wbits: int = 8
    sparse_op: str = "efc"  # "efc" | "identity"
    sparse_features: int = 8
    sparse_wbits: int = 8
    interaction: str = "none"  # "none" | "dsi" | "fm"
    inter_wbits: int = 8
    dense_in: list = field(default_factory=lambda: [0])
    sparse_in: list = field(default_factory=lambda: [0])


@dataclass
class Pim:
    xbar: int = 64
    dac_bits: int = 1
    cell_bits: int = 2
    adc_bits: int = 8

    def feasible(self) -> bool:
        """Lossless-ADC rule (see kernels.ref.PimConfig.feasible)."""
        mx = self.xbar * ((1 << self.dac_bits) - 1) * ((1 << self.cell_bits) - 1)
        return mx <= (1 << self.adc_bits) - 1


@dataclass
class Genome:
    name: str
    dataset: str
    d_emb: int = 32
    blocks: list = field(default_factory=list)
    final_wbits: int = 8
    pim: Pim = field(default_factory=Pim)

    # ------------------------------------------------------------------
    def validate(self) -> None:
        assert self.d_emb in SPARSE_DIMS, f"d_emb {self.d_emb}"
        assert len(self.blocks) >= 1
        assert self.pim.feasible(), "PIM genome violates the ADC rule"
        for i, b in enumerate(self.blocks):
            assert b.dense_op in DENSE_OPS and b.sparse_op in SPARSE_OPS
            assert b.interaction in INTERACTIONS
            assert b.dense_dim in DENSE_DIMS
            assert b.sparse_features in SPARSE_FEATURES
            for w in (b.dense_wbits, b.sparse_wbits, b.inter_wbits):
                assert w in WEIGHT_BITS
            # connections must reference raw input (0) or earlier blocks
            assert b.dense_in and all(0 <= j <= i for j in b.dense_in)
            assert b.sparse_in and all(0 <= j <= i for j in b.sparse_in)
            # paper constraint: ≥1 dense and ≥1 sparse operator per block
            # (identity counts as "pass-through selected" only when the
            # branch is still fed; enforced by non-empty inputs above)

    # ------------------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "name": self.name,
            "dataset": self.dataset,
            "d_emb": self.d_emb,
            "blocks": [
                {
                    "dense_op": b.dense_op,
                    "dense_dim": b.dense_dim,
                    "dense_wbits": b.dense_wbits,
                    "sparse_op": b.sparse_op,
                    "sparse_features": b.sparse_features,
                    "sparse_wbits": b.sparse_wbits,
                    "interaction": b.interaction,
                    "inter_wbits": b.inter_wbits,
                    "dense_in": list(b.dense_in),
                    "sparse_in": list(b.sparse_in),
                }
                for b in self.blocks
            ],
            "final_wbits": self.final_wbits,
            "pim": {
                "xbar": self.pim.xbar,
                "dac_bits": self.pim.dac_bits,
                "cell_bits": self.pim.cell_bits,
                "adc_bits": self.pim.adc_bits,
            },
        }

    @staticmethod
    def from_json(j: dict) -> "Genome":
        g = Genome(
            name=j["name"],
            dataset=j["dataset"],
            d_emb=j["d_emb"],
            blocks=[Block(**b) for b in j["blocks"]],
            final_wbits=j["final_wbits"],
            pim=Pim(**j["pim"]),
        )
        g.validate()
        return g

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @staticmethod
    def load(path: str) -> "Genome":
        with open(path) as f:
            return Genome.from_json(json.load(f))

    # ------------------------------------------------------------------
    def dp_rows(self, dense_dim: int) -> int:
        """DP engine stack height: ⌈√(2·dim_d)⌉ EFC rows + 1 FC row (§3.2)."""
        return int(math.ceil(math.sqrt(2.0 * dense_dim))) + 1


def random_genome(rng: Rng, dataset: str, name: str = "random") -> Genome:
    """Uniform sample of the design space (used by random_search seeding
    and by the calibration trainer's surrogate-fitting runs)."""
    blocks = []
    for i in range(NUM_BLOCKS):
        blocks.append(
            Block(
                dense_op=str(rng.choice_list(DENSE_OPS)),
                dense_dim=int(rng.choice_list(DENSE_DIMS[:6])),  # cap 512 for CPU
                dense_wbits=int(rng.choice_list(WEIGHT_BITS)),
                sparse_op=str(rng.choice_list(SPARSE_OPS)),
                sparse_features=int(rng.choice_list(SPARSE_FEATURES)),
                sparse_wbits=int(rng.choice_list(WEIGHT_BITS)),
                interaction=str(rng.choice_list(INTERACTIONS)),
                inter_wbits=int(rng.choice_list(WEIGHT_BITS)),
                dense_in=sorted({rng.range(0, i) for _ in range(rng.range(1, 2))}),
                sparse_in=sorted({rng.range(0, i) for _ in range(rng.range(1, 2))}),
            )
        )
    # PIM genome: rejection-sample until the ADC rule passes.
    while True:
        pim = Pim(
            xbar=int(rng.choice_list(XBAR_SIZES)),
            dac_bits=int(rng.choice_list(DAC_BITS)),
            cell_bits=int(rng.choice_list(CELL_BITS)),
            adc_bits=int(rng.choice_list(ADC_BITS)),
        )
        if pim.feasible():
            break
    g = Genome(
        name=name,
        dataset=dataset,
        d_emb=int(rng.choice_list(SPARSE_DIMS)),
        blocks=blocks,
        pim=pim,
    )
    g.validate()
    return g


# Rng.choice works on lists already; alias for clarity with type checkers.
def _choice_list(self, xs):
    return xs[self.below(len(xs))]


Rng.choice_list = _choice_list  # type: ignore[attr-defined]


# ---------------------------------------------------------------------------
# Reference genomes
# ---------------------------------------------------------------------------

def nasrec_like(dataset: str) -> Genome:
    """A strong fixed choice-block architecture standing in for the
    NASRec-searched backbone (naively mapped in Table 3)."""
    blocks = [
        Block(dense_op="fc", dense_dim=256, dense_wbits=8,
              sparse_op="efc", sparse_features=16, sparse_wbits=8,
              interaction="fm", inter_wbits=8, dense_in=[0], sparse_in=[0]),
        Block(dense_op="dp", dense_dim=128, dense_wbits=8,
              sparse_op="efc", sparse_features=16, sparse_wbits=8,
              interaction="none", inter_wbits=8, dense_in=[1], sparse_in=[1]),
        Block(dense_op="fc", dense_dim=256, dense_wbits=8,
              sparse_op="efc", sparse_features=8, sparse_wbits=8,
              interaction="dsi", inter_wbits=8, dense_in=[2], sparse_in=[2]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=8,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="fm", inter_wbits=8, dense_in=[2, 3], sparse_in=[3]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=8,
              sparse_op="efc", sparse_features=8, sparse_wbits=8,
              interaction="none", inter_wbits=8, dense_in=[4], sparse_in=[4]),
        Block(dense_op="dp", dense_dim=64, dense_wbits=8,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="fm", inter_wbits=8, dense_in=[5], sparse_in=[5]),
        Block(dense_op="fc", dense_dim=64, dense_wbits=8,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="none", inter_wbits=8, dense_in=[5, 6], sparse_in=[6]),
    ]
    return Genome(name=f"nasrec-{dataset}", dataset=dataset, d_emb=32,
                  blocks=blocks, pim=Pim(xbar=64, dac_bits=1, cell_bits=1,
                                         adc_bits=8))


def autorac_best(dataset: str) -> Genome:
    """The searched AutoRAC winner (regenerate with `autorac search`;
    see EXPERIMENTS.md §F6). Mirrors the paper's Figure 6 trends:
    8-bit EFC everywhere, 4-bit mid-network FC, 8-bit first/last FC,
    mixed DP precision, and a hardware-friendly PIM config."""
    blocks = [
        Block(dense_op="fc", dense_dim=256, dense_wbits=8,
              sparse_op="efc", sparse_features=16, sparse_wbits=8,
              interaction="fm", inter_wbits=8, dense_in=[0], sparse_in=[0]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=4,
              sparse_op="efc", sparse_features=16, sparse_wbits=8,
              interaction="none", inter_wbits=8, dense_in=[1], sparse_in=[1]),
        Block(dense_op="dp", dense_dim=128, dense_wbits=4,
              sparse_op="efc", sparse_features=8, sparse_wbits=8,
              interaction="none", inter_wbits=4, dense_in=[1, 2], sparse_in=[2]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=4,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="fm", inter_wbits=4, dense_in=[3], sparse_in=[3]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=4,
              sparse_op="efc", sparse_features=8, sparse_wbits=8,
              interaction="dsi", inter_wbits=4, dense_in=[3, 4], sparse_in=[4]),
        Block(dense_op="dp", dense_dim=64, dense_wbits=8,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="fm", inter_wbits=8, dense_in=[5], sparse_in=[5]),
        Block(dense_op="fc", dense_dim=128, dense_wbits=8,
              sparse_op="identity", sparse_features=8, sparse_wbits=8,
              interaction="none", inter_wbits=8, dense_in=[5, 6], sparse_in=[6]),
    ]
    return Genome(name=f"autorac-{dataset}", dataset=dataset, d_emb=32,
                  blocks=blocks, pim=Pim(xbar=64, dac_bits=1, cell_bits=2,
                                         adc_bits=8))


def design_space_size() -> float:
    """|space| per Table 1 (the paper reports ≈2×10^54 for N=7)."""
    per_block_conn = 0.0
    # connections: any non-empty subset of {0..i} for each branch
    size = 1.0
    for i in range(NUM_BLOCKS):
        conn = (2 ** (i + 1) - 1) ** 2
        ops = (
            len(DENSE_OPS)
            * len(DENSE_DIMS)
            * len(WEIGHT_BITS)
            * len(SPARSE_OPS)
            * len(SPARSE_FEATURES)
            * len(WEIGHT_BITS)
            * len(INTERACTIONS)
            * len(WEIGHT_BITS)
        )
        size *= conn * ops
    size *= len(SPARSE_DIMS) * len(WEIGHT_BITS)  # d_emb, final FC
    feasible_pim = 0
    for x in XBAR_SIZES:
        for da in DAC_BITS:
            for ce in CELL_BITS:
                for ad in ADC_BITS:
                    if Pim(x, da, ce, ad).feasible():
                        feasible_pim += 1
    size *= feasible_pim
    return size
