"""Build-time calibration trainer (the paper's supernet/search-phase
training, adapted per DESIGN.md §1).

Runs ONCE from `make artifacts`, never at serving time. Produces, under
``artifacts/calibration/``:

* ``accuracy.json`` — Table 2: LogLoss/AUC for every baseline and the
  nasrec/autorac genomes on all three dataset profiles.
* ``fig2.json``    — Figure 2: Criteo test LogLoss vs weight bit-width.
* ``surrogate.json`` — ridge-fit coefficients mapping genome features →
  test LogLoss; consumed by the rust search (`nas/accuracy.rs`).
* ``runs.json``    — raw per-run records (the fit's training data).

and, under ``artifacts/params/``, trained parameter .npz snapshots that
``aot.py`` bakes into the inference HLO ("crossbar programming").

Env knobs: AUTORAC_CALIB_STEPS (default 1000), AUTORAC_SURR_GENOMES (6),
AUTORAC_SURR_STEPS (300), AUTORAC_CALIB_FAST=1 (CI preset).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines as bl
from . import model as M
from .arch import Genome, autorac_best, nasrec_like, random_genome
from .datagen import PROFILES, load_split
from .prng import Rng

FAST = os.environ.get("AUTORAC_CALIB_FAST") == "1"
STEPS = int(os.environ.get("AUTORAC_CALIB_STEPS", 60 if FAST else 600))
#: choice-block genomes are deeper than the flat baselines and converge
#: slower; the paper retrains subnets from scratch to convergence, so
#: genome runs get a doubled step budget.
GENOME_STEPS = int(os.environ.get("AUTORAC_GENOME_STEPS", 2 * STEPS))
SURR_GENOMES = int(os.environ.get("AUTORAC_SURR_GENOMES", 2 if FAST else 6))
SURR_STEPS = int(os.environ.get("AUTORAC_SURR_STEPS", 40 if FAST else 300))
BATCH = 256
LR = 0.02


# ---------------------------------------------------------------------------
# Adagrad training loop (shared by genomes and baselines)
# ---------------------------------------------------------------------------

def _adagrad_update(params, accum, grads, lr):
    new_p, new_a = {}, {}
    for k in params:
        g = grads[k]
        a = accum[k] + g * g
        new_p[k] = params[k] - lr * g / (jnp.sqrt(a) + 1e-8)
        new_a[k] = a
    return new_p, new_a


def train_model(loss_fn, params, dense, ids, y, steps, batch, seed, lr=LR):
    """Generic Adagrad trainer with global-norm gradient clipping.

    CTR practice: roughly single-pass training (the paper's protocol
    trains subnets briefly too); callers size `steps` to ~1–2 epochs.
    """
    accum = {k: jnp.full_like(v, 0.1) for k, v in params.items()}

    @jax.jit
    def step(params, accum, d, i, yy):
        loss, grads = jax.value_and_grad(loss_fn)(params, d, i, yy)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-12))
        grads = {k: g * clip for k, g in grads.items()}
        params, accum = _adagrad_update(params, accum, grads, lr)
        return params, accum, loss

    n = len(y)
    rng = np.random.default_rng(seed)
    losses = []
    idx = rng.permutation(n)
    pos = 0
    for s in range(steps):
        if pos + batch > n:
            idx = rng.permutation(n)
            pos = 0
        sel = idx[pos : pos + batch]
        pos += batch
        params, accum, loss = step(
            params, accum, jnp.array(dense[sel]), jnp.array(ids[sel]), jnp.array(y[sel])
        )
        losses.append(float(loss))
    return params, losses


def evaluate(forward, params, dense, ids, y, batch=2048):
    """Test-set LogLoss + AUC."""
    probs = []
    for i in range(0, len(y), batch):
        logits = forward(params, jnp.array(dense[i : i + batch]), jnp.array(ids[i : i + batch]))
        probs.append(np.asarray(jax.nn.sigmoid(logits)))
    probs = np.concatenate(probs)
    return M.logloss(probs, y), M.auc(probs, y)


# ---------------------------------------------------------------------------
# Run wrappers
# ---------------------------------------------------------------------------

def run_genome(g: Genome, data, steps=GENOME_STEPS, seed=0, wbits_override=None):
    """Train + eval one genome; returns (record dict, trained params).

    wbits_override drives the Figure-2 sweep: EVERY weight tensor —
    operator weights AND embedding tables — is fake-quantized to the
    given bit-width ("test LogLoss versus weight bit-width").
    """
    if wbits_override is not None:
        for b in g.blocks:
            b.dense_wbits = b.sparse_wbits = b.inter_wbits = wbits_override
        g.final_wbits = wbits_override if wbits_override in (4, 8) else 8
        if wbits_override not in (4, 8):
            # out-of-space sweep point (Figure 2): bypass validate()
            g.final_wbits = 8
        g.emb_bits = wbits_override  # python-side attr read by model.embed
    dense_tr, ids_tr, y_tr = data["train"]
    dense_te, ids_te, y_te = data["test"]

    def loss_fn(params, d, i, yy):
        logits = M.forward_from_ids(params, g, d, i, backend="train")
        return M.bce_loss(logits, yy)

    params = M.init_params(g, jax.random.PRNGKey(seed))
    t0 = time.time()
    params, losses = train_model(loss_fn, params, dense_tr, ids_tr, y_tr, steps, BATCH, seed)

    fw = jax.jit(lambda p, d, i: M.forward_from_ids(p, g, d, i, backend="train"))
    ll, auc_ = evaluate(fw, params, dense_te, ids_te, y_te)
    rec = {
        "kind": "genome",
        "name": g.name,
        "dataset": g.dataset,
        "genome": g.to_json(),
        "features": genome_features(g),
        "logloss": ll,
        "auc": auc_,
        "params": M.param_count(params),
        "steps": steps,
        "train_seconds": time.time() - t0,
        "final_train_loss": float(np.mean(losses[-20:])),
    }
    return rec, params


def run_baseline(name: str, dataset: str, data, steps=STEPS, seed=0):
    init, forward = bl.BASELINES[name]
    dense_tr, ids_tr, y_tr = data["train"]
    dense_te, ids_te, y_te = data["test"]

    def loss_fn(params, d, i, yy):
        return M.bce_loss(forward(params, dataset, d, i), yy)

    params = init(jax.random.PRNGKey(seed), dataset)
    t0 = time.time()
    params, _ = train_model(loss_fn, params, dense_tr, ids_tr, y_tr, steps, BATCH, seed)
    fw = jax.jit(lambda p, d, i: forward(p, dataset, d, i))
    ll, auc_ = evaluate(fw, params, dense_te, ids_te, y_te)
    return {
        "kind": "baseline",
        "name": name,
        "dataset": dataset,
        "logloss": ll,
        "auc": auc_,
        "steps": steps,
        "train_seconds": time.time() - t0,
    }


# ---------------------------------------------------------------------------
# Surrogate featurization (MUST mirror rust/src/nas/accuracy.rs)
# ---------------------------------------------------------------------------

def genome_features(g: Genome) -> list:
    """Fixed-order feature vector for the accuracy surrogate."""
    n = len(g.blocks)
    n_dp = sum(b.dense_op == "dp" for b in g.blocks)
    n_fm = sum(b.interaction == "fm" for b in g.blocks)
    n_dsi = sum(b.interaction == "dsi" for b in g.blocks)
    n_efc = sum(b.sparse_op == "efc" for b in g.blocks)
    fc4 = sum(b.dense_wbits == 4 for b in g.blocks) / n
    efc4 = sum(b.sparse_wbits == 4 for b in g.blocks) / n
    int4 = sum(b.inter_wbits == 4 for b in g.blocks) / n
    mean_dim = sum(b.dense_dim for b in g.blocks) / n
    shapes = M.infer_shapes(g)
    log_params = float(np.log10(1 + sum(s["din"] * s["dout"] for s in shapes)))
    return [
        1.0,
        log_params,
        n_dp / n,
        n_fm / n,
        n_dsi / n,
        n_efc / n,
        fc4,
        efc4,
        int4,
        g.d_emb / 64.0,
        mean_dim / 512.0,
    ]


FEATURE_NAMES = [
    "bias", "log10_params", "frac_dp", "frac_fm", "frac_dsi", "frac_efc",
    "frac_fc_4bit", "frac_efc_4bit", "frac_inter_4bit", "d_emb_64",
    "mean_dense_dim_512",
]


def fit_surrogate(runs: list) -> dict:
    """Ridge regression (shared slopes, per-dataset intercept shift)."""
    datasets = sorted({r["dataset"] for r in runs})
    rows, ys = [], []
    for r in runs:
        f = list(r["features"])
        for ds in datasets:  # one-hot dataset intercepts (replace bias)
            f.append(1.0 if r["dataset"] == ds else 0.0)
        rows.append(f)
        ys.append(r["logloss"])
    x = np.array(rows)
    y = np.array(ys)
    lam = 1e-2
    a = x.T @ x + lam * np.eye(x.shape[1])
    w = np.linalg.solve(a, x.T @ y)
    pred = x @ w
    # Trust region: the search must not extrapolate the linear fit
    # outside the cloud of measured runs (features AND predictions are
    # clipped to these boxes on the rust side — nas/accuracy.rs).
    n_feat = len(FEATURE_NAMES)
    return {
        "feature_names": FEATURE_NAMES + [f"ds_{d}" for d in datasets],
        "weights": w.tolist(),
        "datasets": datasets,
        "rmse": float(np.sqrt(np.mean((pred - y) ** 2))),
        "n_runs": len(runs),
        "feature_min": x[:, :n_feat].min(axis=0).tolist(),
        "feature_max": x[:, :n_feat].max(axis=0).tolist(),
        "logloss_min": {
            d: float(min(r["logloss"] for r in runs if r["dataset"] == d))
            for d in datasets
        },
        "logloss_max": {
            d: float(max(r["logloss"] for r in runs if r["dataset"] == d))
            for d in datasets
        },
    }


# ---------------------------------------------------------------------------
# Main calibration pass
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/calibration")
    ap.add_argument("--datasets", default="criteo,avazu,kdd")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    params_dir = os.path.join(args.out_dir, "..", "params")
    os.makedirs(params_dir, exist_ok=True)

    datasets = args.datasets.split(",")
    accuracy = {}
    genome_runs = []

    for ds in datasets:
        print(f"=== {ds}: loading splits ===", flush=True)
        data = {split: load_split(ds, split) for split in ("train", "test")}
        accuracy[ds] = {}

        for name in bl.BASELINES:
            rec = run_baseline(name, ds, data)
            accuracy[ds][name] = {"logloss": rec["logloss"], "auc": rec["auc"]}
            print(f"  {name:10s} logloss={rec['logloss']:.4f} auc={rec['auc']:.4f} "
                  f"({rec['train_seconds']:.0f}s)", flush=True)

        for maker in (nasrec_like, autorac_best):
            g = maker(ds)
            rec, params = run_genome(g, data)
            genome_runs.append(rec)
            key = "nasrec" if "nasrec" in g.name else "autorac"
            accuracy[ds][key] = {"logloss": rec["logloss"], "auc": rec["auc"]}
            print(f"  {key:10s} logloss={rec['logloss']:.4f} auc={rec['auc']:.4f}",
                  flush=True)
            np.savez(
                os.path.join(params_dir, f"{key}_{ds}.npz"),
                **{k: np.asarray(v) for k, v in params.items()},
            )

        # Random genomes → surrogate training data.
        rng = Rng(1234)
        for gi in range(SURR_GENOMES):
            g = random_genome(rng.substream(f"surr/{ds}/{gi}"), ds, f"rand{gi}-{ds}")
            rec, _ = run_genome(g, data, steps=SURR_STEPS, seed=gi + 1)
            genome_runs.append(rec)
            print(f"  rand{gi:02d}     logloss={rec['logloss']:.4f}", flush=True)

    # Figure 2: Criteo LogLoss vs weight bit-width.
    fig2 = {}
    if "criteo" in datasets:
        data = {split: load_split("criteo", split) for split in ("train", "test")}
        for bits in (32, 16, 8, 6, 4, 3, 2):
            g = autorac_best("criteo")
            g.name = f"fig2-b{bits}"
            rec, _ = run_genome(g, data, wbits_override=bits if bits < 32 else None)
            fig2[str(bits)] = rec["logloss"]
            print(f"  fig2 bits={bits:2d} logloss={rec['logloss']:.4f}", flush=True)

    surrogate = fit_surrogate(genome_runs)

    def dump(name, obj):
        with open(os.path.join(args.out_dir, name), "w") as f:
            json.dump(obj, f, indent=2)

    dump("accuracy.json", accuracy)
    dump("fig2.json", fig2)
    dump("surrogate.json", surrogate)
    dump("runs.json", genome_runs)
    print(f"calibration complete → {args.out_dir} "
          f"(surrogate rmse {surrogate['rmse']:.4f} over {surrogate['n_runs']} runs)")


if __name__ == "__main__":
    main()
