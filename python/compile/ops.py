"""L2 operator library: FC / EFC / DP / DSI / FM with mixed precision.

Each operator has two execution backends:

* ``train`` — pure jnp with straight-through-estimator fake
  quantization. Differentiable; used by the build-time calibration
  trainer (the paper's supernet/subnet training runs).
* ``pim`` — the Pallas crossbar kernels from :mod:`compile.kernels`,
  bit-exact with the hardware model. Not differentiable; used by
  ``aot.py`` to lower the inference artifacts the rust runtime serves.

Both backends share parameter shapes, so weights trained on the
``train`` path drop straight into the ``pim`` path (that is the
"program the searched weights into the crossbars" step).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels import PimConfig, dp_triu, fm_interaction, pim_linear
from .kernels.ref import fake_quant_ref, fm_ref, dp_triu_ref


# ---------------------------------------------------------------------------
# Fake quantization with straight-through gradients
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fake_quant(w, bits: int):
    return fake_quant_ref(w, bits)


def _fq_fwd(w, bits):
    return fake_quant_ref(w, bits), None


def _fq_bwd(_, g):
    return (g, None)  # straight-through: d(quant)/dw ≈ 1


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def quantized(w, bits: int, backend: str):
    """Weight view for the current backend. On the pim path the Pallas
    kernel quantizes internally, so weights pass through unchanged."""
    if backend == "train" and bits < 32:
        return fake_quant(w, bits)
    return w


# ---------------------------------------------------------------------------
# Operators. All take (params, inputs, wbits, backend, pim_cfg).
# ---------------------------------------------------------------------------

def linear(w, x, wbits: int, backend: str, cfg: PimConfig):
    """x: [B, K] @ w: [K, N] → [B, N] (no activation)."""
    if backend == "pim":
        return pim_linear(x, w, cfg_with_bits(cfg, wbits))
    return x @ quantized(w, wbits, backend)


def fc(w, x, wbits: int, backend: str, cfg: PimConfig):
    """FC layer: linear + ReLU (dense operator)."""
    return jax.nn.relu(linear(w, x, wbits, backend, cfg))


def efc(w, xs, wbits: int, backend: str, cfg: PimConfig):
    """Embedded FC (sparse operator): project the feature-count axis.

    xs: [B, N_in, d]; w: [N_in, N_out] → [B, N_out, d].
    Mapped on crossbars as a matmul with the d axis batched (the sparse
    output arrives naturally transposed — which the FM/DP engines exploit).
    """
    B, n_in, d = xs.shape
    n_out = w.shape[1]
    if backend == "pim":
        flat = jnp.transpose(xs, (0, 2, 1)).reshape(B * d, n_in)
        out = pim_linear(flat, w, cfg_with_bits(cfg, wbits))
        out = out.reshape(B, d, n_out).transpose(0, 2, 1)
    else:
        out = jnp.einsum("bnd,nm->bmd", xs, quantized(w, wbits, backend))
    return jax.nn.relu(out)


def dp(params, xd, xs, dense_dim: int, wbits: int, backend: str, cfg: PimConfig):
    """Dot-Product dense operator (paper §3.2, Fig. 4c).

    Four sub-components: FC dim_d→d; EFC N→⌈√(2·dim_d)⌉; pairwise
    inner products Triu(XXᵀ); FC to dense_dim.
    params: dict with keys w_in [Din, d], w_efc [N, k], w_out [npairs, dense_dim].
    """
    B, n, d = xs.shape
    a = linear(params["w_in"], xd, wbits, backend, cfg)  # [B, d]
    bmat = efc(params["w_efc"], xs, wbits, backend, cfg)  # [B, k, d]
    x = jnp.concatenate([a[:, None, :], bmat], axis=1)  # [B, k+1, d]
    if backend == "pim":
        t = dp_triu(x)
    else:
        t = dp_triu_ref(x)
    return fc(params["w_out"], t, wbits, backend, cfg)


def fm(w, xs, wbits: int, backend: str, cfg: PimConfig):
    """Sparse-to-dense FM merger: interaction engine + FC projection.

    xs: [B, N, d] → interaction [B, d] → FC → [B, out_dim].
    """
    if backend == "pim":
        v = fm_interaction(xs)
    else:
        v = fm_ref(xs)
    return fc(w, v, wbits, backend, cfg)


def dsi(w, xd, n_feat: int, d: int, wbits: int, backend: str, cfg: PimConfig):
    """Dense-to-Sparse merger: FC + reshape into `n_feat` sparse rows.

    xd: [B, dim] → [B, n_feat, d].
    """
    u = linear(w, xd, wbits, backend, cfg)  # [B, n_feat*d]
    return u.reshape(xd.shape[0], n_feat, d)


def cfg_with_bits(cfg: PimConfig, wbits: int) -> PimConfig:
    """PIM config specialized to one operator's searched weight bits."""
    if cfg.w_bits == wbits:
        return cfg
    return PimConfig(
        xbar=cfg.xbar,
        dac_bits=cfg.dac_bits,
        cell_bits=cfg.cell_bits,
        adc_bits=cfg.adc_bits,
        x_bits=cfg.x_bits,
        w_bits=wbits,
    )


# ---------------------------------------------------------------------------
# Sparse-tensor plumbing shared by the block graph
# ---------------------------------------------------------------------------

def concat_sparse(tensors, d: int):
    """Concatenate sparse tensors along the feature-count axis; embedding
    dims are equal by construction (d_emb is global per genome)."""
    for t in tensors:
        assert t.shape[-1] == d, f"sparse dim mismatch: {t.shape} vs d={d}"
    return jnp.concatenate(tensors, axis=1)


def dp_stack_rows(dense_dim: int) -> int:
    """⌈√(2·dim_d)⌉ — the EFC projection height inside a DP operator."""
    return int(math.ceil(math.sqrt(2.0 * dense_dim)))
