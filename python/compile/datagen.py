"""Synthetic CTR dataset system — python half.

The real Criteo / Avazu / KDD Cup 2012 dumps are not available in this
offline environment (DESIGN.md §1), so we model each benchmark with a
*procedural* dataset: every record is a pure function of
``(profile, seed, index)`` computed with the shared PRNG
(:mod:`compile.prng` ⇔ ``rust/src/util/rng.rs``). The rust coordinator
regenerates identical records at serving/eval time without any files
crossing the build boundary; ``rust/tests/data_parity.rs`` pins the
cross-language contract against golden records exported by
``compile.aot``.

Ground-truth click model (what makes Table 2 meaningful): a logistic
model over latent field embeddings with *pairwise interaction terms*, so
models that capture feature interactions (FM / DP / deep crossing) beat
models that cannot — the effect Table 2 measures.

    logit(i) = b + γ_d · Σ_t w_t x_t
                 + γ_f · Σ_j  u_j · e_j[c_ij]
                 + γ_p · Σ_{(j,l) ∈ S} e_j[c_ij] · e_l[c_il]
                 + σ · ε_i,         y_i ~ Bernoulli(σ(logit))

Field pair set S is the deterministic rule ``(31*j + l) % 7 == 0`` over
j < l — dense enough that interactions matter, sparse enough that
first-order models retain signal.

Draw order per record (MUST match rust/src/data/gen.rs):
  1. n_dense normals (dense features, stored as f32)
  2. one zipf sample per sparse field (feature ids)
  3. one normal (label noise ε)
  4. one f64 (label bernoulli draw)
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

import numpy as np

from .prng import Rng, Zipf, seed_from_name

LATENT_K = 8
DEFAULT_SEED = 20250630  # GLSVLSI'25 opening day


@dataclass(frozen=True)
class Profile:
    """Shape/statistics profile mirroring one public CTR benchmark."""

    name: str
    n_dense: int
    cards: tuple  # cardinality per sparse field
    zipf_alpha: float
    base_ctr: float
    gamma_dense: float
    gamma_field: float
    gamma_pair: float
    noise: float

    @property
    def n_sparse(self) -> int:
        return len(self.cards)

    def pairs(self) -> list:
        """Interacting field pairs — deterministic rule shared with rust."""
        return [
            (j, l)
            for j in range(self.n_sparse)
            for l in range(j + 1, self.n_sparse)
            if (31 * j + l) % 7 == 0
        ]


def _cards(rule: str, n: int) -> tuple:
    """Deterministic per-field cardinalities (log-spread, field-indexed)."""
    out = []
    for j in range(n):
        # spread roughly 150..2000, deterministic in j
        c = int(150 * (1.45 ** (j % 8)))
        out.append(min(c, 2000))
    return tuple(out)


#: The three benchmark stand-ins. Field counts mirror the real datasets
#: (Criteo: 13 dense + 26 categorical; Avazu: 22 categorical, no dense;
#: KDD Cup 2012 track 2: 3 numeric + 10 categorical).
PROFILES = {
    "criteo": Profile(
        name="criteo",
        n_dense=13,
        cards=_cards("criteo", 26),
        zipf_alpha=1.25,
        base_ctr=0.256,
        gamma_dense=0.3,
        gamma_field=0.45,
        gamma_pair=0.55,
        noise=0.6,
    ),
    "avazu": Profile(
        name="avazu",
        n_dense=0,
        cards=_cards("avazu", 22),
        zipf_alpha=1.30,
        base_ctr=0.17,
        gamma_dense=0.0,
        gamma_field=0.5,
        gamma_pair=0.55,
        noise=0.6,
    ),
    "kdd": Profile(
        name="kdd",
        n_dense=3,
        cards=_cards("kdd", 10),
        zipf_alpha=1.35,
        base_ctr=0.045,
        gamma_dense=0.25,
        gamma_field=0.5,
        gamma_pair=0.6,
        noise=0.5,
    ),
}


def dataset_key(seed: int, name: str) -> int:
    """Root key for one dataset = substream state of the global seed."""
    root = Rng(seed)
    ds = root.substream("data/" + name)
    return ds.s[0] ^ ds.s[2]


class TruthModel:
    """Latent ground-truth parameters (lazily materialized, cached)."""

    def __init__(self, profile: Profile, seed: int = DEFAULT_SEED):
        self.profile = profile
        self.key = dataset_key(seed, profile.name)
        p = profile
        # Dense weights.
        r = Rng(seed_from_name(self.key, "densew"))
        self.w_dense = np.array(
            [r.normal() for _ in range(p.n_dense)], dtype=np.float64
        )
        # Per-field readout vectors u_j.
        self.u = []
        for j in range(p.n_sparse):
            r = Rng(seed_from_name(self.key, f"fieldw/{j}"))
            self.u.append(
                np.array([r.normal() for _ in range(LATENT_K)], dtype=np.float64)
                / math.sqrt(LATENT_K)
            )
        # Truth embedding tables (random-access generation, then cached).
        self._emb_cache: dict = {}
        self.pair_list = p.pairs()
        # Bias calibrated so that E[sigmoid(logit)] ≈ base_ctr: with
        # logit = b + s·N(0,1), E[sigmoid] ≈ sigmoid(b / √(1 + πs²/8))
        # (probit approximation), so scale the target logit by that factor.
        # Variance terms use the *actual* generated truth parameters, so
        # the rust mirror (data/gen.rs) reproduces b bit-identically.
        var = p.noise * p.noise
        var += p.gamma_dense ** 2 * float(self.w_dense @ self.w_dense)
        for j in range(p.n_sparse):
            var += p.gamma_field ** 2 * float(self.u[j] @ self.u[j]) / LATENT_K
        var += p.gamma_pair ** 2 * len(self.pair_list) / LATENT_K
        self.bias = math.log(p.base_ctr / (1.0 - p.base_ctr)) * math.sqrt(
            1.0 + math.pi * var / 8.0
        )

    def emb(self, j: int, c: int) -> np.ndarray:
        key = (j, c)
        e = self._emb_cache.get(key)
        if e is None:
            r = Rng(seed_from_name(self.key, f"emb/{j}/{c}"))
            e = np.array(
                [r.normal() for _ in range(LATENT_K)], dtype=np.float64
            ) / math.sqrt(LATENT_K)
            self._emb_cache[key] = e
        return e

    def logit(self, dense: np.ndarray, sparse_ids: np.ndarray, eps: float) -> float:
        p = self.profile
        z = self.bias
        if p.n_dense:
            z += p.gamma_dense * float(self.w_dense @ dense)
        embs = [self.emb(j, int(sparse_ids[j])) for j in range(p.n_sparse)]
        for j in range(p.n_sparse):
            z += p.gamma_field * float(self.u[j] @ embs[j])
        for (j, l) in self.pair_list:
            z += p.gamma_pair * float(embs[j] @ embs[l])
        z += p.noise * eps
        return z


class Generator:
    """Procedural record generator — python mirror of rust data::gen."""

    def __init__(self, name: str, seed: int = DEFAULT_SEED):
        self.profile = PROFILES[name]
        self.seed = seed
        self.key = dataset_key(seed, name)
        self.truth = TruthModel(self.profile, seed)
        self.zipfs = [Zipf(c, self.profile.zipf_alpha) for c in self.profile.cards]

    def record(self, index: int):
        """Generate record `index`: (dense f32[n_dense], ids i64[n_sparse], y)."""
        p = self.profile
        r = Rng(seed_from_name(self.key, f"rec/{index}"))
        dense = np.array([r.normal() for _ in range(p.n_dense)], dtype=np.float32)
        ids = np.array(
            [self.zipfs[j].sample(r) for j in range(p.n_sparse)], dtype=np.int64
        )
        eps = r.normal()
        z = self.truth.logit(dense.astype(np.float64), ids, eps)
        y = 1 if r.f64() < 1.0 / (1.0 + math.exp(-z)) else 0
        return dense, ids, y

    def block(self, start: int, count: int):
        """Vectorized-ish block generation (dense[count,nd], ids, y)."""
        p = self.profile
        dense = np.zeros((count, max(p.n_dense, 1)), dtype=np.float32)
        ids = np.zeros((count, p.n_sparse), dtype=np.int64)
        ys = np.zeros((count,), dtype=np.float32)
        for i in range(count):
            d, s, y = self.record(start + i)
            if p.n_dense:
                dense[i, : p.n_dense] = d
            ids[i] = s
            ys[i] = y
        return dense[:, : max(p.n_dense, 1)], ids, ys


# ---------------------------------------------------------------------------
# Cached materialization: generating records in pure python is ~50 µs each;
# the calibration trainer touches each record many times, so we materialize
# once per (profile, seed, split) and cache under artifacts/data_cache/.
# ---------------------------------------------------------------------------

SPLIT_SIZES = {
    # 80/10/10 like the paper's protocol, scaled to CPU-feasible sizes.
    "train": int(os.environ.get("AUTORAC_TRAIN_N", 80_000)),
    "val": int(os.environ.get("AUTORAC_VAL_N", 10_000)),
    "test": int(os.environ.get("AUTORAC_TEST_N", 10_000)),
}

# Split layout over the index space (contiguous, in this order).
SPLIT_OFFSETS = {
    "train": 0,
    "val": SPLIT_SIZES["train"],
    "test": SPLIT_SIZES["train"] + SPLIT_SIZES["val"],
}


def load_split(name: str, split: str, seed: int = DEFAULT_SEED, cache_dir=None):
    """Materialize (dense, ids, y) for a split, with .npz caching."""
    n = SPLIT_SIZES[split]
    off = SPLIT_OFFSETS[split]
    if cache_dir is None:
        cache_dir = os.path.join(
            os.path.dirname(__file__), "..", "..", "artifacts", "data_cache"
        )
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, f"{name}_{seed}_{split}_{n}_v2.npz")
    if os.path.exists(path):
        z = np.load(path)
        return z["dense"], z["ids"], z["y"]
    gen = Generator(name, seed)
    dense, ids, y = gen.block(off, n)
    np.savez_compressed(path, dense=dense, ids=ids, y=y)
    return dense, ids, y


def batches(dense, ids, y, batch_size: int, seed: int, epochs: int = 1):
    """Shuffled minibatch iterator (numpy-side; not parity-critical)."""
    n = len(y)
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = perm[i : i + batch_size]
            yield dense[sel], ids[sel], y[sel]
