"""L1 Pallas kernels (build-time only; lowered into the model HLO).

Three kernels cover the paper's PIM engines (Fig. 4f):

* :mod:`crossbar_mvm` — the MVM engine (FC / EFC / DSI / DP sub-layers)
* :mod:`fm_kernel` — the FM engine (transposed array + MBSA)
* :mod:`dp_kernel` — the DP engine (Gram stage)

All run under ``interpret=True`` so the lowered HLO executes on the CPU
PJRT client the rust runtime uses. :mod:`ref` holds the pure-jnp oracles.
"""

from .crossbar_mvm import pim_linear, pim_mvm_int
from .dp_kernel import dp_gram, dp_triu
from .fm_kernel import fm_interaction
from .ref import PimConfig

__all__ = [
    "PimConfig",
    "pim_linear",
    "pim_mvm_int",
    "dp_gram",
    "dp_triu",
    "fm_interaction",
]
