"""L1 Pallas kernel: DP engine Gram stage (paper Fig. 4c).

The DP layer's inner-product core computes all pairwise dot products of
the stacked feature matrix X ∈ R^{m×d}: G = XXᵀ. In hardware, each EFC
output vector is *programmed* into the DP-engine crossbar while the next
one is produced (double-buffered, overlap-friendly — the EFC output is
already transposed so Xᵀ programs directly); each stored vector then
feeds the word lines to produce one row of G per read.

The kernel emits the full Gram matrix; strict-upper-triangle selection
(`Triu`, k=1) is output addressing in the digital periphery and lives in
the `dp_triu` wrapper, mirroring where the work happens on chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dp_kernel(x_ref, o_ref):
    """x_ref: f32 [1, m, d]; o_ref: f32 [1, m, m] = X Xᵀ."""
    x = x_ref[0]  # [m, d]
    o_ref[0] = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def dp_gram(x):
    """x: f32 [B, m, d] → f32 [B, m, m] via Pallas (interpret mode)."""
    B, m, d = x.shape
    return pl.pallas_call(
        _dp_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, m, d), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, m, m), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, m), jnp.float32),
        interpret=True,
    )(x)


def dp_triu(x):
    """x: f32 [B, m, d] → f32 [B, m(m-1)/2] (strict upper triangle)."""
    g = dp_gram(x)
    m = x.shape[-2]
    iu = np.triu_indices(m, k=1)
    return g[:, iu[0], iu[1]]
