"""L1 Pallas kernel: bit-serial ReRAM crossbar MVM (paper Fig. 3a / §3.2).

This is the compute hot-spot of every FC / EFC / DSI / DP sub-layer in
the AutoRAC model, expressed as the analog array actually computes it:

  * the weight matrix is bit-sliced into ``cell_bits`` planes across a
    positive and a negative array (signed weights ⇒ differential pair);
  * the activation vector is fed ``dac_bits`` bits per step
    (offset-binary unsigned, offset corrected digitally);
  * each row-tile of ``xbar`` word lines produces analog column sums
    that pass through the ADC transfer function (quantize + clip);
  * the digital periphery shift-adds the partial codes.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the grid dimension
over row-tiles is the HBM→VMEM schedule; one (xbar × N) weight tile and a
(B × xbar) activation tile live in VMEM per step, mirroring the paper's
wordline-register / crossbar residency. ``interpret=True`` everywhere —
real-TPU lowering would emit a Mosaic custom-call the CPU PJRT client
cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PimConfig, quant_act_u8, quant_sym


def _mvm_kernel(x_ref, wp_ref, wn_ref, o_ref, *, cfg: PimConfig):
    """One row-tile step: accumulate ADC-quantized bit-serial partials.

    Refs (per grid step t over K // cfg.xbar row tiles):
        x_ref:  int32 [B, xbar]   — activation slice for this tile
        wp_ref: int32 [xbar, N]   — positive weight slice
        wn_ref: int32 [xbar, N]   — negative weight slice
        o_ref:  int32 [B, N]      — running accumulator (whole output)
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    wp = wp_ref[...]
    wn = wn_ref[...]

    dac_mask = (1 << cfg.dac_bits) - 1
    cell_mask = (1 << cfg.cell_bits) - 1
    levels = (1 << cfg.adc_bits) - 1
    step = cfg.adc_step

    acc = jnp.zeros_like(o_ref)
    # Static unrolled loops — chunk/plane counts are compile-time consts,
    # exactly like the fixed cycle schedule of the analog array.
    for c in range(cfg.n_chunks):
        chunk = (x >> (c * cfg.dac_bits)) & dac_mask
        for p in range(cfg.n_planes):
            shift = c * cfg.dac_bits + p * cfg.cell_bits
            for wmat, sign in ((wp, 1), (wn, -1)):
                plane = (wmat >> (p * cfg.cell_bits)) & cell_mask
                # f32 dot, rounded back to int — bit-exact at crossbar
                # operand ranges and avoids the s32 dot_general miscompile
                # in the rust runtime's xla_extension 0.5.1 (see ref.py).
                partial = jax.lax.dot_general(
                    chunk.astype(jnp.float32),
                    plane.astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).astype(jnp.int32)
                # ADC transfer: mid-tread quantize + full-scale clip.
                code = jnp.clip((partial + step // 2) // step, 0, levels)
                acc = acc + sign * (code * step << shift)
    o_ref[...] += acc


def pim_mvm_int(x_u, w_pos, w_neg, cfg: PimConfig):
    """Integer crossbar MVM via Pallas. Shapes as in ref.pim_mvm_int_ref."""
    B, K = x_u.shape
    N = w_pos.shape[1]
    assert K % cfg.xbar == 0, "pad K to the crossbar size"
    n_tiles = K // cfg.xbar
    kernel = functools.partial(_mvm_kernel, cfg=cfg)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((B, cfg.xbar), lambda t: (0, t)),
            pl.BlockSpec((cfg.xbar, N), lambda t: (t, 0)),
            pl.BlockSpec((cfg.xbar, N), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((B, N), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=True,
    )(x_u, w_pos, w_neg)


def pim_linear(x, w, cfg: PimConfig):
    """Float-in/float-out PIM linear layer using the Pallas core.

    Same contract as ref.pim_linear_ref: quantize (digital) → bit-serial
    crossbar MVM (analog, Pallas) → offset-correct + dequantize (digital).
    """
    K = x.shape[-1]
    pad = (-K) % cfg.xbar
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    wq, w_scale = quant_sym(w, cfg.w_bits)
    w_pos = jnp.maximum(wq, 0)
    w_neg = jnp.maximum(-wq, 0)
    x_u, x_scale, offset = quant_act_u8(x, cfg.x_bits)
    acc = pim_mvm_int(x_u, w_pos, w_neg, cfg)
    ones = jnp.full((1, x_u.shape[1]), offset, dtype=jnp.int32)
    corr = pim_mvm_int(ones, w_pos, w_neg, cfg)
    return (acc - corr).astype(jnp.float32) * x_scale * w_scale
