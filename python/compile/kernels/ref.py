"""Pure-jnp oracles for the L1 Pallas kernels.

These are the *correctness contracts*: every Pallas kernel in this
package must agree with its oracle exactly (integer paths) or to
float tolerance (float paths). pytest + hypothesis enforce this
(`python/tests/test_kernels.py`).

The quantization/crossbar model implemented here is the same one the
rust functional simulator implements (`rust/src/pim/crossbar.rs`); the
integration test `rust/tests/kernel_parity.rs` closes the triangle
(pallas kernel ≡ jnp oracle ≡ rust crossbar).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PimConfig:
    """Static crossbar configuration (one point of Table 1's ReRAM space).

    Attributes:
        xbar: crossbar rows per tile (16/32/64). Row tiling happens at
            this granularity; each row-tile's column sums pass through
            the ADC separately (that is what makes the config matter).
        dac_bits: DAC resolution (1/2) — input bits fed per cycle.
        cell_bits: memristor precision (1/2) — weight bits per cell.
        adc_bits: ADC resolution (4/6/8) — output levels per column read.
        x_bits: activation quantization (fixed 8 in AutoRAC's space).
        w_bits: weight quantization (4/8, searched per operator).
    """

    xbar: int = 64
    dac_bits: int = 1
    cell_bits: int = 2
    adc_bits: int = 8
    x_bits: int = 8
    w_bits: int = 8

    @property
    def n_chunks(self) -> int:
        return -(-self.x_bits // self.dac_bits)

    @property
    def n_planes(self) -> int:
        # magnitude bits only; sign handled by pos/neg crossbar pair
        return -(-(self.w_bits - 1) // self.cell_bits)

    @property
    def adc_max_in(self) -> int:
        """Largest analog column sum a row-tile can produce."""
        return self.xbar * ((1 << self.dac_bits) - 1) * ((1 << self.cell_bits) - 1)

    @property
    def adc_step(self) -> int:
        """Integer LSB of the ADC transfer function (≥1)."""
        levels = (1 << self.adc_bits) - 1
        return max(1, -(-self.adc_max_in // levels))

    def feasible(self) -> bool:
        """The paper's feasibility rule ("we only consider combinations of
        DAC and memristor precision that fall within the maximum ADC
        resolution range to avoid any loss during the analog-to-digital
        conversion process"): the largest analog column sum must be
        representable exactly, i.e. the ADC step is 1."""
        return self.adc_max_in <= (1 << self.adc_bits) - 1


# ---------------------------------------------------------------------------
# Quantization helpers (digital periphery)
# ---------------------------------------------------------------------------

def quant_sym(w, bits: int):
    """Symmetric per-tensor weight quantization → (int values, scale)."""
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax
    wq = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int32)
    return wq, scale


def quant_act_u8(x, bits: int = 8):
    """Activation quantization to *offset-binary* unsigned ints.

    Crossbars compute with non-negative line voltages, so signed
    activations are shifted by 2^(bits-1); the offset contribution
    (offset · column-sum) is subtracted digitally afterwards.
    Returns (x_u int32 in [0, 2^bits-1], scale, offset).
    """
    qmax = (1 << (bits - 1)) - 1
    offset = 1 << (bits - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    xq = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return xq + offset, scale, offset


def adc_transfer(v, cfg: PimConfig):
    """Mid-tread integer ADC: round to the step grid, clip to full scale."""
    levels = (1 << cfg.adc_bits) - 1
    step = cfg.adc_step
    code = jnp.clip((v + step // 2) // step, 0, levels)
    return code * step


# ---------------------------------------------------------------------------
# Oracle 1: bit-serial crossbar MVM (integer core)
# ---------------------------------------------------------------------------

def pim_mvm_int_ref(x_u, w_pos, w_neg, cfg: PimConfig):
    """Reference for the crossbar MVM integer core.

    Args:
        x_u: int32 [B, K] unsigned offset-binary activations.
        w_pos/w_neg: int32 [K, N] magnitude parts of the quantized weight
            (w_q = w_pos - w_neg, both in [0, 2^(w_bits-1)-1]).
    Returns:
        int32 [B, N]: Σ over row-tiles/chunks/planes of ADC-quantized
        partial sums, shift-add recombined. K must be a multiple of
        cfg.xbar (the mapping layer pads).
    """
    B, K = x_u.shape
    N = w_pos.shape[1]
    assert K % cfg.xbar == 0, "pad K to the crossbar size"
    dac_mask = (1 << cfg.dac_bits) - 1
    cell_mask = (1 << cfg.cell_bits) - 1
    acc = jnp.zeros((B, N), dtype=jnp.int32)
    for t in range(K // cfg.xbar):
        rows = slice(t * cfg.xbar, (t + 1) * cfg.xbar)
        xt = x_u[:, rows]
        for c in range(cfg.n_chunks):
            chunk = (xt >> (c * cfg.dac_bits)) & dac_mask
            for p in range(cfg.n_planes):
                shift = c * cfg.dac_bits + p * cfg.cell_bits
                for wmat, sign in ((w_pos, 1), (w_neg, -1)):
                    plane = (wmat[rows, :] >> (p * cfg.cell_bits)) & cell_mask
                    # Analog column sums. The dot is computed in f32 and
                    # rounded back: bit-exact because operands are tiny
                    # (≤ 2^dac·2^cell · xbar ≪ 2^24), and it sidesteps a
                    # miscompiled s32 dot_general in the xla_extension
                    # 0.5.1 CPU backend the rust runtime links against.
                    partial = (
                        chunk.astype(jnp.float32) @ plane.astype(jnp.float32)
                    ).astype(jnp.int32)
                    acc = acc + sign * (adc_transfer(partial, cfg) << shift)
    return acc


def pim_linear_ref(x, w, cfg: PimConfig):
    """Full PIM linear layer: quantize → crossbar MVM → dequantize.

    The float-in/float-out contract used by the L2 model's inference
    path. x: [B, K] float, w: [K, N] float → [B, N] float.
    """
    K = x.shape[-1]
    pad = (-K) % cfg.xbar
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    wq, w_scale = quant_sym(w, cfg.w_bits)
    w_pos = jnp.maximum(wq, 0)
    w_neg = jnp.maximum(-wq, 0)
    x_u, x_scale, offset = quant_act_u8(x, cfg.x_bits)
    acc = pim_mvm_int_ref(x_u, w_pos, w_neg, cfg)
    # Digital periphery: offset correction uses the same ADC path the
    # hardware's dummy row sees — modeled exactly (ones-vector MVM).
    ones = jnp.full((1, x_u.shape[1]), offset, dtype=jnp.int32)
    corr = pim_mvm_int_ref(ones, w_pos, w_neg, cfg)
    return (acc - corr).astype(jnp.float32) * x_scale * w_scale


# ---------------------------------------------------------------------------
# Oracle 2: FM interaction (square-of-sum minus sum-of-squares)
# ---------------------------------------------------------------------------

def fm_ref(x):
    """x: [B, N, d] → [B, d]; 0.5 · ((Σ_n x)² − Σ_n x²) as in Rendle'10.

    The 0.5 makes each pair count once (the transposed-array engine
    produces the same result by construction).
    """
    s = jnp.sum(x, axis=-2)
    ss = jnp.sum(x * x, axis=-2)
    return 0.5 * (s * s - ss)


# ---------------------------------------------------------------------------
# Oracle 3: DP engine (pairwise inner products, Gram matrix)
# ---------------------------------------------------------------------------

def dp_gram_ref(x):
    """x: [B, m, d] → [B, m, m] Gram matrix XXᵀ (full; triu selection is
    digital addressing and happens in the wrapper)."""
    return jnp.einsum("bmd,bnd->bmn", x, x)


def dp_triu_ref(x):
    """x: [B, m, d] → [B, m(m-1)/2] — strict upper triangle, row-major."""
    g = dp_gram_ref(x)
    m = x.shape[-2]
    iu = np.triu_indices(m, k=1)
    return g[:, iu[0], iu[1]]


# ---------------------------------------------------------------------------
# Fake-quant (training-time) reference — straight-through estimator
# ---------------------------------------------------------------------------

def fake_quant_ref(w, bits: int):
    """Round-to-grid weight fake-quantization (forward value only)."""
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax
    return jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
