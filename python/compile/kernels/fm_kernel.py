"""L1 Pallas kernel: FM interaction engine (paper Fig. 3b / Fig. 4d–e).

Computes the sparse-to-dense factorization-machine merger
``0.5·((Σ_n x_n)² − Σ_n x_n²)`` per batch element.

Hardware story (what the single fused pass models): the EFC layer's
output vectors are written *column-wise* into a transposed ReRAM array
(Wan ISSCC'20-style), so

  * a ones-vector read along word lines yields Σ_n x_n per column
    (square-of-sum input, squared in the MBSA bit-serial AND array);
  * reading the array with each stored vector itself yields x_n², and
    the bit-line sum gives Σ_n x_n² — concurrently with the first read.

Both reductions stream through the same array once, which is why the
kernel is a single pass over N — the paper's "full data pipelining".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_kernel(x_ref, o_ref):
    """x_ref: f32 [1, N, d] (one batch element); o_ref: f32 [1, d]."""
    x = x_ref[0]                      # [N, d]
    s = jnp.sum(x, axis=0)            # Σ x      (ones-vector wordline read)
    ss = jnp.sum(x * x, axis=0)       # Σ x²     (self-vector read, concurrent)
    o_ref[0, :] = 0.5 * (s * s - ss)  # MBSA square + digital subtract


def fm_interaction(x):
    """x: f32 [B, N, d] → f32 [B, d] via Pallas (interpret mode)."""
    B, N, d = x.shape
    return pl.pallas_call(
        _fm_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N, d), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=True,
    )(x)
