"""Deterministic PRNG mirroring ``rust/src/util/rng.rs`` bit-for-bit.

The synthetic CTR datasets are a *pure function* of (profile, seed, index)
so that the build-time python trainer and the run-time rust coordinator
see identical data without shipping dataset files across the boundary.
That only works if both sides run the same generator: splitmix64-seeded
xoshiro256** with identical f64 / range / normal / zipf derivations.

Any change here MUST be mirrored in rng.rs (and vice versa); the golden
vectors in ``python/tests/test_prng.py`` and ``rng.rs::tests`` pin the
contract.
"""

from __future__ import annotations

import math

_M64 = (1 << 64) - 1


def splitmix64(state: int) -> tuple[int, int]:
    """One splitmix64 step: returns (new_state, output)."""
    state = (state + 0x9E3779B97F4A7C15) & _M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return state, (z ^ (z >> 31)) & _M64


def seed_from_name(root: int, name: str) -> int:
    """FNV-1a of the name folded through splitmix64 (mirrors rng.rs)."""
    h = 0xCBF29CE484222325
    for b in name.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & _M64
    _, out = splitmix64(root ^ h)
    return out


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & _M64


class Rng:
    """xoshiro256** (Blackman & Vigna), seeded through splitmix64."""

    __slots__ = ("s",)

    def __init__(self, seed: int):
        s = []
        st = seed & _M64
        for _ in range(4):
            st, v = splitmix64(st)
            s.append(v)
        self.s = s

    def substream(self, name: str) -> "Rng":
        return Rng(seed_from_name(self.s[0] ^ self.s[2], name))

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & _M64, 7) * 9) & _M64
        t = (s[1] << 17) & _M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def f32(self) -> float:
        # Mirrors rng.rs: (u >> 40) as f32 / 2^24, computed in f32.
        import struct

        v = (self.next_u64() >> 40) * (1.0 / (1 << 24))
        # round-trip through f32 to match rust's f32 arithmetic
        return struct.unpack("f", struct.pack("f", v))[0]

    def below(self, n: int) -> int:
        """Lemire's unbiased bounded integer (mirrors rng.rs exactly)."""
        assert n > 0
        x = self.next_u64()
        m = x * n
        l = m & _M64
        if l < n:
            t = (-n) % n
            while l < t:
                x = self.next_u64()
                m = x * n
                l = m & _M64
        return (m >> 64) & _M64

    def range(self, lo: int, hi: int) -> int:
        return lo + self.below(hi - lo + 1)

    def chance(self, p: float) -> bool:
        return self.f64() < p

    def normal(self) -> float:
        """Box–Muller, cos branch only (mirrors rng.rs)."""
        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                break
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def shuffle(self, xs: list) -> None:
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


class Zipf:
    """Zipf(alpha) over [0, n) via CDF inversion (mirrors rng.rs)."""

    __slots__ = ("cdf",)

    def __init__(self, n: int, alpha: float):
        assert n > 0
        cdf = []
        acc = 0.0
        for k in range(1, n + 1):
            acc += 1.0 / (k ** alpha)
            cdf.append(acc)
        total = cdf[-1]
        self.cdf = [v / total for v in cdf]

    def sample(self, rng: Rng) -> int:
        u = rng.f64()
        # binary search: first index with cdf[i] >= u (rust uses
        # binary_search_by on partial_cmp; Err(i) is the insertion point,
        # equality is practically unreachable for random u)
        lo, hi = 0, len(self.cdf)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.cdf[mid] < u:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, len(self.cdf) - 1)
