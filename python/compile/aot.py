"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the rust runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exports (all under ``artifacts/``):

* ``model_<ds>_b<B>.hlo.txt`` — inference for the searched AutoRAC genome
  at batch sizes 1/32/512, **pim backend** (Pallas crossbar kernels),
  trained MLP weights baked in as constants ("crossbar programming").
  Signature: (dense f32[B, max(nd,1)], sparse f32[B, Ns, d]) → probs[B].
* ``embeddings_<ds>.bin`` — trained embedding tables (ATNS) for the rust
  memory tiles, which perform the gather at serving time.
* ``train_<ds>.hlo.txt`` + ``train_<ds>_init.bin`` + meta — one fused
  Adagrad train step (params/accums as inputs, gather inside) for the
  e2e rust-driven training example.
* ``genomes/*.json`` — the genome files the rust search/simulator uses.
* ``golden/*.json``  — cross-language parity fixtures (PRNG, records).
* ``meta.json``      — artifact registry (shapes, param orders, profiles).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import atns
from . import model as M
from .arch import Genome, autorac_best, nasrec_like
from .datagen import PROFILES, Generator
from .prng import Rng

INFER_BATCHES = (1, 32, 512)
TRAIN_BATCH = 256
TRAIN_LR = 0.05


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big weight constants as `constant({...})`, which the xla_extension
    # 0.5.1 text parser silently reads back as ZEROS.
    return comp.as_hlo_text(print_large_constants=True)


def load_trained_params(params_dir: str, key: str, g: Genome):
    """Trained calibration params if present, else fresh init (dev mode)."""
    path = os.path.join(params_dir, f"{key}_{g.dataset}.npz")
    if os.path.exists(path):
        z = np.load(path)
        return {k: jnp.asarray(z[k]) for k in z.files}
    print(f"  [aot] WARNING: {path} missing — baking INIT params "
          f"(run compile.train first for trained artifacts)")
    return M.init_params(g, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Inference artifacts
# ---------------------------------------------------------------------------

def export_inference(g: Genome, params: dict, out_dir: str, meta: dict):
    prof = PROFILES[g.dataset]
    nd = max(prof.n_dense, 1)
    mlp = {k: v for k, v in params.items() if not k.startswith("emb/")}

    def infer(dense, sparse):
        return (M.predict_proba(mlp, g, dense, sparse, backend="pim"),)

    for b in INFER_BATCHES:
        dense_spec = jax.ShapeDtypeStruct((b, nd), jnp.float32)
        sparse_spec = jax.ShapeDtypeStruct((b, prof.n_sparse, g.d_emb), jnp.float32)
        lowered = jax.jit(infer).lower(dense_spec, sparse_spec)
        name = f"model_{g.dataset}_b{b}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "kind": "inference",
            "dataset": g.dataset,
            "batch": b,
            "inputs": [
                {"name": "dense", "shape": [b, nd], "dtype": "f32"},
                {"name": "sparse", "shape": [b, prof.n_sparse, g.d_emb],
                 "dtype": "f32"},
            ],
            "outputs": [{"name": "probs", "shape": [b], "dtype": "f32"}],
            "hlo_chars": len(text),
        }
        print(f"  [aot] wrote {name}.hlo.txt ({len(text)/1e6:.1f} MB)")

    # Embedding tables for the rust memory tiles.
    tables = {f"emb/{j}": np.asarray(params[f"emb/{j}"]) for j in
              range(prof.n_sparse)}
    emb_path = os.path.join(out_dir, f"embeddings_{g.dataset}.bin")
    atns.write(emb_path, tables)
    meta["embeddings"][g.dataset] = {
        "file": os.path.basename(emb_path),
        "fields": prof.n_sparse,
        "d_emb": g.d_emb,
        "cards": list(prof.cards),
    }

    # End-to-end parity golden: expected probabilities for the first 8
    # test-split records, evaluated EXACTLY as the rust serving path will
    # (batch-32 artifact semantics: 8 real rows + 24 zero rows — the
    # per-tensor dynamic activation quantization makes probs depend on
    # batch composition, so the golden must match the padding).
    gen = Generator(g.dataset)
    b32 = 32
    dense = np.zeros((b32, nd), dtype=np.float32)
    sparse = np.zeros((b32, prof.n_sparse, g.d_emb), dtype=np.float32)
    test_off = 90_000  # Splits::default() offset shared with rust
    for i in range(8):
        d, ids, _ = gen.record(test_off + i)
        if prof.n_dense:
            dense[i, : prof.n_dense] = d
        for j in range(prof.n_sparse):
            sparse[i, j] = tables[f"emb/{j}"][ids[j]]
    probs = np.asarray(
        M.predict_proba(mlp, g, jnp.array(dense), jnp.array(sparse),
                        backend="pim")
    )
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    with open(os.path.join(gdir, f"probs_{g.dataset}.json"), "w") as f:
        json.dump({"test_offset": test_off, "n": 8,
                   "probs": [float(p) for p in probs[:8]]}, f, indent=2)


# ---------------------------------------------------------------------------
# Train-step artifact (e2e example: rust drives a full training loop)
# ---------------------------------------------------------------------------

def export_train_step(g: Genome, out_dir: str, meta: dict):
    prof = PROFILES[g.dataset]
    nd = max(prof.n_dense, 1)
    params = M.init_params(g, jax.random.PRNGKey(7))
    order = sorted(params.keys())

    def train_step(*args):
        n = len(order)
        p = {k: a for k, a in zip(order, args[:n])}
        acc = {k: a for k, a in zip(order, args[n : 2 * n])}
        dense, ids, y = args[2 * n], args[2 * n + 1], args[2 * n + 2]

        def loss_fn(p):
            logits = M.forward_from_ids(p, g, dense, ids, backend="train")
            return M.bce_loss(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        # global-norm clipping, same as the calibration trainer
        gnorm = jnp.sqrt(sum(jnp.sum(gr * gr) for gr in grads.values()))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-12))
        grads = {k: gr * clip for k, gr in grads.items()}
        outs = []
        for k in order:
            a2 = acc[k] + grads[k] * grads[k]
            outs.append(p[k] - TRAIN_LR * grads[k] / (jnp.sqrt(a2) + 1e-8))
        for k in order:
            outs.append(acc[k] + grads[k] * grads[k])
        outs.append(loss)
        return tuple(outs)

    specs = [jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in order]
    specs += [jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in order]
    specs += [
        jax.ShapeDtypeStruct((TRAIN_BATCH, nd), jnp.float32),
        jax.ShapeDtypeStruct((TRAIN_BATCH, prof.n_sparse), jnp.int32),
        jax.ShapeDtypeStruct((TRAIN_BATCH,), jnp.float32),
    ]
    lowered = jax.jit(train_step).lower(*specs)
    text = to_hlo_text(lowered)
    name = f"train_{g.dataset}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    # Initial params + zero accumulators, in feed order.
    init = {f"p/{k}": np.asarray(params[k]) for k in order}
    # adagrad initial accumulator 0.1 (standard) tames the first steps
    init |= {f"a/{k}": np.full(params[k].shape, 0.1, np.float32) for k in order}
    atns.write(os.path.join(out_dir, f"{name}_init.bin"), init)
    meta["artifacts"][name] = {
        "kind": "train_step",
        "dataset": g.dataset,
        "batch": TRAIN_BATCH,
        "param_order": order,
        "param_shapes": {k: list(params[k].shape) for k in order},
        "lr": TRAIN_LR,
        "inputs_tail": [
            {"name": "dense", "shape": [TRAIN_BATCH, nd], "dtype": "f32"},
            {"name": "ids", "shape": [TRAIN_BATCH, prof.n_sparse],
             "dtype": "i32"},
            {"name": "labels", "shape": [TRAIN_BATCH], "dtype": "f32"},
        ],
        "hlo_chars": len(text),
    }
    print(f"  [aot] wrote {name}.hlo.txt ({len(text)/1e6:.1f} MB, "
          f"{len(order)} params)")


# ---------------------------------------------------------------------------
# Cross-language parity fixtures
# ---------------------------------------------------------------------------

def export_goldens(out_dir: str, seed: int = None):
    from .datagen import DEFAULT_SEED

    seed = seed or DEFAULT_SEED
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    # PRNG stream goldens.
    r = Rng(42)
    stream = [r.next_u64() for _ in range(8)]
    r2 = Rng(7)
    f64s = [r2.f64() for _ in range(8)]
    r3 = Rng(9)
    normals = [r3.normal() for _ in range(8)]
    with open(os.path.join(gdir, "prng.json"), "w") as f:
        json.dump({"stream_seed42": [str(v) for v in stream],
                   "f64_seed7": f64s, "normal_seed9": normals}, f, indent=2)
    # Record goldens per dataset.
    records = {}
    for ds in PROFILES:
        gen = Generator(ds, seed)
        recs = []
        for i in list(range(8)) + [10_000, 99_999]:
            dense, ids, y = gen.record(i)
            recs.append({
                "index": i,
                "dense": [float(v) for v in dense],
                "ids": [int(v) for v in ids],
                "y": int(y),
            })
        records[ds] = recs
    with open(os.path.join(gdir, "records.json"), "w") as f:
        json.dump({"seed": seed, "records": records}, f, indent=2)
    print(f"  [aot] wrote golden fixtures")


# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--datasets", default="criteo,avazu,kdd")
    ap.add_argument("--skip-train-step", action="store_true")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "genomes"), exist_ok=True)
    params_dir = os.path.join(out, "params")

    meta = {"version": 1, "artifacts": {}, "embeddings": {}, "profiles": {}}
    for ds, prof in PROFILES.items():
        meta["profiles"][ds] = {
            "n_dense": prof.n_dense,
            "cards": list(prof.cards),
            "zipf_alpha": prof.zipf_alpha,
            "base_ctr": prof.base_ctr,
        }

    for ds in args.datasets.split(","):
        print(f"=== aot: {ds} ===", flush=True)
        for maker, key in ((autorac_best, "autorac"), (nasrec_like, "nasrec")):
            g = maker(ds)
            g.save(os.path.join(out, "genomes", f"{key}_{ds}.json"))
        g = autorac_best(ds)
        params = load_trained_params(params_dir, "autorac", g)
        export_inference(g, params, out, meta)
        if ds == "criteo" and not args.skip_train_step:
            export_train_step(autorac_best("criteo"), out, meta)

    export_goldens(out)
    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("aot complete")


if __name__ == "__main__":
    main()
