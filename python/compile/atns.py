"""ATNS — a tiny binary tensor container for the python→rust boundary.

Used for the trained embedding tables (loaded by the rust memory tiles)
and the train-step initial parameters (fed to the train-step HLO by the
e2e example). MLP weights of inference models are NOT shipped this way —
they are baked into the HLO as constants ("programming the crossbars").

Layout (little-endian):
    magic   b"ATNS"
    u32     version (1)
    u32     tensor count
    per tensor:
        u32   name length, then UTF-8 name bytes
        u8    dtype (0 = f32, 1 = i32, 2 = i64)
        u8    ndim
        u32×ndim  shape
        u64   payload bytes
        raw   payload (row-major)

Rust reader: ``rust/src/runtime/atns.rs``.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"ATNS"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32, 2: np.int64}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.int64): 2}


def write(path: str, tensors: dict) -> None:
    """tensors: ordered {name: np.ndarray} (f32 / i32 / i64 only)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES.get(arr.dtype)
            if code is None:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def read(path: str) -> dict:
    """Inverse of :func:`write` (used by tests; rust has its own reader)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = f.read(nbytes)
            out[name] = np.frombuffer(data, dtype=_DTYPES[code]).reshape(shape).copy()
    return out
