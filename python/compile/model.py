"""L2 model: the AutoRAC choice-block network, built from a Genome.

The model is the paper's §3.1 composition: N choice blocks + final FC.
Each block ingests any subset of earlier dense/sparse outputs (0 = raw
inputs), applies its dense operator (FC or DP), sparse operator (EFC or
identity), and optional interaction (DSI or FM), and emits one dense and
one sparse tensor. See arch.py for the genome schema and shape rules.

``init_params`` / ``forward`` are pure functions over a params dict so
the same code path serves training (backend="train", differentiable)
and AOT lowering (backend="pim", Pallas kernels, weights baked).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import ops
from .arch import DSI_FEATURES, Genome
from .datagen import PROFILES
from .kernels import PimConfig


def pim_config(g: Genome) -> PimConfig:
    return PimConfig(
        xbar=g.pim.xbar,
        dac_bits=g.pim.dac_bits,
        cell_bits=g.pim.cell_bits,
        adc_bits=g.pim.adc_bits,
    )


# ---------------------------------------------------------------------------
# Static shape inference (mirrored in rust/src/nas/space.rs::shapes)
# ---------------------------------------------------------------------------

def infer_shapes(g: Genome):
    """Walk the block graph and return per-block IO shapes.

    Returns a list of dicts with keys din, dout (dense dims) and
    nin, nout (sparse feature counts); index 0 is the raw input.
    """
    prof = PROFILES[g.dataset]
    dense_dims = [max(prof.n_dense, 1)]  # raw dense (≥1: zero pad when absent)
    sparse_ns = [prof.n_sparse]
    shapes = []
    for b in g.blocks:
        din = sum(dense_dims[j] for j in b.dense_in)
        nin = sum(sparse_ns[j] for j in b.sparse_in)
        nout = b.sparse_features if b.sparse_op == "efc" else nin
        if b.interaction == "dsi":
            nout += DSI_FEATURES
        shapes.append({"din": din, "dout": b.dense_dim, "nin": nin, "nout": nout})
        dense_dims.append(b.dense_dim)
        sparse_ns.append(nout)
    return shapes


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_params(g: Genome, key, with_embeddings: bool = True) -> dict:
    """Initialize all trainable parameters for a genome."""
    prof = PROFILES[g.dataset]
    shapes = infer_shapes(g)
    params = {}
    keys = iter(jax.random.split(key, 16 * len(g.blocks) + len(prof.cards) + 4))
    if with_embeddings:
        for j, c in enumerate(prof.cards):
            params[f"emb/{j}"] = (
                jax.random.normal(next(keys), (c, g.d_emb), jnp.float32) * 0.05
            )
    for i, (b, sh) in enumerate(zip(g.blocks, shapes)):
        p = f"block{i}"
        if b.dense_op == "fc":
            params[f"{p}/fc"] = _glorot(next(keys), (sh["din"], b.dense_dim))
        else:  # dp
            k = ops.dp_stack_rows(b.dense_dim)
            npairs = (k + 1) * k // 2
            params[f"{p}/dp/w_in"] = _glorot(next(keys), (sh["din"], g.d_emb))
            params[f"{p}/dp/w_efc"] = _glorot(next(keys), (sh["nin"], k))
            params[f"{p}/dp/w_out"] = _glorot(next(keys), (npairs, b.dense_dim))
        if b.sparse_op == "efc":
            params[f"{p}/efc"] = _glorot(next(keys), (sh["nin"], b.sparse_features))
        if b.interaction == "fm":
            params[f"{p}/fm"] = _glorot(next(keys), (g.d_emb, b.dense_dim))
        elif b.interaction == "dsi":
            params[f"{p}/dsi"] = _glorot(
                next(keys), (b.dense_dim, DSI_FEATURES * g.d_emb)
            )
    params["final"] = _glorot(next(keys), (g.blocks[-1].dense_dim, 1))
    return params


def param_count(params: dict) -> int:
    return int(sum(np.prod(v.shape) for v in params.values()))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def embed(params: dict, g: Genome, ids):
    """Gather embeddings: ids int32 [B, N_s] → [B, N_s, d_emb].

    The Figure-2 bit-width sweep quantizes the embedding tables too
    (set a python-side ``g.emb_bits`` attribute; weights-as-stored in the
    sweep's sense include the tables). Normal genomes leave tables at
    full precision — they live in the memory tiles, not the crossbars.
    """
    from .ops import fake_quant

    prof = PROFILES[g.dataset]
    emb_bits = getattr(g, "emb_bits", 32)
    cols = []
    for j in range(prof.n_sparse):
        table = params[f"emb/{j}"]
        if emb_bits < 32:
            table = fake_quant(table, emb_bits)
        cols.append(table[ids[:, j]])
    return jnp.stack(cols, axis=1)


def forward(params: dict, g: Genome, dense, sparse, backend: str = "train"):
    """Model logits.

    Args:
        dense: f32 [B, max(n_dense,1)] — raw dense features (zeros when
            the profile has none, e.g. avazu).
        sparse: f32 [B, N_s, d_emb] — already-gathered embeddings (the
            rust memory tiles do the gather at serving time).
    Returns: f32 [B] logits.
    """
    cfg = pim_config(g)
    dense_outs = [dense]
    sparse_outs = [sparse]
    for i, b in enumerate(g.blocks):
        p = f"block{i}"
        xd = jnp.concatenate([dense_outs[j] for j in b.dense_in], axis=-1)
        xs = ops.concat_sparse([sparse_outs[j] for j in b.sparse_in], g.d_emb)
        # dense branch
        if b.dense_op == "fc":
            yd = ops.fc(params[f"{p}/fc"], xd, b.dense_wbits, backend, cfg)
        else:
            dpp = {
                "w_in": params[f"{p}/dp/w_in"],
                "w_efc": params[f"{p}/dp/w_efc"],
                "w_out": params[f"{p}/dp/w_out"],
            }
            yd = ops.dp(dpp, xd, xs, b.dense_dim, b.dense_wbits, backend, cfg)
        # sparse branch
        if b.sparse_op == "efc":
            ys = ops.efc(params[f"{p}/efc"], xs, b.sparse_wbits, backend, cfg)
        else:
            ys = xs
        # interaction
        if b.interaction == "fm":
            yd = yd + ops.fm(params[f"{p}/fm"], ys, b.inter_wbits, backend, cfg)
        elif b.interaction == "dsi":
            extra = ops.dsi(
                params[f"{p}/dsi"], yd, DSI_FEATURES, g.d_emb,
                b.inter_wbits, backend, cfg,
            )
            ys = jnp.concatenate([ys, extra], axis=1)
        dense_outs.append(yd)
        sparse_outs.append(ys)
    logit = ops.linear(params["final"], dense_outs[-1], g.final_wbits, backend, cfg)
    return logit[:, 0]


def forward_from_ids(params: dict, g: Genome, dense, ids, backend: str = "train"):
    """Training-path forward that includes the embedding gather."""
    return forward(params, g, dense, embed(params, g, ids), backend)


def predict_proba(params: dict, g: Genome, dense, sparse, backend: str = "pim"):
    return jax.nn.sigmoid(forward(params, g, dense, sparse, backend))


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------

def bce_loss(logits, y):
    """Numerically-stable binary cross entropy with logits."""
    return jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def logloss(probs, y, eps: float = 1e-7):
    p = np.clip(np.asarray(probs, dtype=np.float64), eps, 1 - eps)
    y = np.asarray(y, dtype=np.float64)
    return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))


def auc(probs, y) -> float:
    """Rank-based AUC (Mann–Whitney)."""
    p = np.asarray(probs, dtype=np.float64)
    y = np.asarray(y)
    order = np.argsort(p, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    # average ranks for ties
    sorted_p = p[order]
    i = 0
    n = len(p)
    while i < n:
        j = i
        while j + 1 < n and sorted_p[j + 1] == sorted_p[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    n_pos = float(y.sum())
    n_neg = float(len(y) - n_pos)
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
