"""Hand-crafted CTR baselines for Table 2 (train-path only).

Each baseline implements the uniform interface
``init(key, dataset, d_emb) -> params`` and
``forward(params, dense, ids) -> logits`` so the calibration trainer
(:mod:`compile.train`) treats all rows of Table 2 identically.

Implementations are faithful, compact versions of the cited designs:

* **DLRM** (Naumov'19) — bottom MLP on dense, pairwise-dot feature
  interaction over field embeddings, top MLP.
* **DeepFM** (Guo'17) — first+second-order FM plus a deep MLP sharing
  the same embeddings.
* **xDeepFM** (Lian'18) — Compressed Interaction Network (CIN) + DNN.
* **AutoInt+** (Song'19) — multi-head self-attention over field
  embeddings, plus a parallel DNN.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .datagen import PROFILES
from .kernels.ref import fm_ref


def _glorot(key, shape):
    lim = math.sqrt(6.0 / (shape[0] + shape[-1]))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def _embeddings(keys, prof, d_emb):
    return {
        f"emb/{j}": jax.random.normal(next(keys), (c, d_emb), jnp.float32) * 0.05
        for j, c in enumerate(prof.cards)
    }


def _embed(params, prof, ids):
    return jnp.stack(
        [params[f"emb/{j}"][ids[:, j]] for j in range(prof.n_sparse)], axis=1
    )


def _mlp_init(keys, dims, prefix):
    return {
        f"{prefix}/w{i}": _glorot(next(keys), (dims[i], dims[i + 1]))
        for i in range(len(dims) - 1)
    } | {
        f"{prefix}/b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
        for i in range(len(dims) - 1)
    }


def _mlp(params, x, n_layers, prefix, final_relu=False):
    for i in range(n_layers):
        x = x @ params[f"{prefix}/w{i}"] + params[f"{prefix}/b{i}"]
        if i < n_layers - 1 or final_relu:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------

def dlrm_init(key, dataset, d_emb=32):
    prof = PROFILES[dataset]
    keys = iter(jax.random.split(key, 64 + prof.n_sparse))
    nd = max(prof.n_dense, 1)
    params = _embeddings(keys, prof, d_emb)
    params |= _mlp_init(keys, [nd, 128, d_emb], "bot")
    m = prof.n_sparse + 1
    n_int = m * (m - 1) // 2
    params |= _mlp_init(keys, [n_int + d_emb, 256, 128, 1], "top")
    return params


def dlrm_forward(params, dataset, dense, ids):
    prof = PROFILES[dataset]
    e = _embed(params, prof, ids)  # [B, N, d]
    z = _mlp(params, dense, 2, "bot", final_relu=True)  # [B, d]
    x = jnp.concatenate([z[:, None, :], e], axis=1)  # [B, N+1, d]
    g = jnp.einsum("bmd,bnd->bmn", x, x)
    m = x.shape[1]
    iu = jnp.triu_indices(m, k=1)
    inter = g[:, iu[0], iu[1]]
    top_in = jnp.concatenate([inter, z], axis=-1)
    return _mlp(params, top_in, 3, "top")[:, 0]


# ---------------------------------------------------------------------------
# DeepFM
# ---------------------------------------------------------------------------

def deepfm_init(key, dataset, d_emb=32):
    prof = PROFILES[dataset]
    keys = iter(jax.random.split(key, 64 + 2 * prof.n_sparse))
    nd = max(prof.n_dense, 1)
    params = _embeddings(keys, prof, d_emb)
    for j, c in enumerate(prof.cards):  # first-order weights
        params[f"w1/{j}"] = jax.random.normal(next(keys), (c,), jnp.float32) * 0.01
    params["w_dense"] = _glorot(next(keys), (nd, 1))
    params |= _mlp_init(keys, [prof.n_sparse * d_emb + nd, 256, 128, 1], "dnn")
    return params


def deepfm_forward(params, dataset, dense, ids):
    prof = PROFILES[dataset]
    e = _embed(params, prof, ids)
    first = sum(params[f"w1/{j}"][ids[:, j]] for j in range(prof.n_sparse))
    first = first + (dense @ params["w_dense"])[:, 0]
    second = jnp.sum(fm_ref(e), axis=-1)  # scalar FM interaction
    dnn_in = jnp.concatenate([e.reshape(e.shape[0], -1), dense], axis=-1)
    deep = _mlp(params, dnn_in, 3, "dnn")[:, 0]
    return first + second + deep


# ---------------------------------------------------------------------------
# xDeepFM (CIN + DNN)
# ---------------------------------------------------------------------------

CIN_LAYERS = [16, 16]


def xdeepfm_init(key, dataset, d_emb=32):
    prof = PROFILES[dataset]
    keys = iter(jax.random.split(key, 64 + prof.n_sparse))
    nd = max(prof.n_dense, 1)
    params = _embeddings(keys, prof, d_emb)
    h_prev = prof.n_sparse
    for li, h in enumerate(CIN_LAYERS):
        params[f"cin/w{li}"] = _glorot(next(keys), (h_prev * prof.n_sparse, h))
        h_prev = h
    params["cin/out"] = _glorot(next(keys), (sum(CIN_LAYERS), 1))
    params |= _mlp_init(keys, [prof.n_sparse * d_emb + nd, 256, 128, 1], "dnn")
    return params


def xdeepfm_forward(params, dataset, dense, ids):
    prof = PROFILES[dataset]
    e = _embed(params, prof, ids)  # [B, m, d]
    x0 = e
    xk = e
    pooled = []
    for li, h in enumerate(CIN_LAYERS):
        # outer product along fields, compressed: z [B, Hk*m, d]
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        z = z.reshape(z.shape[0], -1, z.shape[-1])
        xk = jnp.einsum("bnd,nh->bhd", z, params[f"cin/w{li}"])
        xk = jax.nn.relu(xk)
        pooled.append(jnp.sum(xk, axis=-1))  # [B, h]
    cin = jnp.concatenate(pooled, axis=-1) @ params["cin/out"]
    dnn_in = jnp.concatenate([e.reshape(e.shape[0], -1), dense], axis=-1)
    deep = _mlp(params, dnn_in, 3, "dnn")[:, 0]
    return cin[:, 0] + deep


# ---------------------------------------------------------------------------
# AutoInt+
# ---------------------------------------------------------------------------

N_HEADS = 2
ATT_DIM = 32


def autoint_init(key, dataset, d_emb=32):
    prof = PROFILES[dataset]
    keys = iter(jax.random.split(key, 64 + prof.n_sparse))
    nd = max(prof.n_dense, 1)
    params = _embeddings(keys, prof, d_emb)
    for h in range(N_HEADS):
        for nm in ("q", "k", "v"):
            params[f"att/{nm}{h}"] = _glorot(next(keys), (d_emb, ATT_DIM))
    params["att/res"] = _glorot(next(keys), (d_emb, N_HEADS * ATT_DIM))
    params["att/out"] = _glorot(next(keys), (prof.n_sparse * N_HEADS * ATT_DIM, 1))
    params |= _mlp_init(keys, [prof.n_sparse * d_emb + nd, 256, 128, 1], "dnn")
    return params


def autoint_forward(params, dataset, dense, ids):
    prof = PROFILES[dataset]
    e = _embed(params, prof, ids)  # [B, m, d]
    heads = []
    for h in range(N_HEADS):
        q = e @ params[f"att/q{h}"]
        k = e @ params[f"att/k{h}"]
        v = e @ params[f"att/v{h}"]
        att = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2) / math.sqrt(ATT_DIM), axis=-1)
        heads.append(att @ v)  # [B, m, ATT_DIM]
    multi = jnp.concatenate(heads, axis=-1)  # [B, m, H*A]
    multi = jax.nn.relu(multi + e @ params["att/res"])
    att_logit = multi.reshape(multi.shape[0], -1) @ params["att/out"]
    dnn_in = jnp.concatenate([e.reshape(e.shape[0], -1), dense], axis=-1)
    deep = _mlp(params, dnn_in, 3, "dnn")[:, 0]
    return att_logit[:, 0] + deep


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

BASELINES = {
    "dlrm": (dlrm_init, dlrm_forward),
    "deepfm": (deepfm_init, deepfm_forward),
    "xdeepfm": (xdeepfm_init, xdeepfm_forward),
    "autoint+": (autoint_init, autoint_forward),
}
