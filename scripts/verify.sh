#!/usr/bin/env bash
# One-command repo verify (CI entry point). Fully offline:
#   1. tier-1: release build + full test suite (artifact-gated tests skip)
#   2. qcheck-heavy property/differential suites again under --release
#      (optimized float paths + the randomized DAG differential)
#   3. hygiene: no #[ignore]d test may exist unless it is artifact-gated
#   4. rustdoc with ALL warnings denied (broken intra-doc links included)
#
# Usage: ./scripts/verify.sh   (from anywhere; cd's to the repo root)

set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier 1: cargo build --release =="
cargo build --release

echo "== tier 1: cargo test -q =="
cargo test -q

echo "== tier 1.5: property/differential suites under --release =="
# The qcheck suites draw hundreds of randomized cases; running them
# optimized both speeds CI and exercises the release float paths the
# benches measure.
cargo test -q --release --test sharding_prop --test sim_differential --test coordinator_e2e --test hotcache_prop --test failover_prop --test tail_prop --test fault_prop
cargo test -q --release --lib mapping::cost

echo "== wire suites under --release: lazy/tree differential + malformed-input =="
# The lazy scanner's whole contract is "never disagrees with the tree
# parser"; the security suite pins "malformed bytes never panic or hang
# the server". Both are release-mode properties (optimized byte loops).
cargo test -q --release --test json_lazy_prop --test wire_security

echo "== serve-bench socket smoke: loopback TCP end to end, cache on =="
# One CI-sized run through the real stack: TCP accept loop, lazy wire
# parse, coordinator, hot-row cache tier + batch coalescer, response
# encoder, loadgen socket clients (with OOV sentinels injected so the
# oob_ids counter is exercised). Fail closed on the report lines AND
# the JSON fields disappearing. The report is kept at the repo root as
# the serving paper-artifact snapshot.
serve_json=BENCH_serving.json
serve_out=$(cargo run --quiet --release --bin autorac -- serve-bench \
    --listen 127.0.0.1:0 --quick --conns 4 --cache-rows 256 \
    --oov-frac 0.05 --json "$serve_json")
printf '%s\n' "$serve_out"
if ! printf '%s\n' "$serve_out" | grep -q "wire (4 conns)"; then
    echo "ERROR: serve-bench --listen no longer reports wire-level stats"
    exit 1
fi
if ! printf '%s\n' "$serve_out" | grep -q "parse: tree"; then
    echo "ERROR: serve-bench --listen no longer runs the parse microbench"
    exit 1
fi
# the hit-rate line only prints when cache lookups actually happened —
# its absence means the cache tier silently fell out of the hot path
if ! printf '%s\n' "$serve_out" | grep -q "cache: hit-rate"; then
    echo "ERROR: serve-bench --cache-rows no longer reports the cache hit-rate"
    exit 1
fi
for field in '"transport": "socket"' '"schema_version"' '"wire_p50_us"' \
    '"throughput_rps"' '"lazy_speedup"' '"cache_hit_rate"' \
    '"coalesced_rows"' '"oob_ids"'; do
    if ! grep -q "$field" "$serve_json"; then
        echo "ERROR: serve-bench socket JSON report lost $field"
        exit 1
    fi
done

echo "== serve-bench failover smoke: worker-crash scenario, 4 workers =="
# Kill worker 1 two batches in (deterministic fuse — a wall-clock fuse
# can lose the race on a fast CI box) and hold the run to the §SH SLO:
# post-crash availability >= 99%, exact ledger, p99 under budget. The
# coordinator must reroute around the corpse — a single "no live
# worker" / "all worker queues closed" line means the old poison bug
# is back. Fail closed on the verdict line AND the JSON fields.
crash_json=$(mktemp /tmp/serve_crash.XXXXXX.json)
crash_out=$(cargo run --quiet --release --bin autorac -- serve-bench \
    --quick --workers 4 --scenario worker-crash --crash-worker 1 \
    --crash-after-batches 2 --slo-p99-ms 500 --json "$crash_json")
printf '%s\n' "$crash_out"
if printf '%s\n' "$crash_out" | grep -Eq "no live worker|all worker queues closed"; then
    echo "ERROR: a single worker crash surfaced a total-outage error"
    exit 1
fi
if ! printf '%s\n' "$crash_out" | grep -q "SLO PASS"; then
    echo "ERROR: worker-crash scenario missed its SLO (or the verdict line vanished)"
    exit 1
fi
for field in '"scenario": "worker-crash"' '"ledger_ok": true' \
    '"slo_ok": true' '"post_crash_availability"' '"live_workers"'; do
    if ! grep -q "$field" "$crash_json"; then
        echo "ERROR: worker-crash JSON report lost $field"
        exit 1
    fi
done
rm -f "$crash_json"

echo "== serve-bench gray-failure smoke: slow-worker scenario, hedging on =="
# One worker turns into a 20ms-per-batch straggler (gray: correct but
# slow) two batches in. The tail machinery must (a) hedge — hedges > 0,
# (b) keep the extended ledger exact, and (c) beat the unhedged twin
# run's p99 — all folded into the "verdict PASS" on the tail SLO line.
# Fail closed on that line, its counters, and the JSON fields: a
# vanished `hedges`/`expired`/`degraded_responses` counter means the
# gray-failure telemetry silently fell out of the report.
gray_json=$(mktemp /tmp/serve_gray.XXXXXX.json)
gray_out=$(cargo run --quiet --release --bin autorac -- serve-bench \
    --quick --workers 2 --scenario slow-worker --slow-after-batches 2 \
    --slo-p99-ms 500 --json "$gray_json")
printf '%s\n' "$gray_out"
if ! printf '%s\n' "$gray_out" | grep -q "tail SLO: hedges"; then
    echo "ERROR: slow-worker scenario no longer prints the tail SLO line"
    exit 1
fi
if ! printf '%s\n' "$gray_out" | grep "tail SLO:" | grep -q "verdict PASS"; then
    echo "ERROR: slow-worker tail SLO verdict is not PASS (hedging broken or p99 regressed)"
    exit 1
fi
for field in '"scenario": "slow-worker"' '"schema_version"' '"hedges"' \
    '"expired"' '"degraded_responses"' '"ledger_ok": true' \
    '"unhedged_p99_us"' '"tail_slo_ok": true'; do
    if ! grep -q "$field" "$gray_json"; then
        echo "ERROR: slow-worker JSON report lost $field"
        exit 1
    fi
done
rm -f "$gray_json"

echo "== search determinism under --release (workers=8 vs serial) =="
# Bit-identity of the parallel engine is a release-mode property too —
# optimized float codegen must not reorder the per-candidate reductions.
cargo test -q --release --test search_determinism
cargo test -q --release --lib nas::

echo "== search-bench smoke: the eval cache must land hits =="
# The duplicate-heavy smoke revisits single-step mutation neighbours; a
# 0% hit-rate means the genome-keyed memo (or its structural hash) broke.
bench_out=$(cargo run --quiet --release --bin autorac -- search-bench --workers 8 --generations 12)
printf '%s\n' "$bench_out"
# fail-closed: the smoke line must exist AND report a non-zero hit-rate
if ! printf '%s\n' "$bench_out" | grep -q "duplicate-heavy smoke: cache hit-rate"; then
    echo "ERROR: search-bench no longer prints the duplicate-heavy smoke line"
    exit 1
fi
if printf '%s\n' "$bench_out" | grep -q "duplicate-heavy smoke: cache hit-rate 0.0%"; then
    echo "ERROR: duplicate-heavy smoke reported a 0% cache hit-rate"
    exit 1
fi
if ! printf '%s\n' "$bench_out" | grep -q "parallel trace bit-identical to serial: true"; then
    echo "ERROR: search-bench did not confirm serial/parallel bit-identity"
    exit 1
fi

echo "== xbar-bench parity smoke: batched kernel vs reference, 4 threads =="
# The batched crossbar kernel's contract is bit-identity with the
# per-vector reference (outputs AND activity counts) on every config AND
# at every thread count. xbar-bench ensure!s it in-run — at threads 1
# and 4 here — and exits non-zero on any mismatch (including any ABFT
# false positive on clean hardware); fail-closed on the parity and
# ABFT-overhead lines disappearing too. The JSON report is kept at the
# repo root as the kernel paper-artifact snapshot (ROADMAP: bench
# trajectory), so regressions in pack/thread speedups and checksum
# overhead are diffable across PRs.
xbar_json=BENCH_xbar.json
xbar_out=$(cargo run --quiet --release --bin autorac -- xbar-bench --quick --threads 4 --json "$xbar_json")
printf '%s\n' "$xbar_out"
if ! printf '%s\n' "$xbar_out" | grep -q "parity: OK"; then
    echo "ERROR: xbar-bench did not report kernel parity"
    exit 1
fi
if ! printf '%s\n' "$xbar_out" | grep -q "abft b=32:"; then
    echo "ERROR: xbar-bench no longer measures the ABFT verify overhead"
    exit 1
fi
for field in '"bench": "xbar"' '"pack_speedup_b32"' '"abft_overhead"'; do
    if ! grep -q "$field" "$xbar_json"; then
        echo "ERROR: xbar-bench JSON report lost $field"
        exit 1
    fi
done

echo "== serve-bench device-fault smoke: cell-fault scenario, PIM engine =="
# Program every worker's crossbar banks with seeded stuck-at cells (a
# per-worker substream each) plus a spare-tile budget, then hold the run
# to the §SJ fault SLO: exact ledger AND zero corrupted responses AND a
# twin-engine probe showing repaired scores bit-identical to a
# fault-free engine. The rate is production-plausible (~a few stuck
# cells across the whole fleet) so single-cell faults dominate — each
# one is detected by the ABFT checksum and repaired from a spare, and
# the verdict must come out PASS. Fail closed on the verdict line AND
# the JSON fields.
fault_json=$(mktemp /tmp/serve_fault.XXXXXX.json)
fault_out=$(cargo run --quiet --release --bin autorac -- serve-bench \
    --quick --workers 2 --engine pim --scenario cell-fault \
    --fault-rate 2e-6 --spare-tiles 4 --json "$fault_json")
printf '%s\n' "$fault_out"
if ! printf '%s\n' "$fault_out" | grep -q "fault SLO:"; then
    echo "ERROR: cell-fault scenario no longer prints the fault SLO line"
    exit 1
fi
if ! printf '%s\n' "$fault_out" | grep "fault SLO:" | grep -q "verdict PASS"; then
    echo "ERROR: cell-fault SLO verdict is not PASS (detection/repair broken or ledger drifted)"
    exit 1
fi
for field in '"scenario": "cell-fault"' '"tiles_faulty"' '"tiles_repaired"' \
    '"corrupted_responses"' '"ledger_ok": true' '"fault_slo_ok": true'; do
    if ! grep -q "$field" "$fault_json"; then
        echo "ERROR: cell-fault JSON report lost $field"
        exit 1
    fi
done
rm -f "$fault_json"

echo "== hygiene: the blocked i64 kernel fallback must stay deleted =="
# Every tile geometry now takes the multi-word packed AND+popcount path;
# a reappearing scalar fallback would silently re-slow the large-tile
# configs the search space rewards.
if grep -rn "mvm_batch_blocked" rust/src; then
    echo "ERROR: the blocked i64 fallback symbol is back in the kernel"
    exit 1
fi

echo "== kernel-parity + thread-determinism suites under --release =="
cargo test -q --release --test xbar_kernel --test xbar_threads

echo "== hygiene: no un-gated #[ignore] tests =="
# Skipping must be an artifact-gate (runtime check + eprintln SKIP), not
# a silent #[ignore]: any #[ignore] line must carry an 'artifact'
# justification on the same line.
if grep -rn '#\[ignore' rust/src rust/tests | grep -v 'artifact'; then
    echo "ERROR: #[ignore]d test(s) without artifact gating (see above)"
    exit 1
fi

echo "== docs: cargo doc --no-deps (warnings denied) =="
# -D warnings turns every rustdoc lint — including
# rustdoc::broken_intra_doc_links and rustdoc::bare_urls — into an error.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "verify OK"
