#!/usr/bin/env bash
# One-command repo verify (CI entry point). Fully offline:
#   1. tier-1: release build + full test suite (artifact-gated tests skip)
#   2. rustdoc with ALL warnings denied (broken intra-doc links included)
#
# Usage: ./scripts/verify.sh   (from anywhere; cd's to the repo root)

set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier 1: cargo build --release =="
cargo build --release

echo "== tier 1: cargo test -q =="
cargo test -q

echo "== docs: cargo doc --no-deps (warnings denied) =="
# -D warnings turns every rustdoc lint — including
# rustdoc::broken_intra_doc_links and rustdoc::bare_urls — into an error.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "verify OK"
