//! Bench: regenerate **Figure 6** (best model discovered). Prints the
//! genome saved by the fig5/search run when present (the actual search
//! output), falling back to the checked-in reference winner, and
//! verifies the paper's qualitative precision trends.
//!
//! Run: `cargo bench --bench fig6`

use autorac::nas::{autorac_best, DenseOp, Genome, SparseOp};
use std::path::Path;

fn main() -> autorac::Result<()> {
    let searched = Path::new("artifacts/searched_best.json");
    let (g, source) = if searched.exists() {
        (Genome::load(searched)?, "artifacts/searched_best.json (search output)")
    } else {
        (autorac_best("criteo"), "built-in reference winner (run fig5 to search)")
    };
    println!("source: {source}");
    autorac::report::fig6(&g);

    // Figure 6 trends reported by the paper:
    let efc8 = g
        .blocks
        .iter()
        .filter(|b| b.sparse_op == SparseOp::Efc)
        .all(|b| b.sparse_wbits == 8);
    let first_fc8 = g
        .blocks
        .iter()
        .find(|b| b.dense_op == DenseOp::Fc)
        .map(|b| b.dense_wbits == 8)
        .unwrap_or(false);
    let mid_has_4bit = g.blocks[1..g.blocks.len() - 1]
        .iter()
        .any(|b| b.dense_wbits == 4);
    println!("trend: EFC layers predominantly 8-bit ............ {}", yn(efc8));
    println!("trend: first FC retains 8-bit precision .......... {}", yn(first_fc8));
    println!("trend: mid-network FCs use 4-bit precision ....... {}", yn(mid_has_4bit));
    Ok(())
}

fn yn(b: bool) -> &'static str {
    if b {
        "yes"
    } else {
        "no (see EXPERIMENTS.md §F6)"
    }
}
