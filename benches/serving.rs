//! Serving bench: the latency/throughput knee of the shard-aware
//! coordinator under MockEngine — zero artifacts, fully offline.
//!
//! Four experiments:
//!   1. routing-policy comparison at fixed closed-loop load (capacity
//!      regime): throughput, tail latency and cross-shard gather rows
//!      for round-robin / least-queued / shard-affinity;
//!   2. open-loop Poisson sweep against measured capacity (0.4×–1.1×)
//!      with stale-shedding admission — where the knee and the shed
//!      rate appear;
//!   3. hot-row cache A/B at 0.8× capacity open-loop load: the same
//!      skewed traffic with the cache off vs a 1024-row prefetched
//!      tier — p50/p99, hit rate and coalesced rows (EXPERIMENTS.md
//!      §SG);
//!   4. wire-parse microbench: the lazy scanner (util::json_lazy) vs
//!      the full tree parser over the deterministic request corpus,
//!      with and without a realistic cold `ctx` payload — the
//!      EXPERIMENTS.md §SF numbers.
//!   5. failover drill: the worker-crash scenario (EXPERIMENTS.md §SH)
//!      — one worker dies mid-run, survivors absorb its shard via
//!      replica promotion; reports availability, post-crash
//!      availability, and the balanced loss ledger.
//!
//! Run: `cargo bench --bench serving` (AUTORAC_BENCH_FAST=1 shrinks the
//! request counts for smoke runs).

use autorac::coordinator::loadgen::{
    self, Arrival, CrashInjector, LoadGenConfig, Scenario, ScenarioSpec,
};
use autorac::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig,
    MetricsSnapshot, MockEngine, Policy, ServingStore,
};
use autorac::data::profile;
use autorac::embeddings::{
    head_rows_per_table, HotCacheConfig, HotRowCache, ShardMap, ShardPolicy,
    ShardedStore,
};
use autorac::util::json_lazy;
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 4;
const D_EMB: usize = 16;
const BATCH: usize = 32;
const SEED: u64 = 7;
const COVERAGE: f64 = 0.35;
const EXEC: Duration = Duration::from_micros(20);

fn run_once(
    policy: Policy,
    arrival: Arrival,
    admission: AdmissionPolicy,
    n_requests: usize,
    cache_rows: usize,
) -> autorac::Result<MetricsSnapshot> {
    let prof = profile("criteo")?;
    let cached = if cache_rows > 0 {
        head_rows_per_table(&prof.cards, prof.zipf_alpha, cache_rows)
    } else {
        Vec::new()
    };
    let map = ShardMap::build_cached(
        &prof.cards,
        prof.zipf_alpha,
        WORKERS,
        ShardPolicy::HotReplicated,
        &cached,
    );
    let store = Arc::new(ShardedStore::random(&prof, D_EMB, SEED, map));
    let serving = if cache_rows > 0 {
        let cache = HotRowCache::new(
            &store,
            prof.zipf_alpha,
            HotCacheConfig {
                capacity: cache_rows,
                prefetch: true,
            },
        );
        ServingStore::Cached(store, Arc::new(cache))
    } else {
        ServingStore::Sharded(store)
    };
    let (nd, nf) = (prof.n_dense, prof.n_sparse());
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: WORKERS,
            policy,
            admission,
            shed_after: Duration::from_millis(2),
            batcher: BatcherConfig {
                max_batch: BATCH,
                max_wait: Duration::ZERO,
            },
            ..Default::default()
        },
        serving,
        move |_| {
            let mut e = MockEngine::new(BATCH, nd, nf, D_EMB);
            e.delay = EXEC;
            Ok(Box::new(e) as Box<dyn autorac::coordinator::InferenceEngine>)
        },
    )?;
    loadgen::run(
        &coord,
        &prof,
        &LoadGenConfig {
            n_requests,
            arrival,
            seed: SEED,
            coverage: COVERAGE,
            oov_frac: 0.0,
        },
    )?;
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    Ok(snap)
}

fn main() -> autorac::Result<()> {
    let fast = std::env::var("AUTORAC_BENCH_FAST").is_ok();
    let n = if fast { 600 } else { 4000 };

    println!("== serving bench: criteo, {WORKERS} workers, hot-replicated shards, coverage {COVERAGE} ==\n");

    // -- 1. routing policies at closed-loop capacity ---------------------
    println!(
        "{:<16} {:>12} {:>10} {:>10} {:>14}",
        "policy", "throughput", "p50 µs", "p99 µs", "cross-shard"
    );
    let mut capacity = 0.0f64;
    for policy in [Policy::RoundRobin, Policy::LeastQueued, Policy::ShardAffinity] {
        let s = run_once(
            policy,
            Arrival::ClosedLoop { concurrency: 64 },
            AdmissionPolicy::RejectNew,
            n,
            0,
        )?;
        println!(
            "{:<16} {:>10.0}/s {:>10.0} {:>10.0} {:>8} ({:>4.1}%)",
            format!("{policy:?}"),
            s.throughput_rps,
            s.e2e_p50_us,
            s.e2e_p99_us,
            s.remote_rows,
            s.cross_shard_frac() * 100.0
        );
        capacity = capacity.max(s.throughput_rps);
    }

    // -- 2. open-loop knee vs capacity (stale shedding on) ---------------
    println!("\nopen-loop Poisson sweep (shard-affinity, shed-stale 2 ms):");
    println!(
        "{:<10} {:>12} {:>10} {:>10} {:>10}",
        "load", "offered/s", "p50 µs", "p99 µs", "shed-rate"
    );
    for frac in [0.4, 0.7, 0.9, 1.1] {
        let rps = capacity * frac;
        let s = run_once(
            Policy::ShardAffinity,
            Arrival::OpenLoop { rps },
            AdmissionPolicy::ShedStale,
            n,
            0,
        )?;
        println!(
            "{:<10} {:>12.0} {:>10.0} {:>10.0} {:>9.1}%",
            format!("{frac:.1}×cap"),
            rps,
            s.e2e_p50_us,
            s.e2e_p99_us,
            s.shed_rate() * 100.0
        );
    }
    println!(
        "\n(knee: p99 and shed-rate step up as offered load crosses capacity; \
         regen via `autorac serve-bench`, methodology in EXPERIMENTS.md §SB)"
    );

    // -- 3. hot-row cache A/B at 0.8x capacity ---------------------------
    println!("\nhot-row cache A/B (shard-affinity, open-loop 0.8×cap, shed-stale 2 ms):");
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "cache", "p50 µs", "p99 µs", "hit-rate", "coalesced", "cross-shard"
    );
    let rps = capacity * 0.8;
    let mut p99 = [0.0f64; 2];
    for (i, rows) in [0usize, 1024].into_iter().enumerate() {
        let s = run_once(
            Policy::ShardAffinity,
            Arrival::OpenLoop { rps },
            AdmissionPolicy::ShedStale,
            n,
            rows,
        )?;
        p99[i] = s.e2e_p99_us;
        println!(
            "{:<14} {:>10.0} {:>10.0} {:>9.1}% {:>12} {:>12}",
            if rows == 0 {
                "off".to_string()
            } else {
                format!("{rows} rows")
            },
            s.e2e_p50_us,
            s.e2e_p99_us,
            s.cache_hit_rate() * 100.0,
            s.coalesced_rows,
            s.remote_rows,
        );
    }
    println!(
        "(cache p99 {:.2}x vs off; zipf head traffic served from the shared \
         tier, methodology in EXPERIMENTS.md §SG)",
        p99[0] / p99[1].max(1e-9)
    );

    // -- 4. wire-parse microbench: lazy scanner vs tree parser -----------
    parse_bench(n.min(512))?;

    // -- 5. failover drill: one worker dies mid-run ----------------------
    failover_bench(n)?;
    Ok(())
}

/// Worker-crash scenario: the same closed-loop stack as experiment 1,
/// but worker 1's engine is armed to unwind after a few batches. The
/// survivors absorb its shard (replica promotion), the dead worker's
/// queue is booked `failed`, and the run must stay available — the
/// EXPERIMENTS.md §SH drill at bench scale.
fn failover_bench(n_requests: usize) -> autorac::Result<()> {
    let prof = profile("criteo")?;
    let map = ShardMap::build(
        &prof.cards,
        prof.zipf_alpha,
        WORKERS,
        ShardPolicy::HotReplicated,
    );
    let store = Arc::new(ShardedStore::random(&prof, D_EMB, SEED, map));
    let (nd, nf) = (prof.n_dense, prof.n_sparse());
    let mut spec = ScenarioSpec::new(Scenario::WorkerCrash);
    spec.crash_worker = 1;
    // fuse roughly a quarter into the victim's expected batch stream
    spec.crash_after_batches =
        Some((n_requests / (WORKERS * BATCH) / 4).max(1));
    let inj = Arc::new(
        CrashInjector::new(&spec).expect("worker-crash spec arms an injector"),
    );
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: WORKERS,
            policy: Policy::ShardAffinity,
            admission: AdmissionPolicy::RejectNew,
            batcher: BatcherConfig {
                max_batch: BATCH,
                max_wait: Duration::ZERO,
            },
            ..Default::default()
        },
        ServingStore::Sharded(store),
        move |i| {
            let mut e = MockEngine::new(BATCH, nd, nf, D_EMB);
            e.delay = EXEC;
            Ok(inj.arm(i, Box::new(e)))
        },
    )?;
    let out = loadgen::run_scenario(
        &coord,
        &prof,
        &LoadGenConfig {
            n_requests,
            arrival: Arrival::ClosedLoop { concurrency: 64 },
            seed: SEED,
            coverage: COVERAGE,
            oov_frac: 0.0,
        },
        &spec,
    )?;
    // the guard books losses before reply senders drop, but give the
    // dying thread a bounded grace period to finish its drain
    let t0 = std::time::Instant::now();
    let snap = loop {
        let s = coord.metrics.snapshot();
        if s.ledger_ok() || t0.elapsed() > Duration::from_secs(2) {
            break s;
        }
        std::thread::sleep(Duration::from_millis(1));
    };
    coord.shutdown();
    let accepted = snap.requests - snap.rejected;
    let avail = if accepted == 0 {
        1.0
    } else {
        snap.responses as f64 / accepted as f64
    };
    let post_avail = if out.post_crash_sent == 0 {
        avail
    } else {
        out.post_crash_completed as f64 / out.post_crash_sent as f64
    };
    println!(
        "\nfailover drill (worker-crash scenario, worker {} armed, {} requests):",
        spec.crash_worker, n_requests
    );
    println!(
        "  availability {:.2}% | post-crash {:.2}% ({}/{}) | failed {} | \
         live workers {}/{} | ledger {}",
        avail * 100.0,
        post_avail * 100.0,
        out.post_crash_completed,
        out.post_crash_sent,
        snap.failed,
        snap.live_workers(),
        WORKERS,
        if snap.ledger_ok() { "balanced" } else { "UNBALANCED" },
    );
    println!(
        "(the dead worker's queue is booked `failed`, survivors absorb its \
         shard via replica promotion; methodology in EXPERIMENTS.md §SH)"
    );
    Ok(())
}

/// Seconds per call of `f`: one warmup call, then as many as fit the
/// budget (single-threaded, mirrors main.rs `time_per_call`).
fn time_per_call<F: FnMut()>(budget: Duration, mut f: F) -> f64 {
    f();
    let t0 = std::time::Instant::now();
    let mut calls = 0u64;
    while t0.elapsed() < budget {
        f();
        calls += 1;
    }
    t0.elapsed().as_secs_f64() / calls.max(1) as f64
}

fn parse_bench(n_requests: usize) -> autorac::Result<()> {
    let prof = profile("criteo")?;
    let cfg = LoadGenConfig {
        n_requests,
        arrival: Arrival::ClosedLoop { concurrency: 64 },
        seed: SEED,
        coverage: COVERAGE,
        oov_frac: 0.0,
    };
    println!("\nwire-parse microbench ({n_requests}-request corpus, ns/request):");
    println!(
        "{:<22} {:>10} {:>10} {:>9}",
        "corpus", "tree", "lazy", "speedup"
    );
    for (label, with_ctx) in [("hot fields only", false), ("with cold ctx", true)] {
        let corpus = loadgen::wire_corpus(&prof, &cfg, with_ctx)?;
        let lines: Vec<&[u8]> =
            corpus.iter().map(|l| l.trim_end().as_bytes()).collect();
        let budget = Duration::from_millis(300);
        let per = |f: &dyn Fn(&[u8])| {
            time_per_call(budget, || {
                for line in &lines {
                    f(line);
                }
            }) / lines.len() as f64
                * 1e9
        };
        let tree_ns = per(&|b| {
            let _ = std::hint::black_box(json_lazy::parse_request_tree(b));
        });
        let lazy_ns = per(&|b| {
            let _ = std::hint::black_box(json_lazy::parse_request(b));
        });
        println!(
            "{label:<22} {tree_ns:>10.0} {lazy_ns:>10.0} {:>8.1}x",
            tree_ns / lazy_ns.max(1e-9)
        );
    }
    println!(
        "(lazy must win by >= 5x on the ctx corpus — the serving hot path \
         only extracts id/dense/tables/ids; regen in EXPERIMENTS.md §SF)"
    );
    Ok(())
}
