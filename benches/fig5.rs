//! Bench: regenerate **Figure 5** (% criterion drop over 240 search
//! generations) on the parallel engine — all hardware threads, memoized
//! evaluations, bit-identical to a serial run (S20). Full fidelity by
//! default; AUTORAC_BENCH_FAST=1 runs a 40-generation smoke version.
//!
//! Run: `cargo bench --bench fig5`

use autorac::nas::SearchConfig;

fn main() -> autorac::Result<()> {
    let fast = std::env::var("AUTORAC_BENCH_FAST").ok().as_deref() == Some("1");
    let cfg = SearchConfig {
        generations: if fast { 40 } else { 240 },
        workers: SearchConfig::all_cores(),
        ..SearchConfig::default()
    };
    let (drop, best) = autorac::report::fig5(cfg)?;
    // Paper shape: >10% drop within the first 50 generations, then a
    // plateau with late incremental gains.
    let at50 = drop.get(50.min(drop.len() - 1)).copied().unwrap_or(0.0);
    let fin = *drop.last().unwrap();
    println!("\nshape check: drop@50 {at50:.1}% (paper: >10%), final {fin:.1}%");
    autorac::report::fig6(&best);
    best.save(std::path::Path::new("artifacts/searched_best.json"))?;
    Ok(())
}
