//! Bench: the parallel co-search engine (S20) — wall-clock across worker
//! counts on the default-config smoke, with cache hit-rate and evals/sec
//! (EXPERIMENTS.md §SC). The serial row (1 worker) is the baseline the
//! speedup column divides by; traces are bit-identical across rows
//! (pinned by `rust/tests/search_determinism.rs`), so every row does
//! exactly the same logical work.
//!
//! Run: `cargo bench --bench search`   (AUTORAC_BENCH_FAST=1 shrinks it)

use autorac::nas::{ParallelSearch, SearchConfig, Surrogate};
use std::time::Instant;

fn main() -> autorac::Result<()> {
    let fast = std::env::var("AUTORAC_BENCH_FAST").ok().as_deref() == Some("1");
    let generations = if fast { 12 } else { 24 };
    let cores = SearchConfig::all_cores();
    println!(
        "search-bench sweep: {generations} generations, default SearchConfig, \
         {cores} hardware thread(s)"
    );
    println!(
        "{:>8} {:>9} {:>9} {:>9} {:>11} {:>12}",
        "workers", "wall s", "speedup", "evals/s", "cache hits", "best"
    );
    let mut serial_s = f64::NAN;
    for &workers in &[1usize, 2, 4, 8] {
        let cfg = SearchConfig {
            generations,
            workers,
            ..SearchConfig::default()
        };
        let t0 = Instant::now();
        let mut s = ParallelSearch::new(cfg, Surrogate::load_default())?;
        let best = s.run()?;
        let dt = t0.elapsed().as_secs_f64();
        if workers == 1 {
            serial_s = dt;
        }
        let cs = s.cache_stats();
        println!(
            "{workers:>8} {dt:>9.2} {:>8.2}x {:>9.0} {:>5} ({:>4.1}%) {:>12.4}",
            serial_s / dt.max(1e-9),
            s.trace.evaluations as f64 / dt.max(1e-9),
            cs.hits,
            100.0 * cs.hit_rate(),
            best.criterion
        );
    }
    println!(
        "note: ideal speedup saturates at min(workers, children_per_gen, cores); \
         this host has {cores} core(s)"
    );
    Ok(())
}
