//! Bench: regenerate **Table 3** (hardware metrics of AutoRAC against
//! CPU / RecNMP / naively-mapped NASRec / ReREC) on the behavioral
//! simulator with real-scale memory tiles and pooled gathers.
//!
//! Run: `cargo bench --bench table3`

fn main() -> autorac::Result<()> {
    for ds in ["criteo", "avazu", "kdd"] {
        autorac::report::table3(ds)?;
    }
    println!(
        "\nShape targets (paper, Criteo): CPU 22.83×/66.87×, RecNMP \
         3.36×/12.48×, NASRec 3.17×/2.39×/1.68× area, ReREC 1.28×/1.57×.\n\
         See EXPERIMENTS.md §T3 for the measured-vs-paper discussion."
    );
    Ok(())
}
