//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! A1  smart vs naive mapping (same genome, same PIM config)
//! A2  transposed-write FM array vs row-serial buffering
//! A3  access-aware vs contiguous embedding placement
//! A4  searched mixed precision vs uniform 8-bit (area/power)
//! A5  PIM config sweep: crossbar size × DAC × cell × ADC (feasible set)
//!
//! Run: `cargo bench --bench ablations`

use autorac::embeddings::placement::avg_conflict_depth;
use autorac::embeddings::{Placement, Strategy};
use autorac::mapping::{map_genome, MapStyle};
use autorac::nas::{autorac_best, nasrec_like};
use autorac::pim::{PimConfig, TechParams};
use autorac::sim::{simulate, Workload};
use autorac::util::rng::Rng;

fn main() -> autorac::Result<()> {
    let tech = TechParams::default();
    let wl = Workload::default();

    // A1: mapping style, same genome.
    println!("A1: mapping style (nasrec genome, fixed PIM config)");
    let g = nasrec_like("criteo");
    let smart = simulate(&map_genome(&g, &tech, MapStyle::Smart)?, None, &wl);
    let naive = simulate(&map_genome(&g, &tech, MapStyle::Naive)?, None, &wl);
    println!(
        "  smart {:.0} inf/s vs naive {:.0} inf/s → {:.2}× from mapping alone",
        smart.throughput_rps,
        naive.throughput_rps,
        smart.speedup_vs(&naive)
    );

    // A2: transposed-write FM (isolate the operand-write primitive).
    println!("A2: FM operand write: transposed vs row-serial (d=32, n=16)");
    let t = autorac::mapping::cost::operand_write_cost(32, 16, 800.0, true, &tech);
    let n = autorac::mapping::cost::operand_write_cost(32, 16, 800.0, false, &tech);
    println!(
        "  transposed {:.0} ns vs row-serial {:.0} ns → {:.2}×",
        t.latency_ns,
        n.latency_ns,
        n.latency_ns / t.latency_ns
    );

    // A3: embedding placement under batched zipf traffic.
    println!("A3: embedding placement (criteo cards, 8 banks, batch 4)");
    let cards = autorac::data::profile("criteo")?.cards;
    let freqs = Placement::zipf_freqs(&cards, 1.25);
    let aa = Placement::build(&freqs, 8, Strategy::AccessAware);
    let co = Placement::build(&freqs, 8, Strategy::Contiguous);
    let d_aa = avg_conflict_depth(&aa, &cards, 1.25, 4, 300, &mut Rng::new(5));
    let d_co = avg_conflict_depth(&co, &cards, 1.25, 4, 300, &mut Rng::new(5));
    println!(
        "  access-aware depth {d_aa:.1} vs contiguous {d_co:.1} → {:.2}× fewer conflicts",
        d_co / d_aa
    );

    // A4: mixed precision vs uniform 8-bit on the searched genome.
    println!("A4: searched mixed precision vs uniform 8-bit");
    let mixed = autorac_best("criteo");
    let mut uni = mixed.clone();
    for b in &mut uni.blocks {
        b.dense_wbits = 8;
        b.sparse_wbits = 8;
        b.inter_wbits = 8;
    }
    let m = simulate(&map_genome(&mixed, &tech, MapStyle::Smart)?, None, &wl);
    let u = simulate(&map_genome(&uni, &tech, MapStyle::Smart)?, None, &wl);
    println!(
        "  mixed: {:.2} mm², {:.2} W | uniform-8b: {:.2} mm², {:.2} W → {:.2}× area, {:.2}× power saved",
        m.area_mm2,
        m.power_mw / 1e3,
        u.area_mm2,
        u.power_mw / 1e3,
        u.area_mm2 / m.area_mm2,
        u.power_mw / m.power_mw
    );

    // A5: PIM config sweep on the searched model.
    println!("A5: feasible PIM configs on the autorac genome (criteo)");
    println!(
        "  {:<6} {:>4} {:>5} {:>4} {:>12} {:>9} {:>9}",
        "xbar", "dac", "cell", "adc", "inf/s", "mm²", "W"
    );
    let mut rows = Vec::new();
    for cfg in PimConfig::enumerate_feasible() {
        let mut g = autorac_best("criteo");
        g.pim = cfg;
        let r = simulate(&map_genome(&g, &tech, MapStyle::Smart)?, None, &wl);
        rows.push((cfg, r));
    }
    rows.sort_by(|a, b| b.1.throughput_rps.partial_cmp(&a.1.throughput_rps).unwrap());
    for (cfg, r) in &rows {
        println!(
            "  {:<6} {:>4} {:>5} {:>4} {:>12.0} {:>9.2} {:>9.2}",
            cfg.xbar,
            cfg.dac_bits,
            cfg.cell_bits,
            cfg.adc_bits,
            r.throughput_rps,
            r.area_mm2,
            r.power_mw / 1e3
        );
    }
    println!(
        "  → best config {:?} (the search discovers this trade-off; the\n    \
         paper's searched design uses 64/1/2/8)",
        (rows[0].0.xbar, rows[0].0.dac_bits, rows[0].0.cell_bits, rows[0].0.adc_bits)
    );
    Ok(())
}
