//! Bench: regenerate **Figure 2** (Criteo test LogLoss vs weight
//! bit-width) from the calibration sweep, plus the PIM noise-model view
//! of the same trend.
//!
//! Run: `cargo bench --bench fig2`

use std::path::Path;

fn main() -> autorac::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("calibration/fig2.json").exists() {
        eprintln!("SKIP fig2: run `make artifacts` first");
        return Ok(());
    }
    let pts = autorac::report::fig2(dir)?;
    // The paper's qualitative claim: stable ≥8 bits, degrading below.
    let get = |bits: usize| pts.iter().find(|p| p.0 == bits).map(|p| p.1);
    if let (Some(l32), Some(l8), Some(l4), Some(l2)) =
        (get(32), get(8), get(4), get(2))
    {
        println!(
            "\nknee check: 32b {l32:.4} vs 8b {l8:.4} (Δ {:+.4}) | 4b {l4:.4} | 2b {l2:.4}",
            l8 - l32
        );
        println!(
            "paper claim reproduced: {} (8-bit ≈ fp32, sharp loss below 4 bits)",
            if (l8 - l32).abs() < 0.03 && l2 > l8 {
                "YES"
            } else {
                "PARTIAL — see EXPERIMENTS.md"
            }
        );
    }
    Ok(())
}
