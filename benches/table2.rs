//! Bench: regenerate **Table 2** (model accuracy on the three CTR
//! benchmarks) from the calibration artifacts, and cross-check the
//! AutoRAC row by evaluating the served PIM artifact from rust.
//!
//! Run: `cargo bench --bench table2`

use autorac::data::{make_batch, profile, Generator, Splits, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use std::path::Path;

fn main() -> autorac::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("calibration/accuracy.json").exists() {
        eprintln!("SKIP table2: run `make artifacts` first");
        return Ok(());
    }
    autorac::report::table2(dir)?;
    println!(
        "\nPaper reference (real datasets): AutoRAC Criteo 0.4397/0.8116, \
         Avazu 0.3736/0.7906, KDD 0.1489/0.8160 — absolute values differ on\n\
         the synthetic stand-ins; orderings are the reproduction target \
         (see EXPERIMENTS.md §T2)."
    );

    // Rust-side verification: evaluate the AutoRAC PIM artifact on test
    // records through the actual serving stack (quantized crossbar path).
    if !Runtime::pjrt_available() {
        eprintln!("SKIP rust-side eval: PJRT backend not linked (offline stub runtime::xla)");
    } else if dir.join("model_criteo_b512.hlo.txt").exists() {
        let n = 2048usize;
        let prof = profile("criteo")?;
        let store = EmbeddingStore::from_atns(&TensorFile::read(
            &dir.join("embeddings_criteo.bin"),
        )?)?;
        let mut rt = Runtime::open(dir)?;
        let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
        let off = Splits::default().offset("test");
        let nd = prof.n_dense.max(1);
        let mut probs = Vec::new();
        let mut labels = Vec::new();
        for start in (0..n).step_by(512) {
            let b = make_batch(&mut gen, off + start, 512.min(n - start));
            let mut dense = b.dense.clone();
            dense.resize(512 * nd, 0.0);
            let mut sparse = Vec::new();
            store.gather(&b.ids, b.batch, &mut sparse);
            sparse.resize(512 * prof.n_sparse() * store.d_emb, 0.0);
            let p = rt.infer(
                "model_criteo_b512",
                &dense,
                [512, nd],
                &sparse,
                [512, prof.n_sparse(), store.d_emb],
            )?;
            probs.extend_from_slice(&p[..b.batch]);
            labels.extend_from_slice(&b.labels);
        }
        println!(
            "\nRust-side PIM-artifact eval (criteo, {n} test records): \
             LogLoss {:.4}  AUC {:.4}",
            autorac::metrics::logloss(&probs, &labels),
            autorac::metrics::auc(&probs, &labels)
        );
    }
    Ok(())
}
