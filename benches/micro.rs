//! Micro-benchmarks of the L3 hot paths — the §Perf workload.
//!
//! Covers: the behavioral simulator (inside the search loop), genome
//! mapping, the functional crossbar MVM, the evolution step, synthetic
//! record generation, embedding gather, JSON parsing, and the
//! coordinator's batching overhead with a mock engine.
//!
//! Run: `cargo bench --bench micro` (results appended to
//! artifacts/bench_log.json for before/after diffs; tag via
//! AUTORAC_BENCH_TAG)

use autorac::coordinator::{Coordinator, CoordinatorConfig, MockEngine, Request};
use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::mapping::{map_genome, MapStyle};
use autorac::nas::{autorac_best, mutate, Search, SearchConfig, Surrogate};
use autorac::pim::{
    BatchedXbar, MatI32, PimConfig, ProgrammedXbar, TechParams, XbarActivity,
    XbarScratch,
};
use autorac::sim::{simulate, Workload};
use autorac::util::bench::Bencher;
use autorac::util::rng::Rng;
use std::sync::{mpsc, Arc};

fn main() -> autorac::Result<()> {
    let mut b = Bencher::new();
    let tech = TechParams::default();
    let genome = autorac_best("criteo");

    // -- mapping + simulation (the search-loop inner cost) --------------
    b.bench("map_genome(smart)", || {
        std::hint::black_box(map_genome(&genome, &tech, MapStyle::Smart).unwrap());
    });
    let mapped = map_genome(&genome, &tech, MapStyle::Smart)?;
    let wl = Workload {
        n_requests: 48,
        ..Workload::default()
    };
    b.bench("simulate(48 req)", || {
        std::hint::black_box(simulate(&mapped, None, &wl));
    });
    b.bench("search_candidate_eval (map+sim+surrogate)", || {
        let m = map_genome(&genome, &tech, MapStyle::Smart).unwrap();
        let r = simulate(&m, None, &wl);
        std::hint::black_box(r.throughput_rps);
    });

    // -- evolution ------------------------------------------------------
    let mut rng = Rng::new(1);
    b.bench("mutate", || {
        std::hint::black_box(mutate(&genome, &mut rng));
    });
    {
        let cfg = SearchConfig {
            generations: 1,
            population: 16,
            children_per_gen: 8,
            sim_requests: 48,
            ..SearchConfig::default()
        };
        let mut search = Search::new(cfg, Surrogate::prior())?;
        search.init_population()?;
        b.bench("evolution_generation (8 children)", || {
            search.step().unwrap();
        });
    }

    // -- functional crossbar ---------------------------------------------
    let cfg = PimConfig::default();
    let mut rng2 = Rng::new(2);
    let mut w = MatI32::zeros(128, 64);
    for r in 0..128 {
        for c in 0..64 {
            w.set(r, c, rng2.below(255) as i32 - 127);
        }
    }
    let xbar = ProgrammedXbar::program(&w, cfg);
    let x: Vec<i32> = (0..128).map(|_| rng2.below(256) as i32).collect();
    b.bench("crossbar_mvm 128x64 (bit-serial)", || {
        let mut act = XbarActivity::default();
        std::hint::black_box(xbar.mvm_raw(&x, &mut act));
    });
    // batched bit-plane-packed kernel at the serving batch sizes — the
    // before/after trajectory vs the reference loop (per-iter time here
    // is per BATCH; divide by b for per-MVM)
    let bx = BatchedXbar::program(&w, cfg);
    let mut scratch = XbarScratch::default();
    for &bsz in &[1usize, 8, 32] {
        let xs: Vec<i32> =
            (0..bsz * bx.k).map(|_| rng2.below(256) as i32).collect();
        let mut out = vec![0i64; bsz * bx.n];
        b.bench(&format!("crossbar_mvm_batch 128x64 b={bsz}"), || {
            bx.mvm_batch(&xs, bsz, &mut out, &mut scratch);
            std::hint::black_box(&out);
        });
    }
    // tile-geometry × thread sweep: rows = tile height (48 packs into
    // one word, 128/256 into two/four — the geometries the deleted i64
    // fallback used to catch), threads ∈ {1, all cores}. Bit-identity
    // at every point is pinned by tests/xbar_threads.rs; this measures
    // the wall-clock only.
    let host = SearchConfig::all_cores();
    let thread_grid: Vec<usize> = if host > 1 { vec![1, host] } else { vec![1] };
    for &rows in &[48usize, 128, 256] {
        let tcfg = PimConfig {
            xbar: rows,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 8,
            ..PimConfig::default()
        };
        let mut tw = MatI32::zeros(2 * rows, 64); // 2 tiles → real spans
        for r in 0..2 * rows {
            for c in 0..64 {
                tw.set(r, c, rng2.below(255) as i32 - 127);
            }
        }
        let tbx = BatchedXbar::program(&tw, tcfg);
        let bsz = 32usize;
        let xs: Vec<i32> =
            (0..bsz * tbx.k).map(|_| rng2.below(256) as i32).collect();
        let mut out = vec![0i64; bsz * tbx.n];
        for &threads in &thread_grid {
            let mut ts = XbarScratch::with_threads(threads);
            b.bench(
                &format!("crossbar_mvm_batch rows={rows} b={bsz} threads={threads}"),
                || {
                    tbx.mvm_batch(&xs, bsz, &mut out, &mut ts);
                    std::hint::black_box(&out);
                },
            );
        }
    }

    // -- data + embeddings ------------------------------------------------
    let prof = profile("criteo")?;
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let mut idx = 0usize;
    b.bench("record_generation", || {
        idx += 1;
        std::hint::black_box(gen.record(idx));
    });
    let store = EmbeddingStore::random(&prof, 32, 1);
    let ids: Vec<i32> = (0..26).map(|j| (j * 3) as i32).collect();
    let mut out = Vec::new();
    b.bench("embedding_gather (26 fields)", || {
        out.clear();
        store.gather(&ids, 1, &mut out);
        std::hint::black_box(out.len());
    });

    // -- util -------------------------------------------------------------
    let gj = genome.to_json().to_string_pretty();
    b.bench("genome_json_parse", || {
        std::hint::black_box(autorac::util::json::Json::parse(&gj).unwrap());
    });

    // -- coordinator overhead (mock engine: measures pure L3 path) --------
    {
        let store = Arc::new(EmbeddingStore::random(&prof, 32, 2));
        let coord = Coordinator::start(
            CoordinatorConfig::default(),
            store,
            |_| Ok(Box::new(MockEngine::new(32, 13, 26, 32))),
        )?;
        let mut gen2 = Generator::new(prof.clone(), DEFAULT_SEED);
        let mut id = 0u64;
        b.bench("coordinator_roundtrip (mock engine)", || {
            let (tx, rx) = mpsc::channel();
            let (dense, ids) = gen2.features(id as usize);
            id += 1;
            coord
                .submit(Request::full(
                    id,
                    dense,
                    ids.iter().map(|&x| x as i32).collect(),
                    tx,
                ))
                .unwrap();
            std::hint::black_box(rx.recv().unwrap());
        });
        coord.shutdown();
    }

    let tag = std::env::var("AUTORAC_BENCH_TAG").unwrap_or_else(|_| "run".into());
    b.write_log(&tag)?;
    println!("\n(logged to artifacts/bench_log.json, tag `{tag}`)");
    Ok(())
}
