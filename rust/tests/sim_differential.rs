//! Differential test for `sim::simulator` (ISSUE 2 satellite): the
//! allocation-free topological sweep must agree with a naive
//! event-heap reference simulator — written here, sharing no code with
//! the production sweep beyond the cost structs — on randomized DAGs
//! and on the real mapped genome, within float tolerance.
//!
//! The model both simulate: each op is a dedicated pipelined resource
//! (accepts a new request every `bottleneck_ns`, completes it
//! `latency_ns` later), deps always have lower ids, requests arrive in
//! order (jittered open loop or closed loop back-to-back).

use autorac::mapping::{map_genome, MapStyle, MappedModel, MappedOp, OpKind};
use autorac::nas::autorac_best;
use autorac::pim::{EngineKind, TechParams};
use autorac::sim::{simulate, Workload};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::util::rng::Rng;
use autorac::util::stats::Quantiles;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One "op (of request r) became ready at t" event, min-ordered by
/// (time, request, op) so simultaneous events grant FIFO.
struct Ev {
    t: f64,
    r: usize,
    i: usize,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t.total_cmp(&o.t).is_eq() && self.r == o.r && self.i == o.i
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&o.t)
            .then(self.r.cmp(&o.r))
            .then(self.i.cmp(&o.i))
    }
}

struct RefResult {
    latencies: Vec<f64>,
    makespan: f64,
    energy_per_inf: f64,
}

/// Naive event-heap simulation of the same resource model (no
/// embedding front-end, matching `simulate(model, None, wl)`).
fn reference_sim(model: &MappedModel, wl: &Workload) -> RefResult {
    let n_ops = model.ops.len();
    let nr = wl.n_requests;
    // arrivals: replicate the sweep's jitter stream exactly
    let mut rng = Rng::new(wl.seed);
    let inter = if wl.arrival_rps.is_finite() {
        1e9 / wl.arrival_rps
    } else {
        0.0
    };
    let mut arrives = Vec::with_capacity(nr);
    let mut a = 0f64;
    for _ in 0..nr {
        if inter > 0.0 {
            a += inter * (0.5 + rng.f64());
        }
        arrives.push(a);
    }
    // with no front-end the gather is a zero-latency pass-through
    let g_done = arrives.clone();

    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n_ops];
    for (i, op) in model.ops.iter().enumerate() {
        for &d in &op.deps {
            succ[d].push(i);
        }
    }
    let mut deps_left: Vec<Vec<usize>> = (0..nr)
        .map(|_| model.ops.iter().map(|o| o.deps.len()).collect())
        .collect();
    // running max of (g_done, completed deps) per (request, op)
    let mut ready_at: Vec<Vec<f64>> =
        (0..nr).map(|r| vec![g_done[r]; n_ops]).collect();
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    for r in 0..nr {
        for (i, op) in model.ops.iter().enumerate() {
            if op.deps.is_empty() {
                heap.push(Reverse(Ev { t: g_done[r], r, i }));
            }
        }
    }
    let mut free = vec![0f64; n_ops];
    let mut done: Vec<Vec<f64>> = (0..nr).map(|_| vec![0f64; n_ops]).collect();
    while let Some(Reverse(ev)) = heap.pop() {
        let op = &model.ops[ev.i];
        let start = ev.t.max(free[ev.i]);
        let fin = start + op.cost.latency_ns;
        free[ev.i] = start + op.cost.bottleneck_ns.max(1e-3);
        done[ev.r][ev.i] = fin;
        for &s in &succ[ev.i] {
            if ready_at[ev.r][s] < fin {
                ready_at[ev.r][s] = fin;
            }
            deps_left[ev.r][s] -= 1;
            if deps_left[ev.r][s] == 0 {
                heap.push(Reverse(Ev {
                    t: ready_at[ev.r][s],
                    r: ev.r,
                    i: s,
                }));
            }
        }
    }
    let energy_per_inf: f64 =
        model.ops.iter().map(|o| o.cost.energy_pj).sum();
    let latencies: Vec<f64> = (0..nr)
        .map(|r| done[r][n_ops - 1] - arrives[r])
        .collect();
    let makespan = (0..nr)
        .map(|r| done[r][n_ops - 1])
        .fold(0f64, f64::max);
    RefResult {
        latencies,
        makespan,
        energy_per_inf,
    }
}

fn assert_close(a: f64, b: f64, what: &str) -> Result<(), String> {
    let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() > tol {
        return Err(format!("{what}: sweep {a} vs reference {b}"));
    }
    Ok(())
}

fn differential(model: &MappedModel, wl: &Workload) -> Result<(), String> {
    let report = simulate(model, None, wl);
    let rf = reference_sim(model, wl);
    let mut q = Quantiles::new();
    for &l in &rf.latencies {
        q.push(l);
    }
    assert_close(report.makespan_ns, rf.makespan, "makespan")?;
    assert_close(report.latency_ns_mean, q.quantile(0.5), "p50 latency")?;
    assert_close(report.latency_ns_p99, q.p99(), "p99 latency")?;
    assert_close(report.energy_pj_per_inf, rf.energy_per_inf, "energy/inf")?;
    let ref_rps = wl.n_requests as f64 / (rf.makespan.max(1e-9) / 1e9);
    assert_close(report.throughput_rps, ref_rps, "throughput")?;
    Ok(())
}

/// Random DAG with lower-id deps, random pipelined costs (including
/// zero bottlenecks, which exercise the sweep's 1e-3 ns clamp).
fn random_model(g: &mut Gen) -> MappedModel {
    let n_ops = g.usize(1, 14);
    let mut ops = Vec::with_capacity(n_ops);
    for i in 0..n_ops {
        let mut deps = Vec::new();
        for j in 0..i {
            if deps.len() < 3 && g.usize(0, 99) < 35 {
                deps.push(j);
            }
        }
        let latency = g.f64(1.0, 2_000.0);
        let bottleneck = if g.bool() { g.f64(0.0, latency) } else { 0.0 };
        ops.push(MappedOp {
            id: i,
            name: format!("op{i}"),
            kind: OpKind::Fc,
            engine: EngineKind::Mvm,
            cost: autorac::mapping::OpCost {
                latency_ns: latency,
                energy_pj: g.f64(0.0, 1e4),
                bottleneck_ns: bottleneck,
                arrays: 1,
                setup_ns: 0.0,
                setup_pj: 0.0,
            },
            deps,
            bytes_in: 0,
            bytes_out: 0,
        });
    }
    MappedModel {
        genome_name: "random".into(),
        dataset: "criteo".into(),
        style: MapStyle::Smart,
        ops,
        tiles: Vec::new(),
        area_mm2: 1.0,
        leakage_mw: 1.0,
        total_arrays: 1,
        setup_ns: 0.0,
        setup_pj: 0.0,
    }
}

#[test]
fn sweep_matches_event_heap_on_random_dags_closed_loop() {
    qcheck(40, |g| {
        let model = random_model(g);
        let wl = Workload {
            n_requests: g.usize(1, 40),
            arrival_rps: f64::INFINITY,
            seed: g.u64(0, u64::MAX - 1),
        };
        differential(&model, &wl)
    });
}

#[test]
fn sweep_matches_event_heap_on_random_dags_open_loop() {
    qcheck(40, |g| {
        let model = random_model(g);
        let wl = Workload {
            n_requests: g.usize(1, 40),
            // inter-arrival 100 ns – 100 µs around the DAG latencies
            arrival_rps: g.f64(1e4, 1e7),
            seed: g.u64(0, u64::MAX - 1),
        };
        differential(&model, &wl)
    });
}

#[test]
fn sweep_matches_event_heap_on_real_mapped_genome() {
    let tech = TechParams::default();
    for style in [MapStyle::Smart, MapStyle::Naive] {
        let model = map_genome(&autorac_best("criteo"), &tech, style).unwrap();
        for rps in [f64::INFINITY, 2e5] {
            let wl = Workload {
                n_requests: 64,
                arrival_rps: rps,
                seed: 7,
            };
            if let Err(e) = differential(&model, &wl) {
                panic!("style {style:?} rps {rps}: {e}");
            }
        }
    }
}
