//! Kernel-parity suite (ISSUE 4, extended by ISSUE 5): `BatchedXbar::
//! mvm_batch` must be bit-identical — `i64`-equal outputs AND equal
//! `XbarActivity` counts — to the per-vector `ProgrammedXbar::mvm_raw`
//! reference across every feasible PIM config, infeasible (lossy-ADC)
//! configs, 65–256-row wide tiles (multi-word packing, no fallback),
//! ragged batch sizes (1 / 7 / a compiled-batch-sized 32), K-padding
//! edges, and kernel thread counts (serial vs threads=3 re-check on
//! every drawn case; the dedicated suite is `xbar_threads.rs`). The
//! same contract backs `autorac xbar-bench`'s in-run parity gate.

use autorac::nas::genome::WEIGHT_BITS;
use autorac::pim::{
    BatchedXbar, MatI32, PimConfig, ProgrammedXbar, XbarActivity, XbarScratch,
};
use autorac::prop_assert_eq;
use autorac::util::qcheck::{qcheck, Gen};
use autorac::util::rng::Rng;

/// Batch sizes the property draws from: 1 (serve path floor), 7 (ragged),
/// 32 (the default compiled/serving batch).
const BATCHES: [usize; 3] = [1, 7, 32];

fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
    let mut m = MatI32::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
        }
    }
    m
}

/// Per-vector reference outputs + activity over a padded `[b × k]` batch.
fn reference(xbar: &ProgrammedXbar, xs: &[i32], b: usize) -> (Vec<i64>, XbarActivity) {
    let mut act = XbarActivity::default();
    let mut out = Vec::with_capacity(b * xbar.n);
    for j in 0..b {
        out.extend(xbar.mvm_raw(&xs[j * xbar.k..(j + 1) * xbar.k], &mut act));
    }
    (out, act)
}

/// One parity case: program both layouts with the same weights, drive the
/// same inputs, compare raw outputs, corrected outputs, and activity.
fn check_parity(cfg: PimConfig, g: &mut Gen) -> Result<(), String> {
    let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
    // rows straddle tile boundaries: exercises K-padding on both sides
    let rows = g.usize(1, 2 * cfg.xbar + 5);
    let cols = g.usize(1, 24);
    let wq = random_mat(g.rng(), rows, cols, wmax);
    let refx = ProgrammedXbar::program(&wq, cfg);
    let bx = BatchedXbar::program(&wq, cfg);
    prop_assert_eq!(bx.k, refx.k);
    prop_assert_eq!(bx.n, refx.n);
    prop_assert_eq!(bx.program_activity, refx.program_activity);
    prop_assert_eq!(bx.offset_correction(), refx.offset_correction());

    let b = *g.choose(&BATCHES);
    // real rows padded to k — pad value varies (0 vs offset) to pin that
    // padding is the caller's semantic choice, not the kernel's
    let pad = if g.bool() { 0 } else { 1i32 << (cfg.x_bits - 1) };
    let mut xs = Vec::with_capacity(b * bx.k);
    for _ in 0..b {
        for _ in 0..rows.min(bx.k) {
            xs.push(g.rng().below(1u64 << cfg.x_bits) as i32);
        }
        xs.resize(xs.len() + (bx.k - rows.min(bx.k)), pad);
    }

    let (want, want_act) = reference(&refx, &xs, b);
    let mut out = vec![0i64; b * bx.n];
    let mut scratch = XbarScratch::default();
    bx.mvm_batch(&xs, b, &mut out, &mut scratch);
    prop_assert_eq!(&out, &want);
    prop_assert_eq!(scratch.activity, want_act);

    // tile-parallel execution must be invisible in outputs AND activity
    // (small cases fall back to the serial path — identical by
    // construction; big ones actually fan out across threads)
    let mut out_t = vec![0i64; b * bx.n];
    let mut scratch_t = XbarScratch::with_threads(3);
    bx.mvm_batch(&xs, b, &mut out_t, &mut scratch_t);
    prop_assert_eq!(&out_t, &want);
    prop_assert_eq!(scratch_t.activity, want_act);

    // corrected path: same subtraction as the reference's cached vector
    let mut corrected = vec![0i64; b * bx.n];
    bx.mvm_corrected_batch(&xs, b, &mut corrected, &mut scratch);
    for j in 0..b {
        let mut act = XbarActivity::default();
        let want_c = refx.mvm_corrected(&xs[j * bx.k..(j + 1) * bx.k], &mut act);
        prop_assert_eq!(&corrected[j * bx.n..(j + 1) * bx.n], &want_c[..]);
    }
    Ok(())
}

#[test]
fn batched_kernel_matches_reference_on_all_feasible_configs() {
    let configs = PimConfig::enumerate_feasible();
    assert!(!configs.is_empty());
    qcheck(40, |g| {
        let cfg = g.choose(&configs).with_wbits(*g.choose(&WEIGHT_BITS));
        check_parity(cfg, g)
    });
}

#[test]
fn batched_kernel_matches_reference_on_lossy_adc_configs() {
    // infeasible ⇒ adc_transfer is NOT the identity; the kernel must
    // reproduce the reference's quantized partials bit for bit
    let lossy = [
        PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        },
        PimConfig {
            xbar: 16,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 4,
            ..Default::default()
        },
        PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 1,
            adc_bits: 6,
            ..Default::default()
        },
    ];
    for cfg in &lossy {
        assert!(!cfg.feasible(), "{cfg:?} is meant to be infeasible");
    }
    qcheck(25, |g| {
        let cfg = g.choose(&lossy).with_wbits(*g.choose(&WEIGHT_BITS));
        check_parity(cfg, g)
    });
}

#[test]
fn batched_kernel_matches_reference_on_wide_tiles() {
    // Tiles of 65–256 rows pack into 2–4 u64 words per column (the old
    // blocked i64 fallback is gone); ragged widths that straddle word
    // boundaries (e.g. 65, 127, 129, 255) are exactly the partial-last-
    // word edge cases. `check_parity` already draws ragged row counts
    // (1..2·xbar+5) on top, so K-padding is exercised at every width.
    qcheck(16, |g| {
        let cfg = PimConfig {
            xbar: g.usize(65, 256),
            dac_bits: g.usize(1, 2),
            cell_bits: g.usize(1, 2),
            adc_bits: *g.choose(&[4usize, 6, 8]),
            ..Default::default()
        }
        .with_wbits(*g.choose(&WEIGHT_BITS));
        check_parity(cfg, g)
    });
}

#[test]
fn every_feasible_config_is_covered_at_every_batch_size() {
    // deterministic exhaustive floor under the qcheck sampling above:
    // all feasible configs × all pinned batch sizes, one seed
    let mut rng = Rng::new(0x5EED);
    for cfg in PimConfig::enumerate_feasible() {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let wq = random_mat(&mut rng, cfg.xbar * 2 - 3, 7, wmax);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        for b in BATCHES {
            let xs: Vec<i32> = (0..b * bx.k)
                .map(|_| rng.below(1u64 << cfg.x_bits) as i32)
                .collect();
            let (want, want_act) = reference(&refx, &xs, b);
            let mut out = vec![0i64; b * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            assert_eq!(out, want, "cfg {cfg:?} b={b}");
            assert_eq!(scratch.activity, want_act, "cfg {cfg:?} b={b}");
        }
    }
}
