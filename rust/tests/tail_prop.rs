//! Property suite for gray-failure tail tolerance (ISSUE 9): hedged
//! dispatch answers every request exactly once under any race, the
//! extended conservation ledger (`requests == responses + rejected +
//! shed + failed + expired`) stays exact under mixed outcomes, the
//! deadline knob never perturbs the deterministic schedule, quarantined
//! workers receive nothing but trickle probes until one succeeds, and
//! brownout gathers zero-fill exactly the cross-shard rows.

use autorac::coordinator::loadgen::{
    self, build_schedule, Arrival, LoadGenConfig,
};
use autorac::coordinator::router::Router;
use autorac::coordinator::{
    Admission, BatcherConfig, BreakerState, Coordinator, CoordinatorConfig,
    FleetHealth, HedgeGate, InferenceEngine, MockEngine, Policy, Request,
    SlowAfter, TailConfig,
};
use autorac::data::{profile, Profile, ALL_PROFILES};
use autorac::embeddings::{BatchGatherer, EmbeddingStore, ShardMap, ShardPolicy, ShardedStore};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::{prop_assert, prop_assert_eq};
use std::sync::{mpsc, Arc};
use std::time::Duration;

fn tail(hedge_after_ms: u64, budget: f64) -> TailConfig {
    TailConfig {
        hedge_after: Duration::from_millis(hedge_after_ms),
        hedge_budget: budget,
        tick: Duration::from_millis(1),
        ..TailConfig::default()
    }
}

/// One straggling worker (gray: correct but `delay_ms` late), one fast
/// peer, single-request batches so per-request hedging is observable.
fn gray_pair(delay_ms: u64, cfg: CoordinatorConfig) -> Coordinator {
    Coordinator::start(
        cfg,
        Arc::new(EmbeddingStore::random(&profile("criteo").unwrap(), 16, 3)),
        move |i| {
            let e: Box<dyn InferenceEngine> =
                Box::new(MockEngine::new(32, 13, 26, 16));
            Ok(if i == 0 {
                Box::new(SlowAfter::new(
                    e,
                    0,
                    Duration::from_millis(delay_ms),
                    Duration::ZERO,
                    7,
                ))
            } else {
                e
            })
        },
    )
    .unwrap()
}

#[test]
fn hedge_gate_admits_exactly_one_winner_under_contention() {
    qcheck(30, |g| {
        let racers = g.usize(2, 8);
        let gate = Arc::new(HedgeGate::new());
        let wins: Vec<_> = (0..racers)
            .map(|_| {
                let gate = gate.clone();
                std::thread::spawn(move || gate.claim())
            })
            .collect();
        let won = wins.into_iter().filter(|h| h.join().unwrap()).count();
        prop_assert_eq!(won, 1, "{racers} racers, exactly one claim");
        prop_assert!(gate.is_claimed());
        Ok(())
    });
}

#[test]
fn hedged_duplicates_answer_every_request_exactly_once() {
    qcheck(3, |g| {
        let n = g.usize(20, 50) as u64;
        let delay_ms = g.u64(8, 16);
        let c = gray_pair(
            delay_ms,
            CoordinatorConfig {
                n_workers: 2,
                policy: Policy::LeastQueued,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(10),
                },
                tail: Some(tail(2, 1.0)),
                ..Default::default()
            },
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..n {
            let adm = c
                .submit(Request::full(id, vec![0.1; 13], vec![1; 26], tx.clone()))
                .unwrap();
            prop_assert!(matches!(adm, Admission::Enqueued(_)));
        }
        drop(tx);
        // the drain ends only when every reply-sender clone is gone —
        // including the hedge copies and the governor's pending registry
        // — so reaching it at all is part of the property
        let mut got: Vec<u64> = rx.iter().map(|r| r.id).collect();
        got.sort_unstable();
        prop_assert_eq!(
            got,
            (0..n).collect::<Vec<u64>>(),
            "every id exactly once (n {n}, straggler {delay_ms}ms)"
        );
        let snap = c.metrics.snapshot();
        prop_assert!(
            snap.hedges > 0,
            "a {delay_ms}ms straggler vs a 2ms trigger must hedge"
        );
        prop_assert!(
            snap.ledger_ok(),
            "ledger under hedging: req {} resp {} rej {} shed {} failed {} \
             expired {}",
            snap.requests,
            snap.responses,
            snap.rejected,
            snap.shed,
            snap.failed,
            snap.expired
        );
        c.shutdown();
        Ok(())
    });
}

#[test]
fn extended_ledger_is_exact_under_mixed_outcomes() {
    qcheck(4, |g| {
        // random cocktail: maybe deadlines (expiry + infeasible
        // rejections), maybe a tight queue cap (admission rejections),
        // always a straggler (hedges + quarantine churn)
        let deadline_us = if g.usize(0, 1) == 0 { 0 } else { g.u64(1_000, 3_000) };
        let queue_cap = if g.usize(0, 1) == 0 {
            usize::MAX
        } else {
            g.usize(4, 8)
        };
        let delay_ms = g.u64(4, 8);
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                policy: Policy::LeastQueued,
                queue_cap,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(10),
                },
                tail: Some(tail(2, 0.5)),
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            move |i| {
                let e: Box<dyn InferenceEngine> =
                    Box::new(MockEngine::new(16, 3, 10, 8));
                Ok(if i == 0 {
                    Box::new(SlowAfter::new(
                        e,
                        0,
                        Duration::from_millis(delay_ms),
                        Duration::ZERO,
                        11,
                    ))
                } else {
                    e
                })
            },
        )
        .unwrap();
        let cfg = LoadGenConfig {
            n_requests: 60,
            arrival: Arrival::ClosedLoop { concurrency: 12 },
            seed: g.u64(0, 1 << 40),
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us,
        };
        let rep = loadgen::run(&c, &profile("kdd").unwrap(), &cfg).unwrap();
        prop_assert_eq!(rep.sent, 60);
        prop_assert_eq!(
            rep.accepted,
            rep.completed + rep.expired + rep.lost,
            "client accounting (deadline {deadline_us}µs cap {queue_cap})"
        );
        prop_assert_eq!(rep.lost, 0, "every accepted request must answer");
        let snap = c.metrics.snapshot();
        prop_assert_eq!(
            snap.requests,
            snap.responses + snap.rejected + snap.shed + snap.failed
                + snap.expired,
            "extended conservation ledger, exactly"
        );
        prop_assert!(snap.ledger_ok());
        c.shutdown();
        Ok(())
    });
}

#[test]
fn deadline_knob_never_perturbs_the_schedule() {
    qcheck(20, |g| {
        let p = profile(*g.choose(&ALL_PROFILES)).unwrap();
        let base = LoadGenConfig {
            n_requests: g.usize(5, 30),
            arrival: if g.usize(0, 1) == 0 {
                Arrival::OpenLoop {
                    rps: g.f64(1_000.0, 50_000.0),
                }
            } else {
                Arrival::ClosedLoop {
                    concurrency: g.usize(1, 16),
                }
            },
            seed: g.u64(0, 1 << 40),
            coverage: g.f64(0.3, 1.0),
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let d = g.u64(1, 1 << 33);
        let with = LoadGenConfig {
            deadline_us: d,
            ..base.clone()
        };
        let a = build_schedule(&p, &base).unwrap();
        let b = build_schedule(&p, &with).unwrap();
        prop_assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            // content and timing are bit-identical — the deadline is a
            // pure annotation, never an RNG draw
            prop_assert_eq!(x.k, y.k);
            prop_assert_eq!(x.at_ns, y.at_ns);
            prop_assert!(x.dense == y.dense && x.fields == y.fields && x.ids == y.ids);
            prop_assert_eq!(x.deadline_us, 0u64);
            prop_assert_eq!(y.deadline_us, d);
            // and off the wire entirely when unset
            let line = x.to_wire().to_line();
            prop_assert!(
                !line.contains("deadline_us"),
                "deadline 0 must not appear on the wire: {line}"
            );
            prop_assert!(y.to_wire().to_line().contains("\"deadline_us\":"));
        }
        Ok(())
    });
}

#[test]
fn quarantine_blocks_normal_traffic_until_a_probe_succeeds() {
    qcheck(15, |g| {
        let workers = g.usize(2, 5);
        let victim = g.usize(0, workers - 1);
        let policy = *g.choose(&[Policy::LeastQueued, Policy::ShardAffinity]);
        // probe_interval MAX ⇒ only ticket 0 is a probe: exactly one
        // request may reach the quarantined worker, however many flow
        let h = Arc::new(FleetHealth::new(
            workers,
            &TailConfig {
                strikes: 1,
                probe_interval: u64::MAX,
                ..TailConfig::default()
            },
        ));
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..workers).map(|_| mpsc::channel::<usize>()).unzip();
        let r = Router::new(txs, policy).with_health(h.clone());
        for w in 0..workers {
            if w != victim {
                h.record(w, 1_000_000); // 1ms peer baseline
            }
        }
        h.record(victim, 40_000_000); // strike → probation
        h.record(victim, 40_000_000); // strike → quarantined
        prop_assert_eq!(h.state(victim), BreakerState::Quarantined);
        let n = g.usize(10, 60);
        for i in 0..n {
            prop_assert!(r.route_bounded(&[], usize::MAX, i).is_ok());
        }
        let to_victim = rxs[victim].try_iter().count();
        prop_assert_eq!(
            to_victim,
            1,
            "only the single trickle probe may reach quarantine \
             ({workers} workers, victim {victim}, {policy:?})"
        );
        let elsewhere: usize = rxs
            .iter()
            .enumerate()
            .filter(|(w, _)| *w != victim)
            .map(|(_, rx)| rx.try_iter().count())
            .sum();
        prop_assert_eq!(elsewhere, n - 1, "reroute conserves requests");
        // the probe comes back fast → probation; healthy peers still
        // outrank it, so it keeps receiving nothing...
        h.record(victim, 1_000_000);
        prop_assert_eq!(h.state(victim), BreakerState::Probation);
        for i in 0..10 {
            prop_assert!(r.route_bounded(&[], usize::MAX, i).is_ok());
        }
        prop_assert_eq!(
            rxs[victim].try_iter().count(),
            0,
            "probation ranks after healthy"
        );
        // ...until the healthy peers are gone, and the recovered worker
        // serves again (it is no longer walled off)
        for w in 0..workers {
            if w != victim {
                r.slot_handle(w).close();
            }
        }
        for i in 0..10 {
            prop_assert!(r.route_bounded(&[], usize::MAX, i).is_ok());
        }
        prop_assert_eq!(rxs[victim].try_iter().count(), 10);
        Ok(())
    });
}

/// Random partial-coverage batch: each request touches a shuffled,
/// sorted subset of the tables with in-range ids.
fn random_batch(g: &mut Gen, p: &Profile) -> Vec<(Vec<u32>, Vec<i32>)> {
    let nf = p.cards.len();
    (0..g.usize(2, 8))
        .map(|_| {
            let keep = g.usize(1, nf);
            let mut fields: Vec<u32> = (0..nf as u32).collect();
            g.rng().shuffle(&mut fields);
            fields.truncate(keep);
            fields.sort_unstable();
            let ids: Vec<i32> = fields
                .iter()
                .map(|&f| g.usize(0, p.cards[f as usize] - 1) as i32)
                .collect();
            (fields, ids)
        })
        .collect()
}

#[test]
fn degraded_gathers_zero_fill_exactly_the_remote_rows() {
    const POLICIES: [ShardPolicy; 3] = [
        ShardPolicy::RoundRobinTables,
        ShardPolicy::CapacityBalanced,
        ShardPolicy::HotReplicated,
    ];
    qcheck(12, |g| {
        let p = profile(*g.choose(&ALL_PROFILES)).unwrap();
        let n_shards = g.usize(2, 4);
        let policy = *g.choose(&POLICIES);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        let store = ShardedStore::random(&p, 8, g.u64(0, 1 << 40), map);
        let local = g.usize(0, n_shards - 1);
        let batch = random_batch(g, &p);
        let reqs =
            || batch.iter().map(|(f, i)| (f.as_slice(), i.as_slice()));
        let mut gat = BatchGatherer::new(&store.cards);
        let mut normal = Vec::new();
        let st_n =
            gat.gather_batch_mode(&store.map, &store, None, local, reqs(), &mut normal, false);
        let mut gat = BatchGatherer::new(&store.cards);
        let mut degraded = Vec::new();
        let st_d =
            gat.gather_batch_mode(&store.map, &store, None, local, reqs(), &mut degraded, true);
        prop_assert!(st_d.balanced(), "degraded ledger: {st_d:?}");
        prop_assert_eq!(st_d.remote, 0, "brownout never fetches cross-shard");
        prop_assert_eq!(st_d.requested, st_n.requested);
        prop_assert_eq!(st_d.local, st_n.local, "local service unchanged");
        // every output slot is either bit-identical to the normal
        // gather or zero-filled, and the zero-filled count is exactly
        // the degraded leg (random rows are never all-zero)
        let d = store.d_emb;
        prop_assert_eq!(normal.len(), degraded.len());
        let mut zeroed = 0usize;
        for (nb, db) in normal.chunks(d).zip(degraded.chunks(d)) {
            if db == nb {
                continue;
            }
            prop_assert!(
                db.iter().all(|&v| v == 0.0),
                "a diverging slot must be the zero fill"
            );
            zeroed += 1;
        }
        prop_assert_eq!(
            zeroed,
            st_d.degraded,
            "zero fills ≠ degraded leg ({policy:?}, local {local})"
        );
        Ok(())
    });
}
