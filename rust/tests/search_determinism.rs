//! Determinism layer for the parallel co-search engine (S20): worker
//! count and the evaluation cache must not change a single bit of the
//! search result. Every assertion compares `f64::to_bits` — "close
//! enough" is not equality here, because a reordered floating-point
//! reduction is exactly the bug this suite exists to catch.

use autorac::nas::{ParallelSearch, SearchConfig, Surrogate};

fn cfg(seed: u64, workers: usize, cache: bool) -> SearchConfig {
    SearchConfig {
        generations: 6,
        population: 10,
        children_per_gen: 4,
        sample_size: 3,
        sim_requests: 12,
        seed,
        workers,
        cache,
        ..SearchConfig::default()
    }
}

/// Bit-level fingerprint of one full run: best/mean criterion traces,
/// the winning genome, and the objective vector of the archive's knee.
#[derive(Debug, PartialEq, Eq)]
struct Fingerprint {
    best_bits: Vec<u64>,
    mean_bits: Vec<u64>,
    evaluations: usize,
    best_genome_hash: u64,
    knee_bits: Vec<u64>,
}

fn run(seed: u64, workers: usize, cache: bool) -> Fingerprint {
    let mut s = ParallelSearch::new(cfg(seed, workers, cache), Surrogate::prior())
        .expect("engine constructs offline");
    let best = s.run().expect("search completes");
    Fingerprint {
        best_bits: s.trace.best_criterion.iter().map(|c| c.to_bits()).collect(),
        mean_bits: s.trace.mean_criterion.iter().map(|c| c.to_bits()).collect(),
        evaluations: s.trace.evaluations,
        best_genome_hash: best.genome.hash(),
        knee_bits: s
            .archive
            .knee()
            .expect("non-empty archive")
            .objectives
            .iter()
            .map(|o| o.to_bits())
            .collect(),
    }
}

#[test]
fn workers_1_and_8_are_bit_identical_across_seeds() {
    for seed in [1u64, 2, 3, 4, 5] {
        let serial = run(seed, 1, true);
        let parallel = run(seed, 8, true);
        assert_eq!(serial, parallel, "seed {seed}: worker count changed the result");
    }
}

#[test]
fn cache_on_and_off_are_equivalent() {
    for seed in [11u64, 12] {
        let cached = run(seed, 4, true);
        let uncached = run(seed, 4, false);
        assert_eq!(cached, uncached, "seed {seed}: the cache changed the result");
    }
}

#[test]
fn same_seed_repeats_and_seeds_differ() {
    assert_eq!(run(21, 2, true), run(21, 2, true), "re-run diverged");
    assert_ne!(
        run(21, 1, true).best_genome_hash,
        run(22, 1, true).best_genome_hash,
        "different seeds found the identical genome"
    );
}

#[test]
fn traces_record_one_entry_per_generation() {
    let f = run(31, 3, true);
    // init + 6 generations
    assert_eq!(f.best_bits.len(), 7);
    assert_eq!(f.mean_bits.len(), 7);
    // population + 6 × children logical evaluations, cache hits included
    assert_eq!(f.evaluations, 10 + 6 * 4);
}
