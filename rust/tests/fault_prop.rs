//! Device-fault tolerance properties (S34): stuck-at injection, ABFT
//! checksum detection, and spare-tile repair on the batched crossbar
//! kernel and the serving engine above it.
//!
//! The contract under test, in layers:
//!   1. Clean hardware: ABFT never fires (zero false positives) and the
//!      verify path changes no output bit — every feasible config,
//!      thread count, and batch size; likewise a build with spare slots
//!      reserved and a rate-0 fault spec installed.
//!   2. Single cell fault: the checksum flags the tile IF AND ONLY IF
//!      some output of that batch is wrong (δ_out = ±2^shift·x[j,row]
//!      on exactly one column — the same term is missing from the tile
//!      checksum, so one fault can never alias), and one pristine spare
//!      restores bit-identity.
//!   3. Random stuck-at faults: every flagged tile is ground-truth
//!      corrupt (zero false positives under faults, any rate); in the
//!      single-flip-dominated regime wrong outputs imply a flag; and
//!      whenever detect→repair→re-run drives the corrupt set empty the
//!      outputs are bit-identical to a fault-free build. (Completeness
//!      is NOT asserted for dense multi-fault tiles: two flips in the
//!      same (block, row) on different columns cancel in the single
//!      checksum column — a known single-column-ABFT limitation,
//!      documented in DESIGN.md §7.13.)
//!   4. Faulted kernel == faulted reference: the packed-plane injection
//!      and the `ProgrammedXbar` plane-stack injection are the same
//!      fault model, differentially (pre-repair, ABFT off).
//!   5. Drift: the fuse fires exactly once after N MVM batches; before
//!      it the device serves bit-identically and flag-free.
//!   6. `PimEngine`: drained `FaultCounts` agree with what the scores
//!      say — zero corrupt rows ⟹ bit-identical serving.

use autorac::coordinator::{InferenceEngine, PimEngine};
use autorac::nas::autorac_best;
use autorac::pim::fault::FaultGeom;
use autorac::pim::{
    BatchedXbar, FaultMap, FaultSpec, MatI32, PimConfig, ProgrammedXbar,
    XbarActivity, XbarOptions, XbarScratch,
};
use autorac::util::qcheck::qcheck;
use autorac::util::rng::Rng;
use autorac::{prop_assert, prop_assert_eq};

/// Batch sizes the properties draw from (serving floor / ragged /
/// default compiled batch) — same grid as `xbar_kernel.rs`.
const BATCHES: [usize; 3] = [1, 7, 32];

fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
    let mut m = MatI32::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
        }
    }
    m
}

/// Offset-binary inputs, every value in `[lo, 2^x_bits)`.
fn random_inputs(rng: &mut Rng, cfg: &PimConfig, k: usize, b: usize, lo: u64) -> Vec<i32> {
    let span = (1u64 << cfg.x_bits) - lo;
    (0..b * k).map(|_| (lo + rng.below(span)) as i32).collect()
}

/// The bank-style detect→repair→re-run loop: returns `true` when the
/// batch converged to a flag-clean pass, `false` when repair ran out of
/// good spares (degraded mode). Mirrors `PimBank::forward_batch`.
fn repair_loop(
    bx: &mut BatchedXbar,
    xs: &[i32],
    b: usize,
    out: &mut [i64],
    scratch: &mut XbarScratch,
) -> bool {
    bx.mvm_batch(xs, b, out, scratch);
    loop {
        if scratch.flagged.is_empty() {
            return true;
        }
        let flagged = scratch.flagged.clone();
        let mut repaired = false;
        for &t in &flagged {
            repaired |= bx.repair_tile(t as usize);
        }
        if !repaired {
            return false;
        }
        bx.mvm_batch(xs, b, out, scratch);
    }
}

// ---------------------------------------------------------------------------
// 1. Zero false positives on clean hardware
// ---------------------------------------------------------------------------

#[test]
fn clean_hardware_never_flags_on_any_feasible_config() {
    // deterministic exhaustive floor: every feasible config × threads
    // {1, 3} × batch {1, 7, 32}, ABFT on, outputs == reference
    let mut rng = Rng::new(0xFA17_5EED);
    for cfg in PimConfig::enumerate_feasible() {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let wq = random_mat(&mut rng, 2 * cfg.xbar + 3, 9, wmax);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert!(bx.abft_on(), "{cfg:?}: feasible config must verify");
        for b in BATCHES {
            let xs = random_inputs(&mut rng, &cfg, bx.k, b, 0);
            let mut want = Vec::with_capacity(b * bx.n);
            let mut want_act = XbarActivity::default();
            for j in 0..b {
                want.extend(
                    refx.mvm_raw(&xs[j * bx.k..(j + 1) * bx.k], &mut want_act),
                );
            }
            for threads in [1usize, 3] {
                let mut out = vec![0i64; b * bx.n];
                let mut scratch = XbarScratch::with_threads(threads);
                bx.mvm_batch(&xs, b, &mut out, &mut scratch);
                assert_eq!(out, want, "{cfg:?} b={b} threads={threads}");
                assert_eq!(
                    scratch.activity, want_act,
                    "{cfg:?} b={b} threads={threads}"
                );
                assert!(
                    scratch.flagged.is_empty()
                        && scratch.activity.faulty_tiles == 0,
                    "ABFT false positive on clean hardware: {cfg:?} b={b} \
                     threads={threads}"
                );
            }
        }
    }
}

#[test]
fn clean_builds_with_spares_and_rate_zero_are_bit_identical() {
    // fault-free path unchanged: spare slots reserved and a rate-0
    // fault spec installed must not move a single output bit
    let configs = PimConfig::enumerate_feasible();
    qcheck(24, |g| {
        let cfg = *g.choose(&configs);
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let rows = g.usize(1, 2 * cfg.xbar + 5);
        let cols = g.usize(1, 16);
        let wq = random_mat(g.rng(), rows, cols, wmax);
        let plain = BatchedXbar::program(&wq, cfg);
        let opts = XbarOptions {
            spare_tiles: g.usize(1, 3),
            fault: Some(FaultSpec::cells(0.0, g.rng().below(u64::MAX))),
            ..XbarOptions::default()
        };
        let guarded = BatchedXbar::program_with(&wq, cfg, &opts);
        prop_assert_eq!(guarded.offset_correction(), plain.offset_correction());
        prop_assert!(guarded.corrupt_logical_tiles().is_empty());
        let b = *g.choose(&BATCHES);
        let xs = random_inputs(g.rng(), &cfg, plain.k, b, 0);
        let threads = if g.bool() { 1 } else { 3 };
        let mut o1 = vec![0i64; b * plain.n];
        let mut o2 = vec![0i64; b * plain.n];
        let mut s1 = XbarScratch::with_threads(threads);
        let mut s2 = XbarScratch::with_threads(threads);
        plain.mvm_batch(&xs, b, &mut o1, &mut s1);
        guarded.mvm_batch(&xs, b, &mut o2, &mut s2);
        prop_assert_eq!(&o1, &o2);
        prop_assert_eq!(s1.activity, s2.activity);
        prop_assert!(s2.flagged.is_empty());
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Single-fault iff: flag ⟺ wrong output
// ---------------------------------------------------------------------------

#[test]
fn single_cell_fault_flags_iff_an_output_is_wrong() {
    let configs = PimConfig::enumerate_feasible();
    qcheck(40, |g| {
        let cfg = *g.choose(&configs);
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let rows = g.usize(1, 2 * cfg.xbar + 5);
        let cols = g.usize(1, 12);
        let wq = random_mat(g.rng(), rows, cols, wmax);
        let clean = BatchedXbar::program(&wq, cfg);
        let opts = XbarOptions {
            spare_tiles: 1,
            ..XbarOptions::default()
        };
        let mut faulty = BatchedXbar::program_with(&wq, cfg, &opts);
        // one flipped packed bit: a guaranteed single-cell corruption
        let t = g.usize(0, faulty.tiles() - 1);
        let blocks = cfg.n_planes() * 2 * cfg.cell_bits;
        let block = g.usize(0, blocks - 1);
        let col = g.usize(0, cols - 1);
        let row = g.usize(0, cfg.xbar - 1);
        faulty.corrupt_bit(t, block, col, row / 64, row % 64);

        let b = *g.choose(&BATCHES);
        // lo = 0: unexcited rows (x == 0 in every batch row) are legal
        // and must produce NEITHER a flag NOR a wrong output
        let xs = random_inputs(g.rng(), &cfg, clean.k, b, 0);
        let mut want = vec![0i64; b * clean.n];
        let mut out = vec![0i64; b * clean.n];
        let mut sc = XbarScratch::default();
        let mut sf = XbarScratch::default();
        clean.mvm_batch(&xs, b, &mut want, &mut sc);
        faulty.mvm_batch(&xs, b, &mut out, &mut sf);
        let differs = out != want;
        prop_assert_eq!(!sf.flagged.is_empty(), differs);
        if differs {
            prop_assert_eq!(&sf.flagged, &vec![t as u32]);
            prop_assert!(sf.activity.faulty_tiles > 0);
        }
        // the pristine spare repairs the tile back to bit-identity
        // whether or not this batch happened to excite the fault
        prop_assert!(faulty.repair_tile(t));
        let mut sr = XbarScratch::default();
        faulty.mvm_batch(&xs, b, &mut out, &mut sr);
        prop_assert_eq!(&out, &want);
        prop_assert!(sr.flagged.is_empty());
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. Random stuck-at faults: detection coverage + repair fidelity
// ---------------------------------------------------------------------------

#[test]
fn random_faults_flag_only_corrupt_tiles_and_repair_restores_bits() {
    let configs = PimConfig::enumerate_feasible();
    qcheck(36, |g| {
        let cfg = *g.choose(&configs);
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let rows = g.usize(cfg.xbar / 2, 2 * cfg.xbar + 5);
        let cols = g.usize(2, 12);
        let wq = random_mat(g.rng(), rows, cols, wmax);
        let clean = BatchedXbar::program(&wq, cfg);
        let rate = *g.choose(&[1e-5f64, 1e-4, 1e-3]);
        let opts = XbarOptions {
            spare_tiles: g.usize(0, 4),
            fault: Some(FaultSpec::cells(rate, g.rng().below(u64::MAX))),
            ..XbarOptions::default()
        };
        let mut faulty = BatchedXbar::program_with(&wq, cfg, &opts);
        let corrupt = faulty.corrupt_logical_tiles();

        let b = *g.choose(&BATCHES);
        let xs = random_inputs(g.rng(), &cfg, clean.k, b, 0);
        let mut want = vec![0i64; b * clean.n];
        let mut sc = XbarScratch::default();
        clean.mvm_batch(&xs, b, &mut want, &mut sc);

        // first pass, pre-repair: flags ⊆ ground-truth corrupt tiles —
        // zero false positives under faults, at every rate
        let mut out = vec![0i64; b * clean.n];
        let mut sf = XbarScratch::default();
        faulty.mvm_batch(&xs, b, &mut out, &mut sf);
        for &t in &sf.flagged {
            prop_assert!(
                corrupt.contains(&(t as usize)),
                "flagged tile {} is not ground-truth corrupt",
                t
            );
        }
        // completeness only in the single-flip-dominated regime: at
        // 1e-5 a second flip in the same tile is vanishingly rare, so
        // the single-fault iff theorem applies per tile. (Denser tiles
        // can alias in the checksum sum — see the module doc.)
        if rate == 1e-5 && out != want {
            prop_assert!(
                !sf.flagged.is_empty(),
                "wrong outputs escaped detection (rate {})",
                rate
            );
        }

        // detect→repair→re-run: when the corrupt set is driven empty,
        // every mapped slot is verified-clean and bit-identity is a
        // structural guarantee, at every rate
        let converged = repair_loop(&mut faulty, &xs, b, &mut out, &mut sf);
        if converged {
            prop_assert!(sf.flagged.is_empty());
            if faulty.corrupt_logical_tiles().is_empty() {
                prop_assert_eq!(&out, &want);
            }
        } else {
            // degraded: a flag still raised and no repair succeeded
            prop_assert!(!sf.flagged.is_empty());
            prop_assert_eq!(faulty.spares_free(), 0);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 4. Differential fault parity: packed kernel == plane-stack reference
// ---------------------------------------------------------------------------

#[test]
fn faulted_kernel_matches_faulted_reference_bit_for_bit() {
    let configs = PimConfig::enumerate_feasible();
    qcheck(32, |g| {
        let cfg = *g.choose(&configs);
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let rows = g.usize(1, 2 * cfg.xbar + 5);
        let cols = g.usize(1, 12);
        let wq = random_mat(g.rng(), rows, cols, wmax);
        let spec = FaultSpec {
            rate: *g.choose(&[1e-4f64, 1e-3, 5e-3]),
            col_rate: *g.choose(&[0.0f64, 0.02]),
            seed: g.rng().below(u64::MAX),
            ..FaultSpec::default()
        };
        // ABFT off: chk_blocks = 0, so the kernel's fault geometry is
        // reconstructible here and the map it drew is reproducible
        let opts = XbarOptions {
            abft: false,
            fault: Some(spec.clone()),
            label: "par".to_string(),
            ..XbarOptions::default()
        };
        let bx = BatchedXbar::program_with(&wq, cfg, &opts);
        let k_pad = rows.div_ceil(cfg.xbar) * cfg.xbar;
        let rem = cfg.xbar % 64;
        let geom = FaultGeom {
            blocks: cfg.n_planes() * 2 * cfg.cell_bits,
            chk_blocks: 0,
            n_tiles_phys: k_pad / cfg.xbar,
            cols,
            n_words: cfg.xbar.div_ceil(64),
            last_mask: if rem == 0 { u64::MAX } else { (1u64 << rem) - 1 },
        };
        let map = FaultMap::build(&spec, "par", &geom);
        let mut refx = ProgrammedXbar::program(&wq, cfg);
        refx.apply_faults(&map);

        let b = *g.choose(&BATCHES);
        let xs = random_inputs(g.rng(), &cfg, bx.k, b, 0);
        let mut want = Vec::with_capacity(b * bx.n);
        let mut want_act = XbarActivity::default();
        for j in 0..b {
            want.extend(
                refx.mvm_raw(&xs[j * bx.k..(j + 1) * bx.k], &mut want_act),
            );
        }
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::with_threads(if g.bool() { 1 } else { 3 });
        bx.mvm_batch(&xs, b, &mut out, &mut scratch);
        prop_assert_eq!(&out, &want);
        prop_assert_eq!(scratch.activity, want_act);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 5. Drift: fuse fires once, pre-fuse service is pristine
// ---------------------------------------------------------------------------

#[test]
fn drift_fuse_fires_once_and_corruption_is_flagged() {
    qcheck(12, |g| {
        let cfg = PimConfig::default();
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        let wq = random_mat(g.rng(), 2 * cfg.xbar + 5, 16, wmax);
        let clean = BatchedXbar::program(&wq, cfg);
        let spec = FaultSpec {
            rate: 0.0,
            drift_after: Some(2),
            // sparse wave: single-flip-dominated, so any excited
            // corruption surfaces as a flag or an output change — the
            // invisible-cancellation window is negligible here
            drift_rate: 2e-5,
            seed: g.rng().below(u64::MAX),
            ..FaultSpec::default()
        };
        let opts = XbarOptions {
            spare_tiles: 2,
            fault: Some(spec),
            ..XbarOptions::default()
        };
        let mut faulty = BatchedXbar::program_with(&wq, cfg, &opts);
        prop_assert!(faulty.corrupt_logical_tiles().is_empty());

        let b = *g.choose(&BATCHES);
        // lo = 1: every row excited, so a drifted DATA bit in a mapped
        // slot must change an output (and a drifted CHK bit must
        // mismatch the recomputed sum)
        let xs = random_inputs(g.rng(), &cfg, clean.k, b, 1);
        let mut want = vec![0i64; b * clean.n];
        let mut sc = XbarScratch::default();
        clean.mvm_batch(&xs, b, &mut want, &mut sc);

        let mut out = vec![0i64; b * clean.n];
        let mut sf = XbarScratch::default();
        // two pristine MVM batches before the fuse crosses
        for _ in 0..2 {
            faulty.mvm_batch(&xs, b, &mut out, &mut sf);
            prop_assert!(sf.flagged.is_empty());
            prop_assert_eq!(&out, &want);
            faulty.tick_drift();
        }
        // the fuse fired exactly once; further ticks are no-ops
        prop_assert!(!faulty.tick_drift());
        let corrupted = !faulty.corrupt_logical_tiles().is_empty();
        faulty.mvm_batch(&xs, b, &mut out, &mut sf);
        if corrupted {
            prop_assert!(
                !sf.flagged.is_empty() || out != want,
                "a mapped tile drifted invisibly: no flag, no output change"
            );
        } else {
            // wave missed every mapped slot (or changed no stored bit):
            // service stays pristine
            prop_assert!(sf.flagged.is_empty());
            prop_assert_eq!(&out, &want);
        }
        // repair when possible (drift also hits spares; program-verify
        // burns bad ones — exhaustion degrades, and that is the contract)
        let converged = repair_loop(&mut faulty, &xs, b, &mut out, &mut sf);
        if converged && faulty.corrupt_logical_tiles().is_empty() {
            prop_assert_eq!(&out, &want);
        }
        if !converged {
            prop_assert_eq!(faulty.spares_free(), 0);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 6. Engine level: drained counts agree with the scores
// ---------------------------------------------------------------------------

#[test]
fn engine_fault_counts_agree_with_score_fidelity() {
    let genome = autorac_best("criteo");
    let (nd, ns, d) = (13usize, 26usize, 16usize);
    let batch = 8usize;
    qcheck(6, |g| {
        let opts = XbarOptions {
            spare_tiles: 4,
            fault: Some(FaultSpec::cells(
                *g.choose(&[1e-5f64, 1e-4]),
                g.rng().below(u64::MAX),
            )),
            ..XbarOptions::default()
        };
        let mut clean = PimEngine::new(&genome, batch, nd, ns, d, 42).unwrap();
        let mut faulty =
            PimEngine::new_with(&genome, batch, nd, ns, d, 42, &opts).unwrap();
        let b = g.usize(1, batch);
        let dense: Vec<f32> =
            (0..b * nd).map(|_| g.rng().normal() as f32).collect();
        let sparse: Vec<f32> = (0..b * ns * d)
            .map(|_| (g.rng().normal() * 0.05) as f32)
            .collect();
        let want = clean.infer_batch(&dense, &sparse, b).unwrap();
        let got = faulty.infer_batch(&dense, &sparse, b).unwrap();
        let fc = faulty.take_fault_counts();
        let identical = want
            .iter()
            .zip(&got)
            .all(|(a, c)| a.to_bits() == c.to_bits());
        if fc.corrupt_rows == 0 {
            // everything detected was repaired (or nothing was hit):
            // serving fidelity must be exact
            prop_assert!(
                identical,
                "no corrupt rows booked but scores diverged \
                 (faulty {} repaired {})",
                fc.tiles_faulty,
                fc.tiles_repaired
            );
        } else {
            // degraded mode is always accompanied by a detection event
            prop_assert!(fc.tiles_faulty > 0);
        }
        // a drained engine books nothing more while no batch is served
        let fc2 = faulty.take_fault_counts();
        prop_assert!(!fc2.any());
        Ok(())
    });
}
