//! Coordinator integration: multi-worker serving with mock engines under
//! concurrent load — shard-affinity conservation, admission-control
//! accounting under overload, drain-on-shutdown, per-client FIFO — plus
//! (artifact-gated) a PJRT-backed smoke run.

use autorac::coordinator::loadgen::{self, Arrival, LoadGenConfig};
use autorac::coordinator::{
    Admission, AdmissionPolicy, BatcherConfig, Coordinator,
    CoordinatorConfig, CrashAfter, InferenceEngine, MockEngine, NetClient,
    NetServer, NetServerConfig, PjrtEngine, Policy, Request, ServingStore,
    WireResponse,
};
use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::embeddings::{EmbeddingStore, ShardMap, ShardPolicy, ShardedStore};
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use autorac::util::json_lazy::WireRequest;
use std::io::Write;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn store() -> Arc<EmbeddingStore> {
    Arc::new(EmbeddingStore::random(&profile("criteo").unwrap(), 32, 7))
}

fn sharded_store(n_shards: usize) -> Arc<ShardedStore> {
    let p = profile("criteo").unwrap();
    let map = ShardMap::for_profile(&p, n_shards, ShardPolicy::CapacityBalanced);
    Arc::new(ShardedStore::random(&p, 16, 7, map))
}

#[test]
fn concurrent_load_from_many_producers() {
    let coord = Arc::new(
        Coordinator::start(
            CoordinatorConfig {
                n_workers: 3,
                batcher: BatcherConfig {
                    max_batch: 16,
                    max_wait: Duration::from_micros(300),
                },
                ..Default::default()
            },
            store(),
            |_| Ok(Box::new(MockEngine::new(16, 13, 26, 32))),
        )
        .unwrap(),
    );
    let n_producers = 4u64;
    let per = 100u64;
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for p in 0..n_producers {
        let coord = coord.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let mut gen =
                Generator::new(profile("criteo").unwrap(), DEFAULT_SEED + p);
            for i in 0..per {
                let (dense, ids) = gen.features(i as usize);
                coord
                    .submit(Request::full(
                        p * 1000 + i,
                        dense,
                        ids.iter().map(|&x| x as i32).collect(),
                        tx.clone(),
                    ))
                    .unwrap();
            }
        }));
    }
    drop(tx);
    for h in handles {
        h.join().unwrap();
    }
    let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids.len(), (n_producers * per) as usize);
    ids.dedup();
    assert_eq!(
        ids.len(),
        (n_producers * per) as usize,
        "duplicate responses"
    );
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, n_producers * per);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

/// ShardAffinity conservation: every accepted request lands on exactly
/// one queue and produces exactly one response, even when requests
/// touch arbitrary table subsets.
#[test]
fn shard_affinity_conserves_requests() {
    let sharded = sharded_store(4);
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: 4,
            policy: Policy::ShardAffinity,
            ..Default::default()
        },
        ServingStore::Sharded(sharded),
        |_| Ok(Box::new(MockEngine::new(16, 13, 26, 16))),
    )
    .unwrap();
    let p = profile("criteo").unwrap();
    let nf = p.n_sparse();
    let mut gen = Generator::new(p, DEFAULT_SEED);
    let mut rng = autorac::util::rng::Rng::new(99);
    let (tx, rx) = mpsc::channel();
    let n = 300u64;
    for id in 0..n {
        let (dense, ids_full) = gen.features(id as usize);
        // random subset of 1..nf tables
        let keep = rng.range(1, nf);
        let mut fields: Vec<u32> = (0..nf as u32).collect();
        rng.shuffle(&mut fields);
        fields.truncate(keep);
        fields.sort_unstable();
        let ids = fields
            .iter()
            .map(|&f| ids_full[f as usize] as i32)
            .collect();
        let adm = coord
            .submit(Request::partial(id, dense, fields, ids, tx.clone()))
            .unwrap();
        // unbounded queues: ShardAffinity must accept onto exactly one
        // worker (the routed index is in range)
        match adm {
            Admission::Enqueued(w) => assert!(w < 4, "worker {w}"),
            Admission::Rejected => panic!("unbounded queue rejected"),
            Admission::DeadlineInfeasible => panic!("no deadline was set"),
        }
    }
    drop(tx);
    let mut got: Vec<u64> = rx.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, (0..n).collect::<Vec<_>>(), "lost or duplicated ids");
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.requests, n);
    assert_eq!(snap.responses, n);
    assert_eq!(snap.rejected + snap.shed, 0);
    // sharded gather accounting covered every requested row
    assert!(snap.local_rows + snap.remote_rows > 0);
    coord.shutdown();
}

/// Under overload with RejectNew, the books balance exactly:
/// requests == responses + rejected, and the client sees precisely the
/// accepted subset.
#[test]
fn reject_policy_counts_add_up_under_overload() {
    let block = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let block2 = block.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_workers: 2,
            queue_cap: 6,
            admission: AdmissionPolicy::RejectNew,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::ZERO,
            },
            ..Default::default()
        },
        store(),
        move |_| {
            // gated MockEngine: the worker blocks in infer_batch until
            // released, so queue buildup (and rejection) is deterministic
            let mut e = MockEngine::new(4, 13, 26, 32);
            e.gate = Some(block2.clone());
            Ok(Box::new(e))
        },
    )
    .unwrap();
    let (tx, rx) = mpsc::channel();
    let n = 200u64;
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for id in 0..n {
        match coord
            .submit(Request::full(id, vec![0.0; 13], vec![1; 26], tx.clone()))
            .unwrap()
        {
            Admission::Enqueued(_) => accepted += 1,
            Admission::Rejected => rejected += 1,
            Admission::DeadlineInfeasible => panic!("no deadline was set"),
        }
    }
    assert!(rejected > 0, "200-burst into cap-6 queues must reject");
    block.store(true, std::sync::atomic::Ordering::Relaxed);
    drop(tx);
    let responses = rx.iter().count() as u64;
    let snap = coord.metrics.snapshot();
    assert_eq!(responses, accepted);
    assert_eq!(snap.requests, n);
    assert_eq!(snap.rejected, rejected);
    assert_eq!(
        snap.responses + snap.rejected,
        n,
        "admission accounting must balance"
    );
    coord.shutdown();
}

/// Shutdown after submission drains every in-flight request before the
/// workers exit — no request is stranded on a queue.
#[test]
fn clean_shutdown_drains_in_flight_requests() {
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: 3,
            policy: Policy::ShardAffinity,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            ..Default::default()
        },
        ServingStore::Sharded(sharded_store(3)),
        |_| {
            let mut e = MockEngine::new(8, 13, 26, 16);
            e.delay = Duration::from_micros(200); // keep work in flight
            Ok(Box::new(e))
        },
    )
    .unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..90u64 {
        coord
            .submit(Request::full(id, vec![0.0; 13], vec![2; 26], tx.clone()))
            .unwrap();
    }
    drop(tx);
    // shutdown immediately: queues still hold most of the 90
    coord.shutdown();
    assert_eq!(rx.iter().count(), 90, "shutdown must drain, not drop");
}

/// Responses are FIFO per client. Two shapes: (a) a single worker
/// preserves submission order end-to-end; (b) with ShardAffinity, a
/// client whose requests all touch one shard's tables is sticky-routed
/// to that worker, so its stream stays FIFO even with 3 workers.
#[test]
fn response_ordering_is_per_client_fifo() {
    // (a) single worker
    let c = Coordinator::start(
        CoordinatorConfig::default(),
        store(),
        |_| Ok(Box::new(MockEngine::new(8, 13, 26, 32))),
    )
    .unwrap();
    let (tx_a, rx_a) = mpsc::channel();
    let (tx_b, rx_b) = mpsc::channel();
    for k in 0..60u64 {
        c.submit(Request::full(k, vec![0.0; 13], vec![0; 26], tx_a.clone()))
            .unwrap();
        c.submit(Request::full(1000 + k, vec![0.0; 13], vec![0; 26], tx_b.clone()))
            .unwrap();
    }
    drop(tx_a);
    drop(tx_b);
    let a: Vec<u64> = rx_a.iter().map(|r| r.id).collect();
    let b: Vec<u64> = rx_b.iter().map(|r| r.id).collect();
    assert_eq!(a, (0..60).collect::<Vec<_>>(), "client A order broken");
    assert_eq!(
        b,
        (1000..1060).collect::<Vec<_>>(),
        "client B order broken"
    );
    c.shutdown();

    // (b) shard-affine clients on 3 workers
    let p = profile("criteo").unwrap();
    let sharded = sharded_store(3);
    let map = sharded.map.clone();
    let c = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: 3,
            policy: Policy::ShardAffinity,
            ..Default::default()
        },
        ServingStore::Sharded(sharded),
        |_| Ok(Box::new(MockEngine::new(8, 13, 26, 16))),
    )
    .unwrap();
    let mut gen = Generator::new(p, DEFAULT_SEED);
    let mut clients: Vec<(mpsc::Sender<_>, mpsc::Receiver<_>)> =
        (0..3).map(|_| mpsc::channel()).collect();
    for k in 0..120u64 {
        // client s only touches tables owned by shard s → affinity 1.0
        // for worker s, strictly less for the others → deterministic
        // single-queue routing
        let s = (k % 3) as usize;
        let fields: Vec<u32> =
            map.tables_of(s).iter().map(|&j| j as u32).collect();
        let (dense, ids_full) = gen.features(k as usize);
        let ids = fields
            .iter()
            .map(|&f| ids_full[f as usize] as i32)
            .collect();
        c.submit(Request::partial(k, dense, fields, ids, clients[s].0.clone()))
            .unwrap();
    }
    // drop the original senders so each client stream closes once its
    // in-flight requests are answered
    let receivers: Vec<mpsc::Receiver<_>> = clients
        .drain(..)
        .map(|(tx, rx)| {
            drop(tx);
            rx
        })
        .collect();
    for (s, rx) in receivers.iter().enumerate() {
        let got: Vec<u64> = rx.iter().map(|r| r.id).collect();
        let want: Vec<u64> =
            (0..120).filter(|k| (k % 3) as usize == s).collect();
        assert_eq!(got, want, "client {s} stream not FIFO");
    }
    c.shutdown();
}

fn wire_request(id: u64) -> WireRequest {
    WireRequest {
        id,
        dense: vec![0.1; 13],
        tables: (0..26).collect(),
        ids: vec![1; 26],
        deadline_us: None,
    }
}

/// Conservation over real sockets with hostile clients in the mix:
/// `requests == responses + rejected + shed + failed` must hold with a
/// client that vanishes mid-request and one that stalls on a half-sent
/// frame — and shutdown must not wait for the staller.
#[test]
fn socket_e2e_conservation_with_hostile_clients() {
    let prof = profile("criteo").unwrap();
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_workers: 2,
            ..Default::default()
        },
        Arc::new(EmbeddingStore::random(&prof, 16, 7)),
        |_| {
            let mut e = MockEngine::new(16, 13, 26, 16);
            e.delay = Duration::from_micros(100); // keep replies in flight
            Ok(Box::new(e))
        },
    )
    .unwrap();
    let srv =
        NetServer::start("127.0.0.1:0", coord, NetServerConfig::default()).unwrap();
    let addr = srv.local_addr();

    // one client vanishes right after sending a valid request — its
    // response has nowhere to go, but the ledger must still book it
    {
        let mut c = NetClient::connect(&addr).unwrap();
        c.send_line(&wire_request(1000).to_line()).unwrap();
    }
    // ... and one stalls forever on a half-sent frame (never booked)
    let mut stall = std::net::TcpStream::connect(addr).unwrap();
    stall.write_all(b"{\"id\":2000,\"dense\":[0.1").unwrap();

    // 4 well-behaved concurrent clients, 30 requests each
    let mut handles = Vec::new();
    for cidx in 0..4u64 {
        handles.push(std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            let mut got = 0u64;
            for k in 0..30u64 {
                match c.request(&wire_request(cidx * 100 + k)).unwrap() {
                    WireResponse::Ok { id, .. } => {
                        assert_eq!(id, cidx * 100 + k);
                        got += 1;
                    }
                    WireResponse::Error { msg, .. } => {
                        panic!("unbounded queues rejected: {msg}")
                    }
                }
            }
            got
        }));
    }
    let completed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(completed, 120);

    // the vanished client's request may still be in flight — wait for
    // the books to balance, then pin them
    let t0 = Instant::now();
    let snap = loop {
        let s = srv.metrics();
        if s.requests == 121
            && s.responses + s.rejected + s.shed + s.failed == s.requests
        {
            break s;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "ledger never balanced: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    // 120 well-behaved + 1 vanished; the staller's half-frame was never
    // parsed, so it must not appear anywhere
    assert_eq!(snap.requests, 121);
    assert_eq!(snap.rejected + snap.shed + snap.failed, 0);

    // drain must complete promptly with the staller still attached
    let t0 = Instant::now();
    srv.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown blocked on a stalled connection"
    );
    drop(stall);
}

/// One worker dies mid-run over real sockets: no client sees a spurious
/// total-outage error, the dead worker's queued requests are booked
/// `failed`, the ledger balances, and a client connecting AFTER the
/// crash gets every request answered by the survivors.
#[test]
fn socket_worker_crash_conserves_ledger_and_stays_available() {
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: 4,
            policy: Policy::ShardAffinity,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            ..Default::default()
        },
        ServingStore::Sharded(sharded_store(4)),
        |i| {
            let e: Box<dyn InferenceEngine> =
                Box::new(MockEngine::new(8, 13, 26, 16));
            Ok(if i == 1 {
                // dies while unloading its second batch
                Box::new(CrashAfter::after_batches(e, 1))
                    as Box<dyn InferenceEngine>
            } else {
                e
            })
        },
    )
    .unwrap();
    let srv =
        NetServer::start("127.0.0.1:0", coord, NetServerConfig::default()).unwrap();
    let addr = srv.local_addr();

    // Hammer with a fire-and-forget client. Requests that die with the
    // worker produce no response line at all, so a blocking
    // request/response loop would hang — split the stream and count
    // whatever comes back until the server closes the connection.
    let n = 200u64;
    let c = NetClient::connect(&addr).unwrap();
    let (mut ctx, mut crx) = c.split();
    let reader = std::thread::spawn(move || {
        let mut got = 0u64;
        loop {
            match crx.recv() {
                Ok(Some(WireResponse::Ok { .. })) => got += 1,
                Ok(Some(WireResponse::Error { msg, .. })) => {
                    panic!("spurious error surfaced to the client: {msg}")
                }
                Ok(None) | Err(_) => break,
            }
        }
        got
    });
    for k in 0..n {
        ctx.send_line(&wire_request(k).to_line()).unwrap();
    }
    ctx.finish();
    let got = reader.join().unwrap();
    assert!(got > 0, "survivors answered nothing");

    // Every parsed frame was booked at submit; completions plus the
    // crash losses must cover them exactly, with the crash visible.
    let t0 = Instant::now();
    let snap = loop {
        let s = srv.metrics();
        if s.requests == n
            && s.responses + s.rejected + s.shed + s.failed == s.requests
        {
            break s;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "ledger never balanced: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(snap.failed > 0, "the armed crash never fired");
    assert_eq!(snap.rejected, 0, "crash losses must not book as rejected");
    assert_eq!(snap.responses, got);
    assert_eq!(snap.live_workers(), 3, "exactly one worker died");

    // post-crash availability: a fresh client gets 100% answers from
    // the promoted survivors
    let mut c2 = NetClient::connect(&addr).unwrap();
    for k in 0..40u64 {
        match c2.request(&wire_request(10_000 + k)).unwrap() {
            WireResponse::Ok { id, .. } => assert_eq!(id, 10_000 + k),
            WireResponse::Error { msg, .. } => {
                panic!("post-crash request failed: {msg}")
            }
        }
    }
    srv.shutdown();
}

/// Seed-determinism survives the transport: the same seed produces the
/// same schedule object twice, and scoring that schedule in-process vs
/// over a loopback socket yields bit-identical id→prob maps.
#[test]
fn socket_and_in_process_runs_agree_bit_for_bit_per_seed() {
    let prof = profile("criteo").unwrap();
    let cfg = LoadGenConfig {
        n_requests: 80,
        arrival: Arrival::ClosedLoop { concurrency: 8 },
        seed: 21,
        coverage: 0.5,
        oov_frac: 0.0,
    };
    let s1 = loadgen::build_schedule(&prof, &cfg).unwrap();
    let s2 = loadgen::build_schedule(&prof, &cfg).unwrap();
    assert_eq!(s1, s2, "schedule must be a pure function of the seed");

    let mk = || {
        Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&prof, 16, 7)),
            |_| Ok(Box::new(MockEngine::new(16, 13, 26, 16))),
        )
        .unwrap()
    };

    // in-process: submit the schedule's content directly
    let coord = mk();
    let (tx, rx) = mpsc::channel();
    for sr in &s1 {
        coord
            .submit(Request::partial(
                sr.k,
                sr.dense.clone(),
                sr.fields.clone(),
                sr.ids.clone(),
                tx.clone(),
            ))
            .unwrap();
    }
    drop(tx);
    let mut inproc: Vec<(u64, u32)> =
        rx.iter().map(|r| (r.id, r.prob.to_bits())).collect();
    inproc.sort_unstable();
    coord.shutdown();

    // over the socket: the same schedule crosses the wire encoder, the
    // lazy parser, and the response encoder — bits must survive all of it
    let srv =
        NetServer::start("127.0.0.1:0", mk(), NetServerConfig::default()).unwrap();
    let mut c = NetClient::connect(&srv.local_addr()).unwrap();
    let mut wired: Vec<(u64, u32)> = Vec::new();
    for sr in &s1 {
        match c.request(&sr.to_wire()).unwrap() {
            WireResponse::Ok { id, prob, .. } => wired.push((id, prob.to_bits())),
            other => panic!("socket run failed on {}: {other:?}", sr.k),
        }
    }
    wired.sort_unstable();
    srv.shutdown();
    assert_eq!(inproc.len(), 80);
    assert_eq!(inproc, wired, "the transport changed the scored results");
}

#[test]
fn pjrt_backed_serving_smoke() {
    if !Runtime::pjrt_available() {
        eprintln!("SKIP: PJRT backend not linked (offline stub runtime::xla)");
        return;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("model_criteo_b32.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let prof = profile("criteo").unwrap();
    let tf = TensorFile::read(&dir.join("embeddings_criteo.bin")).unwrap();
    let st = Arc::new(EmbeddingStore::from_atns(&tf).unwrap());
    let d_emb = st.d_emb;
    let (nd, ns) = (prof.n_dense, prof.n_sparse());
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        st,
        move |_| {
            let rt = Runtime::open(&dir)?;
            Ok(Box::new(PjrtEngine::new(rt, "criteo", 32, nd, ns, d_emb)?))
        },
    )
    .unwrap();
    let mut gen = Generator::new(prof, DEFAULT_SEED);
    let (tx, rx) = mpsc::channel();
    for id in 0..64u64 {
        let (dense, ids) = gen.features(id as usize);
        coord
            .submit(Request::full(
                id,
                dense,
                ids.iter().map(|&x| x as i32).collect(),
                tx.clone(),
            ))
            .unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    assert_eq!(responses.len(), 64);
    for r in &responses {
        assert!((0.0..=1.0).contains(&r.prob), "prob {}", r.prob);
    }
    // probabilities should not be degenerate (all identical)
    let first = responses[0].prob;
    assert!(
        responses.iter().any(|r| (r.prob - first).abs() > 1e-4),
        "model output is constant — check artifact weights"
    );
    coord.shutdown();
}
