//! Coordinator integration: multi-worker serving with mock engines under
//! concurrent load, plus (artifact-gated) a PJRT-backed smoke run.

use autorac::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, MockEngine, PjrtEngine, Request,
};
use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn store() -> Arc<EmbeddingStore> {
    Arc::new(EmbeddingStore::random(&profile("criteo").unwrap(), 32, 7))
}

#[test]
fn concurrent_load_from_many_producers() {
    let coord = Arc::new(
        Coordinator::start(
            CoordinatorConfig {
                n_workers: 3,
                batcher: BatcherConfig {
                    max_batch: 16,
                    max_wait: Duration::from_micros(300),
                },
                ..Default::default()
            },
            store(),
            |_| Ok(Box::new(MockEngine::new(16, 13, 26, 32))),
        )
        .unwrap(),
    );
    let n_producers = 4u64;
    let per = 100u64;
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for p in 0..n_producers {
        let coord = coord.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let mut gen =
                Generator::new(profile("criteo").unwrap(), DEFAULT_SEED + p);
            for i in 0..per {
                let (dense, ids) = gen.features(i as usize);
                coord
                    .submit(Request {
                        id: p * 1000 + i,
                        dense,
                        ids: ids.iter().map(|&x| x as i32).collect(),
                        enqueued: Instant::now(),
                        reply: tx.clone(),
                    })
                    .unwrap();
            }
        }));
    }
    drop(tx);
    for h in handles {
        h.join().unwrap();
    }
    let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids.len(), (n_producers * per) as usize);
    ids.dedup();
    assert_eq!(
        ids.len(),
        (n_producers * per) as usize,
        "duplicate responses"
    );
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, n_producers * per);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn pjrt_backed_serving_smoke() {
    if !Runtime::pjrt_available() {
        eprintln!("SKIP: PJRT backend not linked (offline stub runtime::xla)");
        return;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("model_criteo_b32.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let prof = profile("criteo").unwrap();
    let tf = TensorFile::read(&dir.join("embeddings_criteo.bin")).unwrap();
    let st = Arc::new(EmbeddingStore::from_atns(&tf).unwrap());
    let d_emb = st.d_emb;
    let (nd, ns) = (prof.n_dense, prof.n_sparse());
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        st,
        move |_| {
            let rt = Runtime::open(&dir)?;
            Ok(Box::new(PjrtEngine::new(rt, "criteo", 32, nd, ns, d_emb)?))
        },
    )
    .unwrap();
    let mut gen = Generator::new(prof, DEFAULT_SEED);
    let (tx, rx) = mpsc::channel();
    for id in 0..64u64 {
        let (dense, ids) = gen.features(id as usize);
        coord
            .submit(Request {
                id,
                dense,
                ids: ids.iter().map(|&x| x as i32).collect(),
                enqueued: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    assert_eq!(responses.len(), 64);
    for r in &responses {
        assert!((0.0..=1.0).contains(&r.prob), "prob {}", r.prob);
    }
    // probabilities should not be degenerate (all identical)
    let first = responses[0].prob;
    assert!(
        responses.iter().any(|r| (r.prob - first).abs() > 1e-4),
        "model output is constant — check artifact weights"
    );
    coord.shutdown();
}
