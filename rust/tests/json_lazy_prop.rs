//! Differential property suite for `util::json_lazy` (ISSUE 6
//! satellite): the lazy scanner must NEVER disagree with the tree
//! parser — same accept/reject decision on every input, bit-identical
//! fields on every accept — and the fallback trigger paths (escapes,
//! unicode, depth, type surprises) must actually fire.

use autorac::coordinator::loadgen::{self, Arrival, LoadGenConfig};
use autorac::data::profile;
use autorac::util::json_lazy::{
    self, parse_request_traced, parse_request_tree, write_f32, ParsePath,
    WireRequest,
};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::{prop_assert, prop_assert_eq};

/// Bit-level equality (f32 payloads compared through `to_bits`, so
/// -0.0 vs 0.0 and NaN patterns cannot silently pass `==`).
fn same_request(a: &WireRequest, b: &WireRequest) -> bool {
    a.id == b.id
        && a.tables == b.tables
        && a.ids == b.ids
        && a.deadline_us == b.deadline_us
        && a.dense.len() == b.dense.len()
        && a.dense
            .iter()
            .zip(&b.dense)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The headline differential: whatever `parse_request` returns must
/// match the authoritative tree parse on the same bytes.
fn check_differential(bytes: &[u8]) -> Result<ParsePath, String> {
    let (fast, path) = parse_request_traced(bytes);
    let tree = parse_request_tree(bytes);
    match (&fast, &tree) {
        (Ok(a), Ok(b)) => {
            if !same_request(a, b) {
                return Err(format!(
                    "paths disagree on value ({path:?}):\n  fast {a:?}\n  tree {b:?}\n  \
                     input {:?}",
                    String::from_utf8_lossy(bytes)
                ));
            }
        }
        (Err(_), Err(_)) => {}
        _ => {
            return Err(format!(
                "paths disagree on acceptance ({path:?}): fast ok={} tree ok={} \
                 input {:?}",
                fast.is_ok(),
                tree.is_ok(),
                String::from_utf8_lossy(bytes)
            ))
        }
    }
    Ok(path)
}

// ---------------------------------------------------------------------------
// Random request-line generator. Tracks whether it emitted anything the
// lazy scanner is documented to refuse (escape / non-ASCII), so the
// path assertion can be exact.
// ---------------------------------------------------------------------------

struct LineGen {
    out: String,
    /// true once a `\` escape or a non-ASCII char was emitted anywhere
    forced_tree: bool,
}

impl LineGen {
    fn string(&mut self, g: &mut Gen) {
        self.out.push('"');
        for _ in 0..g.usize(0, 8) {
            match g.usize(0, 9) {
                0..=5 => {
                    // plain ASCII letter/digit — lazy-safe
                    let c = b'a' + g.usize(0, 25) as u8;
                    self.out.push(c as char);
                }
                6 => {
                    self.out.push_str("\\n");
                    self.forced_tree = true;
                }
                7 => {
                    self.out.push_str("\\u00e9");
                    self.forced_tree = true;
                }
                8 => {
                    self.out.push_str("\\\"");
                    self.forced_tree = true;
                }
                _ => {
                    self.out.push('é'); // raw UTF-8, non-ASCII byte
                    self.forced_tree = true;
                }
            }
        }
        self.out.push('"');
    }

    fn number(&mut self, g: &mut Gen) {
        match g.usize(0, 3) {
            0 => self.out.push_str(&g.u64(0, 1 << 40).to_string()),
            1 => self.out.push_str(&format!("{}", g.f64(-1.0e4, 1.0e4))),
            2 => self.out.push_str(&format!("{:e}", g.f64(-1.0, 1.0))),
            _ => self.out.push_str(&format!("-{}", g.u64(0, 1000))),
        }
    }

    /// Any JSON value, for cold fields the scanner must skip blind.
    fn value(&mut self, g: &mut Gen, depth: usize) {
        match g.usize(0, if depth < 3 { 5 } else { 3 }) {
            0 => self.number(g),
            1 => self.string(g),
            2 => self.out.push_str(g.choose(&["true", "false", "null"])),
            3 => self.number(g),
            4 | 5 => {
                let (open, close) = if g.bool() { ('[', ']') } else { ('{', '}') };
                self.out.push(open);
                for i in 0..g.usize(0, 3) {
                    if i > 0 {
                        self.out.push(',');
                    }
                    if open == '{' {
                        self.string(g);
                        self.out.push(':');
                    }
                    self.value(g, depth + 1);
                }
                self.out.push(close);
            }
            _ => unreachable!(),
        }
    }
}

/// One randomised request line: hot fields (each present with high
/// probability, occasionally malformed) interleaved with cold fields in
/// random order.
fn gen_line(g: &mut Gen) -> (String, bool) {
    let mut lg = LineGen { out: String::from("{"), forced_tree: false };
    let mut fields: Vec<usize> = (0..g.usize(4, 7)).collect();
    // crude in-place shuffle off the qcheck rng
    for i in (1..fields.len()).rev() {
        let j = g.usize(0, i);
        fields.swap(i, j);
    }
    for (n, f) in fields.iter().enumerate() {
        if n > 0 {
            lg.out.push(',');
        }
        match f {
            0 => {
                lg.out.push_str("\"id\":");
                if g.usize(0, 9) == 0 {
                    lg.out.push_str("\"oops\""); // type surprise
                } else {
                    lg.out.push_str(&g.u64(0, 1 << 40).to_string());
                }
            }
            1 => {
                lg.out.push_str("\"dense\":[");
                for i in 0..g.usize(0, 6) {
                    if i > 0 {
                        lg.out.push(',');
                    }
                    lg.number(g);
                }
                lg.out.push(']');
            }
            2 => {
                lg.out.push_str("\"tables\":[");
                let n = g.usize(0, 6);
                let mut t = g.vec_usize(n, 0, 500);
                t.sort_unstable();
                t.dedup();
                if g.usize(0, 9) == 0 {
                    t.reverse(); // violate the ascending contract
                }
                for (i, v) in t.iter().enumerate() {
                    if i > 0 {
                        lg.out.push(',');
                    }
                    lg.out.push_str(&v.to_string());
                }
                lg.out.push(']');
            }
            3 => {
                lg.out.push_str("\"ids\":[");
                for i in 0..g.usize(0, 6) {
                    if i > 0 {
                        lg.out.push(',');
                    }
                    lg.out.push_str(&g.u64(0, 100_000).to_string());
                }
                lg.out.push(']');
            }
            _ => {
                // cold field with an arbitrary payload (duplicates of a
                // hot key also land here sometimes — first wins)
                match g.usize(0, 5) {
                    0 => lg.out.push_str("\"ctx\":"),
                    1 => lg.out.push_str("\"ua\":"),
                    2 => lg.out.push_str("\"id\":"), // duplicate key
                    _ => {
                        lg.string(g);
                        lg.out.push(':');
                    }
                }
                lg.value(g, 0);
            }
        }
    }
    lg.out.push('}');
    (lg.out, lg.forced_tree)
}

#[test]
fn lazy_and_tree_agree_on_random_request_lines() {
    qcheck(400, |g| {
        let (line, forced_tree) = gen_line(g);
        let path = check_differential(line.as_bytes())?;
        if forced_tree {
            prop_assert_eq!(path, ParsePath::Tree);
        }
        Ok(())
    });
}

#[test]
fn fallback_triggers_route_to_the_tree_and_still_agree() {
    // Each construct is documented to push the scanner onto the tree
    // path; the differential must hold there too.
    let cases: &[&str] = &[
        // escape in a cold string value
        r#"{"id":1,"dense":[0.5],"tables":[2],"ids":[3],"ua":"a\tb"}"#,
        // escape in a KEY
        r#"{"id":1,"dense":[],"tables":[],"ids":[],"k\ney":0}"#,
        // raw unicode in a cold value
        "{\"id\":1,\"dense\":[0.5],\"tables\":[2],\"ids\":[3],\"city\":\"Zürich\"}",
        // hot field with a surprising type
        r#"{"id":"7","dense":[0.5],"tables":[2],"ids":[3]}"#,
        r#"{"id":1,"dense":"nope","tables":[2],"ids":[3]}"#,
        r#"{"id":1,"dense":[0.5],"tables":[2.5],"ids":[3]}"#,
        r#"{"id":-1,"dense":[],"tables":[],"ids":[]}"#,
        // missing hot field
        r#"{"id":1,"dense":[0.5],"tables":[2]}"#,
        // top level not an object
        r#"[1,2,3]"#,
        // trailing bytes
        r#"{"id":1,"dense":[],"tables":[],"ids":[]} x"#,
        // grammar the scanner refuses mid-stream
        r#"{"id":1 "dense":[]}"#,
    ];
    // nesting past MAX_DEPTH inside a cold field
    let deep = format!(
        r#"{{"id":1,"dense":[],"tables":[],"ids":[],"deep":{}{}}}"#,
        "[".repeat(600),
        "]".repeat(600)
    );
    for case in cases.iter().copied().chain([deep.as_str()]) {
        let (_, path) = parse_request_traced(case.as_bytes());
        assert_eq!(path, ParsePath::Tree, "expected fallback for {case:?}");
        check_differential(case.as_bytes()).unwrap();
    }
}

#[test]
fn hostile_byte_soup_never_panics_and_never_disagrees() {
    qcheck(400, |g| {
        let n = g.usize(0, 64);
        let bytes: Vec<u8> = match g.usize(0, 2) {
            // arbitrary bytes (mostly invalid UTF-8)
            0 => (0..n).map(|_| g.u64(0, 255) as u8).collect(),
            // JSON-ish punctuation soup
            1 => (0..n)
                .map(|_| *g.choose(b"{}[]\",:0123456789.eE+- \\\x00\x1f"))
                .collect(),
            // valid prefix, truncated at a random point
            _ => {
                let (line, _) = gen_line(g);
                let cut = g.usize(0, line.len());
                line.as_bytes()[..cut].to_vec()
            }
        };
        check_differential(&bytes)?;
        Ok(())
    });
}

#[test]
fn encoder_round_trips_bit_exactly_on_the_lazy_path() {
    qcheck(300, |g| {
        let nd = g.usize(0, 8);
        let mut dense = Vec::with_capacity(nd);
        for _ in 0..nd {
            dense.push(match g.usize(0, 5) {
                0 => -0.0f32,
                1 => f32::MIN_POSITIVE / 2.0, // subnormal
                2 => g.f64(-1.0e30, 1.0e30) as f32,
                3 => g.u64(0, 1 << 24) as f32,
                _ => g.f64(-8.0, 8.0) as f32,
            });
        }
        let nt = g.usize(0, 8);
        let mut tables: Vec<u32> =
            g.vec_usize(nt, 0, 4000).iter().map(|&t| t as u32).collect();
        tables.sort_unstable();
        tables.dedup();
        let ids: Vec<i32> = (0..tables.len())
            .map(|_| g.u64(0, i32::MAX as u64) as i32)
            .collect();
        // ids stay <= 2^53: the wire narrows through f64 on both paths,
        // so only f64-exact integers can round-trip
        let deadline_us = (g.usize(0, 3) == 0).then(|| g.u64(0, 1 << 53));
        let req = WireRequest {
            id: g.u64(0, 1 << 53),
            dense,
            tables,
            ids,
            deadline_us,
        };
        let line = req.to_line();
        let (parsed, path) = parse_request_traced(line.trim_end().as_bytes());
        let parsed = parsed.map_err(|e| format!("round trip failed: {e}"))?;
        prop_assert_eq!(path, ParsePath::Lazy);
        prop_assert!(
            same_request(&req, &parsed),
            "round trip not bit-exact:\n  sent {req:?}\n  got  {parsed:?}"
        );
        Ok(())
    });
}

#[test]
fn nonfinite_floats_encode_to_null_and_reject_on_both_paths() {
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
        let mut s = String::new();
        write_f32(&mut s, bad);
        assert_eq!(s, "null");
        let req = WireRequest {
            id: 1,
            dense: vec![bad],
            tables: vec![0],
            ids: vec![0],
            deadline_us: None,
        };
        let line = req.to_line();
        check_differential(line.trim_end().as_bytes()).unwrap();
        assert!(json_lazy::parse_request(line.trim_end().as_bytes()).is_err());
    }
}

#[test]
fn the_serving_corpus_stays_entirely_on_the_lazy_path() {
    let prof = profile("kdd").unwrap();
    let cfg = LoadGenConfig {
        n_requests: 64,
        arrival: Arrival::OpenLoop { rps: 50_000.0 },
        seed: 11,
        coverage: 0.5,
        oov_frac: 0.0,
    };
    for with_ctx in [false, true] {
        let corpus = loadgen::wire_corpus(&prof, &cfg, with_ctx).unwrap();
        assert_eq!(corpus.len(), 64);
        for line in &corpus {
            let bytes = line.trim_end().as_bytes();
            let path = check_differential(bytes).unwrap();
            assert_eq!(
                path,
                ParsePath::Lazy,
                "corpus line fell back (with_ctx={with_ctx}): {line:?}"
            );
        }
    }
}
