//! Property tests for worker-failure survival (ISSUE 8): replica
//! promotion (`ShardMap::promote`) keeps every table owned and every
//! gather bit-identical to the monolithic answer, with or without the
//! hot-row cache in front, and the router's dead-worker skip conserves
//! requests under arbitrary kill sets.

use autorac::coordinator::router::{Router, RouteRejection};
use autorac::coordinator::Policy;
use autorac::data::{profile, ALL_PROFILES};
use autorac::embeddings::{
    BatchGatherer, HotCacheConfig, HotRowCache, ShardMap, ShardPolicy,
    ShardedStore,
};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::{prop_assert, prop_assert_eq};
use std::sync::mpsc;

const POLICIES: [ShardPolicy; 3] = [
    ShardPolicy::RoundRobinTables,
    ShardPolicy::CapacityBalanced,
    ShardPolicy::HotReplicated,
];

fn random_cards(g: &mut Gen) -> Vec<usize> {
    let nt = g.usize(1, 24);
    (0..nt).map(|_| g.usize(1, 1200)).collect()
}

/// A dead set over `n` shards that always leaves at least one survivor.
fn random_dead(g: &mut Gen, n: usize) -> Vec<bool> {
    let mut dead: Vec<bool> = (0..n).map(|_| g.usize(0, 2) == 0).collect();
    let survivor = g.usize(0, n - 1);
    dead[survivor] = false;
    dead
}

/// Random per-record `(fields, ids)` batch with OOV sentinels mixed in.
fn random_batch(
    g: &mut Gen,
    cards: &[usize],
    n_records: usize,
) -> Vec<(Vec<u32>, Vec<i32>)> {
    let nf = cards.len();
    (0..n_records)
        .map(|_| {
            let keep = g.usize(1, nf);
            let mut fields: Vec<u32> = (0..nf as u32).collect();
            g.rng().shuffle(&mut fields);
            fields.truncate(keep);
            fields.sort_unstable();
            let ids: Vec<i32> = fields
                .iter()
                .map(|&f| {
                    let c = cards[f as usize];
                    match g.usize(0, 7) {
                        0 => -1,
                        1 => c as i32, // exactly card → OOV row
                        _ => g.usize(0, c - 1) as i32,
                    }
                })
                .collect();
            (fields, ids)
        })
        .collect()
}

#[test]
fn promote_preserves_ownership_invariants() {
    qcheck(60, |g| {
        let cards = random_cards(g);
        let alpha = g.f64(1.05, 1.5);
        let n_shards = g.usize(1, 8);
        let policy = *g.choose(&POLICIES);
        let m = ShardMap::build(&cards, alpha, n_shards, policy);
        let dead: Vec<bool> = (0..n_shards).map(|_| g.usize(0, 2) == 0).collect();
        let m2 = m.promote(&dead);
        prop_assert_eq!(m2.n_shards, m.n_shards);
        prop_assert_eq!(m2.n_tables(), m.n_tables());
        for j in 0..m.n_tables() {
            let before = m.owners(j);
            let after = m2.owners(j);
            prop_assert!(!after.is_empty(), "table {j} lost all owners");
            prop_assert!(
                after.windows(2).all(|w| w[0] < w[1]),
                "owners not sorted/unique for table {j}"
            );
            prop_assert!(
                after.iter().all(|s| before.contains(s)),
                "promotion invented an owner for table {j}"
            );
            let live: Vec<u32> = before
                .iter()
                .copied()
                .filter(|&s| !dead[s as usize])
                .collect();
            if live.is_empty() {
                // every owner died: data-resident fallback keeps the
                // original owners so the table stays addressable
                prop_assert_eq!(after, before, "fallback for table {j}");
            } else {
                prop_assert_eq!(after, &live[..], "live filter for table {j}");
            }
        }
        // no deaths → promotion is the identity
        let id = m.promote(&vec![false; n_shards]);
        for j in 0..m.n_tables() {
            prop_assert_eq!(id.owners(j), m.owners(j));
        }
        Ok(())
    });
}

#[test]
fn promoted_gathers_are_bit_identical_per_record() {
    qcheck(16, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let d_emb = *g.choose(&[4usize, 8]);
        let seed = g.u64(0, 1 << 40);
        let n_shards = g.usize(2, 5);
        let policy = *g.choose(&POLICIES);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        let store = ShardedStore::random(&p, d_emb, seed, map);
        let dead = random_dead(g, n_shards);
        let promoted = store.map.promote(&dead);
        let live: Vec<usize> =
            (0..n_shards).filter(|&s| !dead[s]).collect();
        let local = live[g.usize(0, live.len() - 1)];
        let batch = random_batch(g, &p.cards, g.usize(2, 10));

        let mut want = Vec::new();
        let mut got = Vec::new();
        let (mut w_req, mut w_oob) = (0usize, 0usize);
        let (mut g_req, mut g_oob) = (0usize, 0usize);
        for (fields, ids) in &batch {
            let (l, r, o) = store.gather_from(local, fields, ids, &mut want);
            w_req += l + r;
            w_oob += o;
            let (l2, r2, o2) =
                store.gather_from_with(&promoted, local, fields, ids, &mut got);
            g_req += l2 + r2;
            g_oob += o2;
        }
        prop_assert!(
            got == want,
            "promoted gather diverges ({name}, {policy:?}, dead {dead:?})"
        );
        prop_assert_eq!(g_req, w_req, "row counts must match");
        prop_assert_eq!(g_oob, w_oob, "OOV counts must match");
        Ok(())
    });
}

#[test]
fn promoted_batch_gathers_are_bit_identical_with_any_cache() {
    qcheck(10, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let seed = g.u64(0, 1 << 40);
        let n_shards = g.usize(2, 4);
        let policy = *g.choose(&POLICIES);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        let store = ShardedStore::random(&p, 8, seed, map);
        let dead = random_dead(g, n_shards);
        let promoted = store.map.promote(&dead);
        let live: Vec<usize> =
            (0..n_shards).filter(|&s| !dead[s]).collect();
        let local = live[g.usize(0, live.len() - 1)];
        let batch = random_batch(g, &p.cards, g.usize(2, 10));

        // reference: per-record gathers through the ORIGINAL map
        let mut want = Vec::new();
        for (fields, ids) in &batch {
            store.gather_from(local, fields, ids, &mut want);
        }

        let caches = [
            None,
            Some(HotRowCache::new(
                &store,
                p.zipf_alpha,
                HotCacheConfig {
                    capacity: g.usize(1, 128),
                    prefetch: true,
                },
            )),
        ];
        for cache in &caches {
            let mut gatherer = BatchGatherer::new(&store.cards);
            let mut got = Vec::new();
            let st = gatherer.gather_batch_with(
                &promoted,
                &store,
                cache.as_ref(),
                local,
                batch.iter().map(|(f, i)| (f.as_slice(), i.as_slice())),
                &mut got,
            );
            prop_assert!(
                got == want,
                "promoted batch gather diverges \
                 ({name}, {policy:?}, dead {dead:?}, cache {})",
                cache.is_some()
            );
            prop_assert!(st.balanced(), "unbalanced ledger: {st:?}");
        }
        Ok(())
    });
}

#[test]
fn routing_skips_dead_workers_and_conserves() {
    qcheck(40, |g| {
        let workers = g.usize(2, 6);
        let n_dead = g.usize(1, workers - 1);
        let policy = *g.choose(&[
            Policy::RoundRobin,
            Policy::LeastQueued,
            Policy::ShardAffinity, // no map attached → least-queued
        ]);
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..workers).map(|_| mpsc::channel::<usize>()).unzip();
        let r = Router::new(txs, policy);
        let mut rxs: Vec<Option<mpsc::Receiver<usize>>> =
            rxs.into_iter().map(Some).collect();
        let mut dead = vec![false; workers];
        for _ in 0..n_dead {
            let mut k = g.usize(0, workers - 1);
            while dead[k] {
                k = (k + 1) % workers;
            }
            dead[k] = true;
            if g.usize(0, 1) == 0 {
                // crash style: receiver vanishes, router learns on send
                rxs[k] = None;
            } else {
                // guard style: the slot is closed up front
                r.slot_handle(k).close();
            }
        }
        let n = g.usize(workers, 80);
        for i in 0..n {
            match r.route_bounded(&[], usize::MAX, i) {
                Ok(w) => prop_assert!(
                    !dead[w],
                    "request {i} landed on dead worker {w} ({policy:?})"
                ),
                Err(RouteRejection::Closed(_)) => {
                    prop_assert!(false, "false total-outage with survivors")
                }
                Err(RouteRejection::Overloaded(_)) => {
                    prop_assert!(false, "unbounded route overloaded")
                }
            }
        }
        // ≥ workers routes guarantee every crash-style death was
        // discovered, so the router's live count is exact by now
        prop_assert_eq!(r.n_alive(), workers - n_dead);
        let total: usize = rxs
            .iter()
            .flatten()
            .map(|rx| rx.try_iter().count())
            .sum();
        prop_assert_eq!(total, n, "reroute must conserve requests");
        Ok(())
    });
}
