//! Malformed-input suite for the socket front end (ISSUE 6 satellite):
//! whatever bytes arrive, the server answers a structured error line or
//! closes the connection cleanly — it never panics, never hangs, and
//! its ledger never books a frame that failed to parse.
//!
//! Every exchange runs under a per-case timeout: the probe socket has a
//! 5 s read timeout and a blocked read is a test FAILURE (hang), not a
//! wait. The suite ends with a health check — a fresh connection must
//! still be served after the barrage — and a clean server shutdown.

use autorac::coordinator::{
    Coordinator, CoordinatorConfig, MockEngine, NetClient, NetServer,
    NetServerConfig, WireResponse,
};
use autorac::data::profile;
use autorac::embeddings::EmbeddingStore;
use autorac::util::json::Json;
use autorac::util::json_lazy::WireRequest;
use autorac::util::rng::Rng;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const PROBE_TIMEOUT: Duration = Duration::from_secs(5);

fn server() -> NetServer {
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_workers: 2,
            ..Default::default()
        },
        Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
        |_| Ok(Box::new(MockEngine::new(16, 3, 10, 8))),
    )
    .unwrap();
    NetServer::start("127.0.0.1:0", coord, NetServerConfig::default()).unwrap()
}

fn valid_request(id: u64) -> WireRequest {
    WireRequest {
        id,
        dense: vec![0.25; 3],
        tables: (0..10).collect(),
        ids: vec![1; 10],
        deadline_us: None,
    }
}

/// What one hostile exchange produced.
#[derive(Debug)]
enum Outcome {
    /// every response line the server sent before closing / before we
    /// stopped reading (one per request line we pushed)
    Lines(Vec<String>),
    /// the server closed without answering
    CleanClose,
}

/// Send `payload` on a fresh connection, half-close, then drain up to
/// `expect_lines` response lines. Panics (= test failure) if any read
/// blocks past [`PROBE_TIMEOUT`] — that is the hang the suite exists to
/// catch.
fn probe(addr: &SocketAddr, payload: &[u8], expect_lines: usize) -> Outcome {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(PROBE_TIMEOUT)).unwrap();
    s.write_all(payload).expect("write");
    s.shutdown(Shutdown::Write).unwrap();
    let mut r = BufReader::new(s);
    let mut lines = Vec::new();
    for _ in 0..expect_lines {
        let mut line = String::new();
        match r.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => lines.push(line),
            Err(e)
                if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) =>
            {
                panic!("server hung for {PROBE_TIMEOUT:?} on {payload:?}")
            }
            Err(e) => panic!("probe read failed: {e}"),
        }
    }
    if lines.is_empty() {
        Outcome::CleanClose
    } else {
        Outcome::Lines(lines)
    }
}

/// A response line must be well-formed JSON with an `"error"` string —
/// the structured-error contract.
fn assert_error_line(line: &str, case: &str) {
    let j = Json::parse(line.trim_end())
        .unwrap_or_else(|e| panic!("unparseable error line for {case}: {e}"));
    assert!(
        j.get("error").and_then(Json::as_str).is_some(),
        "no `error` field for {case}: {line:?}"
    );
}

#[test]
fn malformed_frames_get_errors_or_clean_closes_never_hangs() {
    let srv = server();
    let addr = srv.local_addr();

    // (payload, expected responses, label) — expected 0 means a clean
    // close with no line is also acceptable.
    let mut cases: Vec<(Vec<u8>, usize, String)> = vec![
        // truncated frame: valid bytes, no newline, then EOF
        (
            valid_request(1).to_line().trim_end().as_bytes()[..20].to_vec(),
            0,
            "truncated frame".into(),
        ),
        // empty and whitespace-only frames
        (b"\n".to_vec(), 1, "empty frame".into()),
        (b"   \t \r\n".to_vec(), 1, "whitespace frame".into()),
        // NUL and control bytes
        (b"\x00\x01\x02\n".to_vec(), 1, "control bytes".into()),
        // invalid UTF-8 inside a string value
        (
            b"{\"id\":1,\"dense\":[],\"tables\":[],\"ids\":[],\"s\":\"\xff\xfe\"}\n"
                .to_vec(),
            1,
            "invalid UTF-8".into(),
        ),
        // deep nesting: must be a depth error, not a stack overflow
        (
            {
                let mut v = b"{\"deep\":".to_vec();
                v.extend(std::iter::repeat(b'[').take(5000));
                v.extend(std::iter::repeat(b']').take(5000));
                v.extend(b"}\n");
                v
            },
            1,
            "5000-deep nesting".into(),
        ),
        // bare deep array (top level not even an object)
        (
            {
                let mut v: Vec<u8> = std::iter::repeat(b'[').take(5000).collect();
                v.push(b'\n');
                v
            },
            1,
            "unclosed deep array".into(),
        ),
        // huge length claim: dense above MAX_WIRE_DENSE
        (
            {
                let mut s = String::from("{\"id\":1,\"dense\":[");
                s.push_str(&vec!["0.5"; 5000].join(","));
                s.push_str("],\"tables\":[],\"ids\":[]}\n");
                s.into_bytes()
            },
            1,
            "oversize dense".into(),
        ),
        // shape violations
        (
            b"{\"id\":1,\"dense\":[],\"tables\":[1,2],\"ids\":[3]}\n".to_vec(),
            1,
            "length mismatch".into(),
        ),
        (
            b"{\"id\":1,\"dense\":[],\"tables\":[2,1],\"ids\":[0,0]}\n".to_vec(),
            1,
            "non-ascending tables".into(),
        ),
        // type surprises
        (
            b"{\"id\":\"x\",\"dense\":[],\"tables\":[],\"ids\":[]}\n".to_vec(),
            1,
            "string id".into(),
        ),
        (b"{not json}\n".to_vec(), 1, "not json".into()),
        (b"null\n".to_vec(), 1, "bare null".into()),
    ];
    // deterministic random byte soup, some lines ending in '\n'
    let mut rng = Rng::new(0xBAD_F00D);
    for k in 0..16 {
        let n = 1 + rng.below(64) as usize;
        let mut v: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        v.retain(|&b| b != b'\n');
        v.push(b'\n');
        cases.push((v, 1, format!("byte soup #{k}")));
    }

    for (payload, expect, label) in &cases {
        match probe(&addr, payload, (*expect).max(1)) {
            Outcome::CleanClose => {}
            Outcome::Lines(lines) => {
                for line in &lines {
                    assert_error_line(line, label);
                }
                assert!(
                    lines.len() >= *expect,
                    "{label}: wanted {expect} error line(s), got {lines:?}"
                );
            }
        }
    }

    // nothing malformed ever reached the admission ledger
    let snap = srv.metrics();
    assert_eq!(snap.requests, 0, "a malformed frame was submitted");

    // health check: the server still serves a fresh, valid connection
    let mut c = NetClient::connect(&addr).unwrap();
    match c.request(&valid_request(99)).unwrap() {
        WireResponse::Ok { id, .. } => assert_eq!(id, 99),
        other => panic!("health check failed: {other:?}"),
    }
    srv.shutdown();
}

#[test]
fn duplicate_keys_are_first_occurrence_wins_and_still_served() {
    let srv = server();
    let mut c = NetClient::connect(&srv.local_addr()).unwrap();
    // second "id" is hostile garbage; first one wins (Json::get order)
    c.send_line(
        "{\"id\":5,\"dense\":[0.5,0.5,0.5],\"tables\":[0,1],\"ids\":[2,3],\
         \"id\":\"evil\"}\n",
    )
    .unwrap();
    match c.recv().unwrap().expect("server closed") {
        WireResponse::Ok { id, .. } => assert_eq!(id, 5),
        other => panic!("unexpected: {other:?}"),
    }
    srv.shutdown();
}

#[test]
fn over_frame_line_errors_and_closes_without_buffering_it() {
    let srv = server();
    let addr = srv.local_addr();
    // 2 MiB of digits in one line — double the 1 MiB frame cap. The
    // server must answer one structured error and close, having
    // discarded (not accumulated) the overflow.
    let mut payload = Vec::with_capacity(2 << 20);
    payload.extend(b"{\"id\":");
    payload.extend(std::iter::repeat(b'1').take(2 << 20));
    payload.push(b'\n');
    match probe(&addr, &payload, 2) {
        Outcome::Lines(lines) => {
            assert_eq!(lines.len(), 1, "expected close after the error");
            assert_error_line(&lines[0], "over-frame line");
            assert!(
                lines[0].contains("size limit"),
                "unexpected error: {:?}",
                lines[0]
            );
        }
        Outcome::CleanClose => panic!("expected a structured error first"),
    }
    srv.shutdown();
}

#[test]
fn pipelined_garbage_between_valid_requests_does_not_poison_the_stream() {
    let srv = server();
    let mut c = NetClient::connect(&srv.local_addr()).unwrap();
    c.send_line(&valid_request(1).to_line()).unwrap();
    c.send_line("garbage\n").unwrap();
    c.send_line(&valid_request(2).to_line()).unwrap();
    let mut ok = 0;
    let mut err = 0;
    for _ in 0..3 {
        match c.recv().unwrap().expect("server closed early") {
            WireResponse::Ok { id, .. } => {
                assert!(id == 1 || id == 2);
                ok += 1;
            }
            WireResponse::Error { .. } => err += 1,
        }
    }
    assert_eq!((ok, err), (2, 1));
    let snap = srv.metrics();
    assert_eq!(snap.requests, 2);
    assert_eq!(srv.stats.frames_bad.load(std::sync::atomic::Ordering::Relaxed), 1);
    srv.shutdown();
}

#[test]
fn slow_trickled_frame_is_assembled_not_rejected() {
    // a frame arriving one byte at a time over ~100 ms still parses
    let srv = server();
    let mut s = TcpStream::connect(&srv.local_addr()).unwrap();
    s.set_read_timeout(Some(PROBE_TIMEOUT)).unwrap();
    let line = valid_request(7).to_line();
    for chunk in line.as_bytes().chunks(8) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    let j = Json::parse(resp.trim_end()).unwrap();
    assert_eq!(j.get("id").and_then(Json::as_f64), Some(7.0));
    assert!(j.get("error").is_none(), "trickled frame rejected: {resp:?}");
    drop(r);
    let _ = s.shutdown(Shutdown::Both);
    srv.shutdown();
}

#[test]
fn a_stalled_connection_never_blocks_other_clients() {
    let srv = server();
    let addr = srv.local_addr();
    // open a connection, send half a frame, and just… stop
    let mut stall = TcpStream::connect(&addr).unwrap();
    stall.write_all(b"{\"id\":1,\"den").unwrap();
    stall.flush().unwrap();
    // other clients must be completely unaffected
    for k in 0..4 {
        let mut c = NetClient::connect(&addr).unwrap();
        match c.request(&valid_request(k)).unwrap() {
            WireResponse::Ok { id, .. } => assert_eq!(id, k),
            other => panic!("unexpected: {other:?}"),
        }
    }
    // and shutdown must not wait for the staller
    let t0 = std::time::Instant::now();
    srv.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown blocked on a stalled connection"
    );
    // the staller sees its socket die rather than hanging forever
    stall.set_read_timeout(Some(PROBE_TIMEOUT)).unwrap();
    let mut buf = [0u8; 64];
    match stall.read(&mut buf) {
        Ok(_) => {}
        Err(e) => assert!(
            !matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut),
            "stalled socket still open after shutdown"
        ),
    }
}
