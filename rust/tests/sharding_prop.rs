//! Property tests for `embeddings::sharding` (ISSUE 2 satellite):
//! ownership totality, capacity balance, replication budget, and the
//! headline differential — a gather assembled across shards is
//! element-identical to the monolithic `EmbeddingStore` gather on the
//! same seed.

use autorac::data::{profile, ALL_PROFILES};
use autorac::embeddings::{
    sharding::{harmonic, heat_order, REPLICA_BUDGET},
    EmbeddingShard, EmbeddingStore, ShardMap, ShardPolicy, ShardedStore,
};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::{prop_assert, prop_assert_eq};

const POLICIES: [ShardPolicy; 3] = [
    ShardPolicy::RoundRobinTables,
    ShardPolicy::CapacityBalanced,
    ShardPolicy::HotReplicated,
];

fn random_cards(g: &mut Gen) -> Vec<usize> {
    let nt = g.usize(1, 40);
    (0..nt).map(|_| g.usize(1, 2500)).collect()
}

#[test]
fn every_table_is_owned_by_at_least_one_shard() {
    qcheck(60, |g| {
        let cards = random_cards(g);
        let alpha = g.f64(1.05, 1.5);
        let n_shards = g.usize(1, 8);
        let policy = *g.choose(&POLICIES);
        let m = ShardMap::build(&cards, alpha, n_shards, policy);
        prop_assert_eq!(m.n_tables(), cards.len());
        for j in 0..m.n_tables() {
            let owners = m.owners(j);
            prop_assert!(!owners.is_empty(), "table {j} unowned ({policy:?})");
            prop_assert!(
                owners.windows(2).all(|w| w[0] < w[1]),
                "owners not sorted/unique for table {j}"
            );
            prop_assert!(
                owners.iter().all(|&s| (s as usize) < n_shards),
                "owner out of range for table {j}"
            );
            prop_assert_eq!(m.primary(j), owners[0] as usize);
            if policy != ShardPolicy::HotReplicated {
                prop_assert_eq!(owners.len(), 1);
            }
        }
        // every table reachable through tables_of as well
        let covered: usize =
            (0..n_shards).map(|s| m.tables_of(s).len()).sum();
        prop_assert!(covered >= cards.len(), "tables_of misses tables");
        Ok(())
    });
}

#[test]
fn capacity_balanced_stays_within_2x_of_ideal() {
    qcheck(60, |g| {
        let cards = random_cards(g);
        let n_shards = g.usize(1, 8);
        let m = ShardMap::build(
            &cards,
            1.2,
            n_shards,
            ShardPolicy::CapacityBalanced,
        );
        let total: usize = cards.iter().sum();
        let max_card = *cards.iter().max().unwrap();
        // OPT can never beat max(total/m, biggest single table); LPT is
        // a 4/3-approximation, so 2× ideal is a safe hard bound.
        let ideal = (total.div_ceil(n_shards)).max(max_card);
        for s in 0..n_shards {
            let rows = m.rows_of(s, &cards);
            prop_assert!(
                rows <= 2 * ideal,
                "shard {s} holds {rows} rows vs ideal {ideal}"
            );
        }
        // non-replicated: loads partition the total exactly
        let sum: usize = (0..n_shards).map(|s| m.rows_of(s, &cards)).sum();
        prop_assert_eq!(sum, total);
        Ok(())
    });
}

#[test]
fn round_robin_tables_is_modulo_assignment() {
    qcheck(30, |g| {
        let cards = random_cards(g);
        let n_shards = g.usize(1, 8);
        let m = ShardMap::build(&cards, 1.2, n_shards, ShardPolicy::RoundRobinTables);
        for j in 0..cards.len() {
            prop_assert_eq!(m.owners(j), &[(j % n_shards) as u32]);
        }
        Ok(())
    });
}

#[test]
fn hot_replication_respects_the_budget() {
    qcheck(40, |g| {
        let cards = random_cards(g);
        let alpha = g.f64(1.05, 1.5);
        let n_shards = g.usize(1, 8);
        let m =
            ShardMap::build(&cards, alpha, n_shards, ShardPolicy::HotReplicated);
        let total: usize = cards.iter().sum();
        let stored: usize =
            (0..n_shards).map(|s| m.rows_of(s, &cards)).sum();
        // budget arithmetic is exact now: rounded, not truncated
        prop_assert!(
            stored <= total + (total as f64 * REPLICA_BUDGET).round() as usize,
            "replicas blow the budget: {stored} vs {total}"
        );
        Ok(())
    });
}

/// Pin the whole HotReplicated pass, not just its bound: mirror-simulate
/// the documented first-fit-decreasing walk (heat order, skip tables
/// that don't fit, keep going) and require the replicated set to match
/// exactly — so the budget is spent on precisely the tables the
/// documented algorithm picks, and a colder table is replicated only
/// when every hotter unreplicated table genuinely did not fit.
#[test]
fn hot_replication_budget_is_exact_and_first_fit_by_heat() {
    qcheck(40, |g| {
        let cards = random_cards(g);
        let alpha = g.f64(1.05, 1.5);
        let n_shards = g.usize(2, 8);
        let m =
            ShardMap::build(&cards, alpha, n_shards, ShardPolicy::HotReplicated);
        let total: usize = cards.iter().sum();
        let budget = (total as f64 * REPLICA_BUDGET).round() as usize;
        let mut remaining = budget;
        let mut expect_replicated = vec![false; cards.len()];
        for j in heat_order(&cards, alpha) {
            let extra = cards[j] * (n_shards - 1);
            if extra <= remaining {
                remaining -= extra;
                expect_replicated[j] = true;
            }
        }
        let mut spent = 0usize;
        for j in 0..cards.len() {
            let replicated = m.owners(j).len() == n_shards;
            prop_assert!(
                replicated == expect_replicated[j],
                "table {j} (card {}) diverges from the FFD walk",
                cards[j]
            );
            // partial replication never happens: 1 owner or all
            prop_assert!(
                m.owners(j).len() == 1 || replicated,
                "table {j} partially replicated"
            );
            if replicated {
                spent += cards[j] * (n_shards - 1);
            }
        }
        prop_assert!(spent <= budget, "spent {spent} > budget {budget}");
        // heat_order really is sorted by descending head share
        let order = heat_order(&cards, alpha);
        prop_assert!(order
            .windows(2)
            .all(|w| 1.0 / harmonic(cards[w[0]], alpha)
                >= 1.0 / harmonic(cards[w[1]], alpha)));
        Ok(())
    });
}

/// Cache-aware placement follows the SAME first-fit-decreasing walk
/// with each table's replica cost discounted by its cached head rows
/// (mirror-simulated); zero cached rows reproduces `build` exactly.
/// (Note: "superset of the plain replicas" is deliberately NOT claimed —
/// a discount can let a hot table that previously didn't fit consume
/// budget a colder table was using.)
#[test]
fn cached_discount_follows_the_same_ffd_walk() {
    qcheck(40, |g| {
        let cards = random_cards(g);
        let alpha = g.f64(1.05, 1.5);
        let n_shards = g.usize(2, 6);
        let plain =
            ShardMap::build(&cards, alpha, n_shards, ShardPolicy::HotReplicated);
        let zero = ShardMap::build_cached(
            &cards,
            alpha,
            n_shards,
            ShardPolicy::HotReplicated,
            &[],
        );
        let cached: Vec<usize> =
            cards.iter().map(|&c| g.usize(0, c.min(64))).collect();
        let discounted = ShardMap::build_cached(
            &cards,
            alpha,
            n_shards,
            ShardPolicy::HotReplicated,
            &cached,
        );
        let total: usize = cards.iter().sum();
        let mut remaining = (total as f64 * REPLICA_BUDGET).round() as usize;
        let mut expect = vec![false; cards.len()];
        for j in heat_order(&cards, alpha) {
            let extra = cards[j].saturating_sub(cached[j]) * (n_shards - 1);
            if extra <= remaining {
                remaining -= extra;
                expect[j] = true;
            }
        }
        for j in 0..cards.len() {
            prop_assert!(
                zero.owners(j) == plain.owners(j),
                "no cached rows must reproduce build (table {j})"
            );
            prop_assert!(
                (discounted.owners(j).len() == n_shards) == expect[j],
                "table {j} diverges from the discounted FFD walk"
            );
        }
        Ok(())
    });
}

#[test]
fn local_fraction_is_a_fraction() {
    qcheck(40, |g| {
        let cards = random_cards(g);
        let n_shards = g.usize(1, 6);
        let policy = *g.choose(&POLICIES);
        let m = ShardMap::build(&cards, 1.2, n_shards, policy);
        let s = g.usize(0, n_shards - 1);
        let nf = g.usize(0, cards.len());
        let fields: Vec<u32> = (0..nf as u32).collect();
        let f = m.local_fraction(s, &fields);
        prop_assert!((0.0..=1.0).contains(&f), "fraction {f}");
        // a shard fully owns its own table set
        let own: Vec<u32> =
            m.tables_of(s).iter().map(|&j| j as u32).collect();
        prop_assert_eq!(m.local_fraction(s, &own), 1.0);
        Ok(())
    });
}

/// The headline differential: sharded gather == monolithic gather,
/// bit-for-bit, for any placement, any observer shard, any field
/// subset, and ids including out-of-range and negative values (both
/// paths resolve them to row 0, the OOV row, and report matching
/// `oob` counts).
#[test]
fn sharded_gather_is_element_identical_to_monolithic() {
    qcheck(25, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let d_emb = *g.choose(&[4usize, 8]);
        let seed = g.u64(0, 1 << 40);
        let n_shards = g.usize(1, 5);
        let policy = *g.choose(&POLICIES);
        let store = EmbeddingStore::random(&p, d_emb, seed);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        let sharded = ShardedStore::build(&store, map);
        let nf = p.n_sparse();
        for _ in 0..4 {
            // random strictly-ascending field subset
            let keep = g.usize(1, nf);
            let mut fields: Vec<u32> = (0..nf as u32).collect();
            g.rng().shuffle(&mut fields);
            fields.truncate(keep);
            fields.sort_unstable();
            let ids: Vec<i32> = fields
                .iter()
                .map(|&f| {
                    let c = p.cards[f as usize];
                    match g.usize(0, 9) {
                        0 => -1,             // negative → OOV row 0
                        1 => i32::MAX,       // overflow → OOV row 0
                        _ => g.usize(0, 2 * c) as i32, // may exceed card
                    }
                })
                .collect();
            let expect_oob = fields
                .iter()
                .zip(&ids)
                .filter(|(&f, &id)| {
                    id < 0 || id as usize >= p.cards[f as usize]
                })
                .count();
            let mut mono = Vec::new();
            let mono_oob = store.gather_fields(&fields, &ids, &mut mono);
            let local = g.usize(0, n_shards - 1);
            let mut shrd = Vec::new();
            let (l, r, oob) =
                sharded.gather_from(local, &fields, &ids, &mut shrd);
            prop_assert_eq!(l + r, fields.len());
            prop_assert_eq!(mono_oob, expect_oob);
            prop_assert_eq!(oob, expect_oob);
            prop_assert!(mono == shrd, "gather mismatch (local {local})");
        }
        Ok(())
    });
}

/// Shards generated directly from the profile (without materializing
/// the monolithic store) hold bit-identical rows — the zero-copy path
/// `serve-bench` uses.
#[test]
fn directly_generated_shards_match_monolithic_rows() {
    qcheck(15, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let seed = g.u64(0, 1 << 40);
        let n_shards = g.usize(1, 4);
        let policy = *g.choose(&POLICIES);
        let store = EmbeddingStore::random(&p, 4, seed);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        for s in 0..n_shards {
            let shard = EmbeddingShard::random(&p, 4, seed, &map, s);
            for j in 0..p.n_sparse() {
                prop_assert_eq!(shard.owns(j), map.owns(s, j));
                if shard.owns(j) {
                    let id = g.usize(0, p.cards[j] - 1);
                    prop_assert!(
                        shard.row(j, id).unwrap() == store.row(j, id),
                        "row mismatch shard {s} table {j} id {id}"
                    );
                }
            }
        }
        Ok(())
    });
}
