//! Cross-language dataset parity: the rust generator must reproduce the
//! golden records exported by the python build path (`compile.aot`).
//!
//! Requires `make artifacts`. Skips (with a loud message) when the
//! fixtures are absent so `cargo test` works on a cold checkout.

use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::util::json::Json;
use autorac::util::rng::Rng;
use std::path::Path;

fn golden(path: &str) -> Option<Json> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    if !p.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", p.display());
        return None;
    }
    Some(Json::read_file(&p).expect("parse golden"))
}

#[test]
fn prng_stream_matches_python() {
    let Some(j) = golden("artifacts/golden/prng.json") else {
        return;
    };
    let mut r = Rng::new(42);
    for v in j.req_arr("stream_seed42").unwrap() {
        let want: u64 = v.as_str().unwrap().parse().unwrap();
        assert_eq!(r.next_u64(), want);
    }
    let mut r2 = Rng::new(7);
    for v in j.req_arr("f64_seed7").unwrap() {
        let want = v.as_f64().unwrap();
        assert_eq!(r2.f64(), want, "f64 stream must be bit-identical");
    }
    let mut r3 = Rng::new(9);
    for v in j.req_arr("normal_seed9").unwrap() {
        let want = v.as_f64().unwrap();
        let got = r3.normal();
        // transcendental libm differences may cost the last ulp or two
        assert!(
            (got - want).abs() <= 1e-12 * want.abs().max(1.0),
            "normal: {got} vs {want}"
        );
    }
}

#[test]
fn records_match_python_golden() {
    let Some(j) = golden("artifacts/golden/records.json") else {
        return;
    };
    let seed = j.req_usize("seed").unwrap() as u64;
    assert_eq!(seed, DEFAULT_SEED, "golden seed drifted");
    let records = j.get("records").unwrap();
    for ds in ["criteo", "avazu", "kdd"] {
        let mut gen = Generator::new(profile(ds).unwrap(), seed);
        for rec in records.get(ds).unwrap().as_arr().unwrap() {
            let index = rec.req_usize("index").unwrap();
            let got = gen.record(index);
            let want_ids: Vec<usize> = rec
                .req_arr("ids")
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(got.ids, want_ids, "{ds}[{index}] ids");
            let want_dense: Vec<f64> = rec.req_f64s("dense").unwrap();
            assert_eq!(got.dense.len(), want_dense.len());
            for (a, b) in got.dense.iter().zip(&want_dense) {
                assert!(
                    (*a as f64 - b).abs() < 1e-6,
                    "{ds}[{index}] dense {a} vs {b}"
                );
            }
            let want_y = rec.req_usize("y").unwrap() == 1;
            assert_eq!(got.label, want_y, "{ds}[{index}] label");
        }
    }
}

#[test]
fn genome_json_is_python_compatible() {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/genomes");
    if !p.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", p.display());
        return;
    }
    for ds in ["criteo", "avazu", "kdd"] {
        for kind in ["autorac", "nasrec"] {
            let path = p.join(format!("{kind}_{ds}.json"));
            let g = autorac::nas::Genome::load(&path)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            g.validate().unwrap();
            // rust's builtin reference genomes mirror the python ones
            let builtin = match kind {
                "autorac" => autorac::nas::autorac_best(ds),
                _ => autorac::nas::nasrec_like(ds),
            };
            assert_eq!(g, builtin, "{kind}_{ds} drifted from arch.py");
        }
    }
}
