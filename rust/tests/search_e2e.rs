//! Search integration: a reduced Algorithm-1 run end-to-end, checking
//! that the co-search beats both random sampling and the hand-crafted
//! reference under the same criterion, and that hardware-genome search
//! responds to the λ weights.

use autorac::nas::{nasrec_like, random_genome, Search, SearchConfig, Surrogate};
use autorac::util::rng::Rng;

fn quick(gens: usize, lambdas: [f64; 3], seed: u64) -> Search {
    let cfg = SearchConfig {
        generations: gens,
        population: 16,
        children_per_gen: 6,
        sample_size: 5,
        sim_requests: 24,
        lambdas,
        seed,
        ..SearchConfig::default()
    };
    Search::new(cfg, Surrogate::load_default()).unwrap()
}

#[test]
fn search_beats_random_sampling_at_equal_budget() {
    let mut s = quick(20, [0.05; 3], 11);
    let best = s.run().unwrap();
    let budget = s.trace.evaluations;
    // random search with the same evaluation budget
    let mut rs = quick(0, [0.05; 3], 11);
    let mut rng = Rng::new(999);
    let mut best_random = f64::INFINITY;
    for i in 0..budget {
        let g = random_genome(&mut rng, "criteo", &format!("rnd{i}"));
        let ind = rs.evaluate(g).unwrap();
        best_random = best_random.min(ind.criterion);
    }
    assert!(
        best.criterion <= best_random,
        "evolution {} should beat random {} at equal budget",
        best.criterion,
        best_random
    );
}

#[test]
fn search_meets_or_beats_the_handcrafted_reference() {
    let mut s = quick(25, [0.05; 3], 4);
    let best = s.run().unwrap();
    let reference = s.evaluate(nasrec_like("criteo")).unwrap();
    assert!(
        best.criterion < reference.criterion,
        "searched {} vs nasrec {}",
        best.criterion,
        reference.criterion
    );
}

#[test]
fn hardware_lambdas_steer_the_search() {
    // Heavy area weight should find designs no larger than a loss-only
    // search does (stochastic, so allow slack).
    let mut area_heavy = quick(18, [0.01, 0.6, 0.01], 21);
    let a = area_heavy.run().unwrap();
    let mut loss_only = quick(18, [0.0, 0.0, 0.0], 21);
    let l = loss_only.run().unwrap();
    assert!(
        a.metrics[1] <= l.metrics[1] * 1.25,
        "area-weighted search should not find clearly larger designs: {} vs {}",
        a.metrics[1],
        l.metrics[1]
    );
}

#[test]
fn trace_has_paper_shape_quick() {
    let mut s = quick(30, [0.05; 3], 7);
    s.run().unwrap();
    let drop = s.trace.pct_drop();
    assert_eq!(drop[0], 0.0);
    let final_drop = *drop.last().unwrap();
    assert!(final_drop < -1.0, "criterion should drop >1%: {final_drop}");
}
