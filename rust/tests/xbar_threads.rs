//! Determinism layer for the tile-parallel crossbar kernel (S25),
//! mirroring `search_determinism.rs`: the thread count of an
//! [`XbarScratch`] arena must not change a single bit of the kernel's
//! outputs or its [`XbarActivity`] counts — a reordered reduction is
//! exactly the bug this suite exists to catch, and integer addition is
//! what makes bit-identity provable rather than hoped-for.

use autorac::coordinator::{InferenceEngine, PimEngine};
use autorac::mapping::{build_pim_net, NetScratch};
use autorac::nas::autorac_best;
use autorac::pim::{BatchedXbar, MatI32, PimConfig, XbarActivity, XbarScratch};
use autorac::util::rng::Rng;

fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
    let mut m = MatI32::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
        }
    }
    m
}

/// Bit-level fingerprint of one batched pass: raw accumulators,
/// corrected accumulators, and every activity counter.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    raw: Vec<i64>,
    corrected: Vec<i64>,
    activity: XbarActivity,
}

fn run(bx: &BatchedXbar, xs: &[i32], b: usize, threads: usize) -> Fingerprint {
    let mut scratch = XbarScratch::with_threads(threads);
    let mut raw = vec![0i64; b * bx.n];
    bx.mvm_batch(xs, b, &mut raw, &mut scratch);
    let act_raw = scratch.activity;
    let mut corrected = vec![0i64; b * bx.n];
    bx.mvm_corrected_batch(xs, b, &mut corrected, &mut scratch);
    Fingerprint {
        raw,
        corrected,
        activity: act_raw,
    }
}

/// Configs spanning the geometry space: default 64-row tiles, a lossy
/// ADC, a two-word 128-row tile, and a ragged three-word 192-row tile.
fn grid() -> Vec<PimConfig> {
    vec![
        PimConfig::default(),
        PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        },
        PimConfig {
            xbar: 128,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 8,
            ..Default::default()
        },
        PimConfig {
            xbar: 192,
            dac_bits: 1,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        },
    ]
}

#[test]
fn threads_1_and_n_are_bit_identical_across_configs() {
    for (ci, cfg) in grid().into_iter().enumerate() {
        let mut rng = Rng::new(100 + ci as u64);
        // enough tiles and columns to clear the kernel's serial-work
        // threshold, so the parallel path actually runs
        let wq = random_mat(&mut rng, 3 * cfg.xbar + 5, 48, (1 << (cfg.w_bits - 1)) - 1);
        let bx = BatchedXbar::program(&wq, cfg);
        let b = 16;
        let xs: Vec<i32> = (0..b * bx.k)
            .map(|_| rng.below(1u64 << cfg.x_bits) as i32)
            .collect();
        let serial = run(&bx, &xs, b, 1);
        for threads in [2usize, 4, 8] {
            let parallel = run(&bx, &xs, b, threads);
            assert_eq!(
                serial, parallel,
                "config {ci} ({cfg:?}): threads={threads} changed the result"
            );
        }
    }
}

#[test]
fn rerun_with_same_arena_is_stable() {
    let cfg = PimConfig::default();
    let mut rng = Rng::new(7);
    let wq = random_mat(&mut rng, 256, 32, 127);
    let bx = BatchedXbar::program(&wq, cfg);
    let b = 8;
    let xs: Vec<i32> = (0..b * bx.k).map(|_| rng.below(256) as i32).collect();
    let mut scratch = XbarScratch::with_threads(4);
    let mut a = vec![0i64; b * bx.n];
    let mut c = vec![0i64; b * bx.n];
    bx.mvm_batch(&xs, b, &mut a, &mut scratch);
    let act_first = scratch.activity;
    bx.mvm_batch(&xs, b, &mut c, &mut scratch);
    assert_eq!(a, c, "re-run through a warmed arena diverged");
    // counters accumulate linearly: second pass adds exactly one more
    assert_eq!(scratch.activity.read_cycles, 2 * act_first.read_cycles);
    assert_eq!(scratch.activity.adc_conversions, 2 * act_first.adc_conversions);
}

#[test]
fn net_and_engine_scores_survive_any_thread_count() {
    // the full serving stack on top of the kernel: PimNet / PimEngine
    // scores are a pure function of the inputs, threads notwithstanding
    let g = autorac_best("criteo");
    let (nd, ns, d) = (13usize, 26usize, 16usize);
    let mut net = build_pim_net(&g, nd, ns, d, 42).unwrap();
    let b = 6;
    let mut rng = Rng::new(9);
    let dense: Vec<f32> = (0..b * nd).map(|_| rng.normal() as f32).collect();
    let sparse: Vec<f32> =
        (0..b * ns * d).map(|_| (rng.normal() * 0.05) as f32).collect();
    let mut s1 = NetScratch::with_threads(1);
    let p1 = net.forward_batch(&dense, &sparse, b, &mut s1);
    for threads in [2usize, 4] {
        let mut st = NetScratch::with_threads(threads);
        let pt = net.forward_batch(&dense, &sparse, b, &mut st);
        assert!(
            p1.iter().zip(&pt).all(|(a, c)| a.to_bits() == c.to_bits()),
            "PimNet: threads={threads} changed scores"
        );
    }
    let mut e1 = PimEngine::new(&g, 8, nd, ns, d, 42).unwrap();
    let mut e4 = PimEngine::new(&g, 8, nd, ns, d, 42).unwrap().with_threads(4);
    let q1 = e1.infer_batch(&dense, &sparse, b).unwrap();
    let q4 = e4.infer_batch(&dense, &sparse, b).unwrap();
    assert!(q1.iter().zip(&q4).all(|(a, c)| a.to_bits() == c.to_bits()));
    assert_eq!(e1.activity(), e4.activity(), "engine activity diverged");
}
