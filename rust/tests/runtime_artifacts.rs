//! End-to-end artifact tests: load the AOT-compiled HLO on the PJRT CPU
//! client, run inference, and verify numerics against the python-side
//! golden probabilities (the full L1→L2→L3 triangle).
//!
//! Requires `make artifacts`; tests skip loudly when artifacts are
//! missing.

use autorac::data::{profile, Generator, Splits, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use autorac::util::json::Json;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("meta.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

/// Execution-dependent tests additionally need a real PJRT backend; the
/// offline build links the stub `runtime::xla` and must skip, not fail.
fn exec_dir() -> Option<PathBuf> {
    if !Runtime::pjrt_available() {
        eprintln!("SKIP: PJRT backend not linked (offline stub runtime::xla)");
        return None;
    }
    artifacts_dir()
}

#[test]
fn loads_meta_and_lists_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let names = rt.artifact_names();
    assert!(names.contains(&"model_criteo_b1"));
    assert!(names.contains(&"model_criteo_b32"));
    let m = rt.meta("model_criteo_b32").unwrap();
    assert_eq!(m.batch, 32);
    assert_eq!(m.kind, "inference");
}

#[test]
fn inference_matches_python_golden_probs() {
    let Some(dir) = exec_dir() else { return };
    let golden_path = dir.join("golden/probs_criteo.json");
    if !golden_path.exists() {
        eprintln!("SKIP: golden probs missing (re-run `make artifacts`)");
        return;
    }
    let golden = Json::read_file(&golden_path).unwrap();
    let test_off = golden.req_usize("test_offset").unwrap();
    assert_eq!(test_off, Splits::default().offset("test"));
    let want = golden.req_f64s("probs").unwrap();

    // Build the same padded batch-32 inputs the python golden used.
    let prof = profile("criteo").unwrap();
    let tf = TensorFile::read(&dir.join("embeddings_criteo.bin")).unwrap();
    let store = EmbeddingStore::from_atns(&tf).unwrap();
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let b = 32usize;
    let nd = prof.n_dense.max(1);
    let mut dense = vec![0f32; b * nd];
    let mut sparse = vec![0f32; b * prof.n_sparse() * store.d_emb];
    for i in 0..8 {
        let rec = gen.record(test_off + i);
        dense[i * nd..i * nd + prof.n_dense].copy_from_slice(&rec.dense);
        let mut gathered = Vec::new();
        let ids: Vec<i32> = rec.ids.iter().map(|&x| x as i32).collect();
        store.gather(&ids, 1, &mut gathered);
        let stride = prof.n_sparse() * store.d_emb;
        sparse[i * stride..(i + 1) * stride].copy_from_slice(&gathered);
    }

    let mut rt = Runtime::open(&dir).unwrap();
    let probs = rt
        .infer(
            "model_criteo_b32",
            &dense,
            [b, nd],
            &sparse,
            [b, prof.n_sparse(), store.d_emb],
        )
        .unwrap();
    assert_eq!(probs.len(), b);
    for (i, w) in want.iter().enumerate() {
        let got = probs[i] as f64;
        assert!(
            (got - w).abs() < 2e-3 + 1e-2 * w.abs(),
            "record {i}: rust {got} vs python {w}"
        );
        assert!((0.0..=1.0).contains(&got));
    }
}

#[test]
fn batch1_and_batch32_artifacts_agree_on_identical_composition() {
    // With per-tensor dynamic activation quantization, probs depend on
    // the batch composition — but a batch of 32 IDENTICAL rows must give
    // 32 identical outputs, each matching... itself. Sanity invariant.
    let Some(dir) = exec_dir() else { return };
    let prof = profile("criteo").unwrap();
    let tf = TensorFile::read(&dir.join("embeddings_criteo.bin")).unwrap();
    let store = EmbeddingStore::from_atns(&tf).unwrap();
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let rec = gen.record(5);
    let nd = prof.n_dense.max(1);
    let ids: Vec<i32> = rec.ids.iter().map(|&x| x as i32).collect();
    let mut row = Vec::new();
    store.gather(&ids, 1, &mut row);

    let b = 32usize;
    let dense: Vec<f32> = (0..b).flat_map(|_| rec.dense.clone()).collect();
    let sparse: Vec<f32> = (0..b).flat_map(|_| row.clone()).collect();
    let mut rt = Runtime::open(&dir).unwrap();
    let probs = rt
        .infer(
            "model_criteo_b32",
            &dense,
            [b, nd],
            &sparse,
            [b, prof.n_sparse(), store.d_emb],
        )
        .unwrap();
    for p in &probs {
        assert!((p - probs[0]).abs() < 1e-6, "{p} vs {}", probs[0]);
    }
}

#[test]
fn embeddings_artifact_matches_profile() {
    let Some(dir) = artifacts_dir() else { return };
    for ds in ["criteo", "avazu", "kdd"] {
        let tf = TensorFile::read(&dir.join(format!("embeddings_{ds}.bin"))).unwrap();
        let store = EmbeddingStore::from_atns(&tf).unwrap();
        let prof = profile(ds).unwrap();
        assert_eq!(store.n_fields(), prof.n_sparse());
        assert_eq!(store.cards, prof.cards);
        assert_eq!(store.d_emb, 32);
    }
}
