//! Property + integration tests for the hot-row cache tier and the
//! batch coalescer (ISSUE 7 tentpole): the cache is behaviour-invisible
//! (gathers bit-identical with it on, off, cold, or warm), the
//! `GatherStats` ledger always balances, occupancy never exceeds
//! capacity, and the end-to-end serving stack conserves OOV counts
//! through metrics.

use autorac::coordinator::loadgen::{self, Arrival, LoadGenConfig};
use autorac::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, MockEngine, Policy,
    ServingStore,
};
use autorac::data::{profile, ALL_PROFILES};
use autorac::embeddings::{
    head_rows_per_table, BatchGatherer, EmbeddingStore, HotCacheConfig,
    HotRowCache, ShardMap, ShardPolicy, ShardedStore,
};
use autorac::util::qcheck::{qcheck, Gen};
use autorac::{prop_assert, prop_assert_eq};
use std::sync::Arc;
use std::time::Duration;

const POLICIES: [ShardPolicy; 3] = [
    ShardPolicy::RoundRobinTables,
    ShardPolicy::CapacityBalanced,
    ShardPolicy::HotReplicated,
];

/// A batch of records over random field subsets with a hostile id mix:
/// in-range, duplicated-hot (small ids recur across records), negative
/// sentinels, and past-card overflows.
fn hostile_batch(
    g: &mut Gen,
    cards: &[usize],
    n_records: usize,
) -> Vec<(Vec<u32>, Vec<i32>)> {
    let nf = cards.len();
    (0..n_records)
        .map(|_| {
            let keep = g.usize(1, nf);
            let mut fields: Vec<u32> = (0..nf as u32).collect();
            g.rng().shuffle(&mut fields);
            fields.truncate(keep);
            fields.sort_unstable();
            let ids: Vec<i32> = fields
                .iter()
                .map(|&f| {
                    let c = cards[f as usize];
                    match g.usize(0, 9) {
                        0 => -1,
                        1 => i32::MIN,
                        2 => c as i32, // exactly card → OOV
                        // mostly small ids so duplicates + cache hits
                        // actually happen
                        _ => g.usize(0, (c - 1).min(7)) as i32,
                    }
                })
                .collect();
            (fields, ids)
        })
        .collect()
}

/// The tentpole invariant: the coalescing gather — with no cache, a
/// cold cache, or a warm prefetched cache — is bit-identical to the
/// per-record `ShardedStore::gather_from` path, and the ledger balances
/// with conserved oob counts.
#[test]
fn cache_on_off_and_coalescing_are_bit_identical() {
    qcheck(12, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let d_emb = *g.choose(&[4usize, 8]);
        let seed = g.u64(0, 1 << 40);
        let n_shards = g.usize(1, 4);
        let policy = *g.choose(&POLICIES);
        let map = ShardMap::for_profile(&p, n_shards, policy);
        let store = ShardedStore::random(&p, d_emb, seed, map);
        let local = g.usize(0, n_shards - 1);
        let batch = hostile_batch(g, &p.cards, g.usize(2, 12));

        // reference: per-record gather_from
        let mut want = Vec::new();
        let (mut wl, mut wr, mut woob) = (0usize, 0usize, 0usize);
        for (fields, ids) in &batch {
            let (l, r, o) = store.gather_from(local, fields, ids, &mut want);
            wl += l;
            wr += r;
            woob += o;
        }

        let caches = [
            None,
            Some(HotRowCache::new(
                &store,
                p.zipf_alpha,
                HotCacheConfig {
                    capacity: g.usize(1, 256),
                    prefetch: true,
                },
            )),
            Some(HotRowCache::new(
                &store,
                p.zipf_alpha,
                HotCacheConfig {
                    capacity: 64,
                    prefetch: false, // cold: everything misses
                },
            )),
        ];
        for cache in &caches {
            let mut gatherer = BatchGatherer::new(&store.cards);
            let mut got = Vec::new();
            let st = gatherer.gather_batch(
                &store,
                cache.as_ref(),
                local,
                batch.iter().map(|(f, i)| (f.as_slice(), i.as_slice())),
                &mut got,
            );
            prop_assert!(
                got == want,
                "gather diverges (cache {:?}, policy {policy:?})",
                cache.as_ref().map(|c| c.len())
            );
            prop_assert_eq!(st.oob, woob);
            prop_assert_eq!(st.requested, wl + wr);
            prop_assert!(st.balanced(), "unbalanced ledger: {st:?}");
            if let Some(c) = cache.as_ref() {
                // hits + misses == unique rows consulted, and misses are
                // exactly what fell through to the shards
                prop_assert_eq!(
                    st.cache_hits + st.cache_misses,
                    st.requested - st.coalesced
                );
                prop_assert_eq!(st.cache_misses, st.local + st.remote);
                if c.is_empty() {
                    prop_assert_eq!(st.cache_hits, 0);
                }
            } else {
                prop_assert_eq!(st.cache_hits + st.cache_misses, 0);
                prop_assert_eq!(st.requested, st.local + st.remote + st.coalesced);
            }
        }
        Ok(())
    });
}

/// The same gatherer reused across many batches (the worker lifecycle)
/// stays correct — the epoch-stamp dedup must never leak residency
/// across batches.
#[test]
fn gatherer_reuse_across_batches_matches_fresh_gathers() {
    qcheck(8, |g| {
        let p = profile("kdd").unwrap();
        let map = ShardMap::for_profile(&p, 3, ShardPolicy::HotReplicated);
        let store = ShardedStore::random(&p, 8, g.u64(0, 1 << 40), map);
        let cache = HotRowCache::new(
            &store,
            p.zipf_alpha,
            HotCacheConfig {
                capacity: 128,
                prefetch: true,
            },
        );
        let mut gatherer = BatchGatherer::new(&store.cards);
        for _ in 0..5 {
            let batch = hostile_batch(g, &p.cards, g.usize(1, 6));
            let mut want = Vec::new();
            for (fields, ids) in &batch {
                store.gather_from(1, fields, ids, &mut want);
            }
            let mut got = Vec::new();
            let st = gatherer.gather_batch(
                &store,
                Some(&cache),
                1,
                batch.iter().map(|(f, i)| (f.as_slice(), i.as_slice())),
                &mut got,
            );
            prop_assert!(got == want, "stale dedup state leaked across batches");
            prop_assert!(st.balanced());
        }
        Ok(())
    });
}

/// Occupancy is bounded by capacity under arbitrary offer streams, and
/// prefetch fills to min(capacity, total) without a single eviction
/// (the head set is sized to capacity up front). Priority-ordered
/// eviction itself is pinned by the unit tests in `hotcache.rs`.
#[test]
fn occupancy_is_bounded_and_prefetch_never_evicts() {
    qcheck(15, |g| {
        let name = *g.choose(&ALL_PROFILES);
        let p = profile(name).unwrap();
        let map = ShardMap::for_profile(&p, 2, ShardPolicy::CapacityBalanced);
        let store = ShardedStore::random(&p, 4, g.u64(0, 1 << 40), map);
        let capacity = g.usize(1, 48);
        let mut cache = HotRowCache::new(
            &store,
            p.zipf_alpha,
            HotCacheConfig {
                capacity,
                prefetch: false,
            },
        );
        prop_assert_eq!(cache.len(), 0);
        for _ in 0..g.usize(20, 120) {
            let j = g.usize(0, p.cards.len() - 1);
            let id = g.usize(0, p.cards[j] - 1);
            cache.offer(&store, j, id);
            prop_assert!(
                cache.len() <= cache.capacity(),
                "occupancy {} over capacity {}",
                cache.len(),
                cache.capacity()
            );
        }
        // a warm prefetch never evicts and fills to min(capacity, total)
        let warm = HotRowCache::new(
            &store,
            p.zipf_alpha,
            HotCacheConfig {
                capacity,
                prefetch: true,
            },
        );
        prop_assert_eq!(warm.len(), capacity.min(store.total_rows()));
        prop_assert_eq!(warm.stats.evictions(), 0);
        Ok(())
    });
}

/// `head_rows_per_table` is conserved (sums to min(n, total)), bounded
/// per table, and prefix-shaped: the predicted head of each table is
/// its first rows, never a gap.
#[test]
fn head_set_prediction_is_conserved_and_prefix_shaped() {
    qcheck(30, |g| {
        let nt = g.usize(1, 20);
        let cards: Vec<usize> = (0..nt).map(|_| g.usize(1, 400)).collect();
        let alpha = g.f64(1.05, 1.5);
        let n = g.usize(0, 600);
        let total: usize = cards.iter().sum();
        let head = head_rows_per_table(&cards, alpha, n);
        prop_assert_eq!(head.len(), nt);
        prop_assert_eq!(head.iter().sum::<usize>(), n.min(total));
        for (j, &h) in head.iter().enumerate() {
            prop_assert!(h <= cards[j], "table {j} head {h} > card");
        }
        Ok(())
    });
}

/// The acceptance-criteria test: ids `{-1, i32::MIN, card, card+7}`
/// through the monolithic, sharded, and cached paths all return the
/// row-0 OOV embedding bit-identically, with the oob count conserved on
/// every path.
#[test]
fn hostile_ids_resolve_to_row_zero_on_every_path() {
    for name in ALL_PROFILES {
        let p = profile(name).unwrap();
        let d_emb = 8;
        let seed = 1234;
        let mono = EmbeddingStore::random(&p, d_emb, seed);
        let map = ShardMap::for_profile(&p, 3, ShardPolicy::HotReplicated);
        let store = ShardedStore::random(&p, d_emb, seed, map);
        let cache = HotRowCache::new(
            &store,
            p.zipf_alpha,
            HotCacheConfig {
                capacity: 512,
                prefetch: true,
            },
        );
        let nf = p.n_sparse();
        let fields: Vec<u32> = (0..nf as u32).collect();
        let make_ids = |pick: fn(usize) -> i32| -> Vec<i32> {
            p.cards.iter().map(|&c| pick(c)).collect()
        };
        let hostile: [Vec<i32>; 4] = [
            make_ids(|_| -1),
            make_ids(|_| i32::MIN),
            make_ids(|c| c as i32),
            make_ids(|c| (c + 7) as i32),
        ];
        for ids in &hostile {
            let mut a = Vec::new();
            let mono_oob = mono.gather_fields(&fields, ids, &mut a);
            let mut b = Vec::new();
            let (_, _, sh_oob) = store.gather_from(0, &fields, ids, &mut b);
            let mut c = Vec::new();
            let st = BatchGatherer::new(&store.cards).gather_batch(
                &store,
                Some(&cache),
                0,
                std::iter::once((fields.as_slice(), ids.as_slice())),
                &mut c,
            );
            assert_eq!(mono_oob, nf, "{name}: every id must count as OOV");
            assert_eq!(sh_oob, nf);
            assert_eq!(st.oob, nf);
            assert!(a == b && b == c, "{name}: OOV gather diverges");
            for j in 0..nf {
                assert_eq!(
                    &a[j * d_emb..(j + 1) * d_emb],
                    mono.row(j, 0),
                    "{name}: table {j} did not serve the row-0 OOV embedding"
                );
            }
        }
    }
}

/// End-to-end: a Coordinator over `ServingStore::Cached` serving
/// deterministic skewed traffic with injected OOV sentinels — cache
/// counters move, the coalescer fires, responses are conserved, and
/// `oob_ids` lands in the metrics snapshot.
#[test]
fn cached_serving_stack_reports_cache_and_oov_metrics() {
    let p = profile("kdd").unwrap();
    let map = ShardMap::for_profile(&p, 2, ShardPolicy::HotReplicated);
    let store = Arc::new(ShardedStore::random(&p, 8, 7, map));
    let cache = Arc::new(HotRowCache::new(
        &store,
        p.zipf_alpha,
        HotCacheConfig {
            capacity: 256,
            prefetch: true,
        },
    ));
    let coord = Coordinator::start_with(
        CoordinatorConfig {
            n_workers: 2,
            policy: Policy::ShardAffinity,
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
            ..Default::default()
        },
        ServingStore::Cached(store, cache),
        |_| Ok(Box::new(MockEngine::new(16, p.n_dense, 10, 8))),
    )
    .unwrap();
    let cfg = LoadGenConfig {
        n_requests: 400,
        arrival: Arrival::ClosedLoop { concurrency: 32 },
        seed: 23,
        coverage: 0.6,
        oov_frac: 0.1,
    };
    let rep = loadgen::run(&coord, &p, &cfg).unwrap();
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    assert_eq!(rep.sent, 400);
    assert_eq!(rep.completed, 400, "closed loop completes everything");
    assert!(
        snap.cache_hits > 0,
        "zipf head traffic against a 256-row prefetched cache must hit"
    );
    assert!(
        snap.oob_ids > 0,
        "oov_frac 0.1 over 400 requests must inject sentinels"
    );
    // ledger: every requested row was served exactly once
    let served =
        snap.cache_hits + snap.local_rows + snap.remote_rows + snap.coalesced_rows;
    assert!(served > 0);
    assert_eq!(
        snap.cache_misses,
        snap.local_rows + snap.remote_rows,
        "misses are exactly the rows that fell through to the shards"
    );
}
