//! # AutoRAC
//!
//! A from-scratch reproduction of *AutoRAC: Automated Processing-in-Memory
//! Accelerator Design for Recommender Systems* (GLSVLSI '25) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the co-design framework: ReRAM PIM behavioral
//!   simulator, operator→crossbar mapping engine, regularized-evolution
//!   search (Algorithm 1), embedding memory tiles, baseline accelerator
//!   models, and a CTR serving coordinator executing AOT-compiled model
//!   artifacts via PJRT.
//! * **L2/L1 (python/, build-time only)** — JAX recommender models and
//!   Pallas PIM kernels, lowered once to HLO text in `artifacts/`.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod mapping;
pub mod metrics;
pub mod nas;
pub mod embeddings;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

// Root-level error re-exports (the pattern anyhow used): new code can
// write `autorac::Result` / `autorac::Error` instead of the full
// `util::error` path; the `err!`/`bail!`/`ensure!` macros already live
// here via `#[macro_export]`.
pub use util::error::{Context, Error, Result};
