//! Experiment drivers + table/figure formatters (S17).
//!
//! Each paper artifact (Table 2/3, Figures 2/5/6) has one entry point
//! here, shared by the `autorac` CLI and the `cargo bench` harnesses so
//! the numbers in EXPERIMENTS.md regenerate from exactly one code path.

use crate::baselines::{genome_stats_pooled, CpuModel, RecNmpModel, TABLE3_POOLING};
use crate::data::profile;
use crate::embeddings::{EmbeddingStore, MemoryTileModel, Placement, Strategy};
use crate::mapping::{map_genome, MapStyle};
use crate::nas::{autorac_best, nasrec_like, Genome, ParallelSearch, SearchConfig, Surrogate};
use crate::pim::TechParams;
use crate::sim::{simulate, EmbeddingFrontend, SimReport, Workload};
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};
use std::path::Path;

// ---------------------------------------------------------------------------
// Table 2 — model accuracy
// ---------------------------------------------------------------------------

/// Print Table 2 from the calibration artifacts. Returns the JSON blob.
pub fn table2(artifacts: &Path) -> crate::Result<Json> {
    let acc = Json::read_file(&artifacts.join("calibration/accuracy.json"))?;
    let order = [
        ("dlrm", "DLRM [15]"),
        ("xdeepfm", "xDeepFM [11]"),
        ("autoint+", "AutoInt+ [19]"),
        ("deepfm", "DeepFM [3]"),
        ("nasrec", "NASRec [32]"),
        ("autorac", "AutoRAC"),
    ];
    println!("\nTable 2: Performance of AutoRAC on CTR tasks (synthetic stand-ins)");
    println!(
        "{:<14} {:>9} {:>8} {:>9} {:>8} {:>9} {:>8}",
        "Method", "Criteo LL", "AUC", "Avazu LL", "AUC", "KDD LL", "AUC"
    );
    for (key, label) in order {
        let mut row = format!("{label:<14}");
        for ds in ["criteo", "avazu", "kdd"] {
            if let Some(m) = acc.get(ds).and_then(|d| d.get(key)) {
                row += &format!(
                    " {:>9.4} {:>8.4}",
                    m.req_f64("logloss")?,
                    m.req_f64("auc")?
                );
            } else {
                row += &format!(" {:>9} {:>8}", "-", "-");
            }
        }
        println!("{row}");
    }
    Ok(acc)
}

// ---------------------------------------------------------------------------
// Table 3 — hardware metrics
// ---------------------------------------------------------------------------

/// The shared Table 3 embedding front-end: real-scale memory tiles with
/// access-aware placement and a pooled (multi-hot) gather batch.
pub fn table3_frontend(
    dataset: &str,
    tech: &TechParams,
) -> crate::Result<(MemoryTileModel, Placement, Vec<usize>)> {
    let prof = profile(dataset)?;
    let store = EmbeddingStore::random(&prof, 32, 1);
    let rows_total = MemoryTileModel::real_scale_rows(dataset);
    let n_banks = MemoryTileModel::banks_for(rows_total, 32, 32);
    let tiles = MemoryTileModel::with_rows(rows_total, 32, n_banks, tech);
    let freqs = Placement::zipf_freqs(&store.cards, prof.zipf_alpha);
    let placement = Placement::build(&freqs, n_banks, Strategy::AccessAware);
    // one pooled gather batch (dedup: row buffers coalesce repeats)
    let mut rng = Rng::new(3);
    let mut rows = Vec::new();
    for j in 0..store.n_fields() {
        let z = Zipf::new(store.cards[j], prof.zipf_alpha);
        for _ in 0..TABLE3_POOLING {
            rows.push(store.global_row(j, z.sample(&mut rng)));
        }
    }
    rows.sort_unstable();
    rows.dedup();
    Ok((tiles, placement, rows))
}

#[derive(Clone, Debug)]
pub struct Table3Row {
    pub against: String,
    pub area_saving: Option<f64>,
    pub power_eff: f64,
    pub speedup: f64,
    pub paper: (Option<f64>, f64, f64),
}

/// Compute Table 3 (AutoRAC vs CPU / RecNMP / naive-NASRec / ReREC).
pub fn table3(dataset: &str) -> crate::Result<(Vec<Table3Row>, SimReport)> {
    let tech = TechParams::default();
    let wl = Workload::default();
    let (tiles, placement, rows) = table3_frontend(dataset, &tech)?;
    let gather = tiles.gather_cost(&rows, &placement);
    let fe = EmbeddingFrontend {
        tiles: &tiles,
        placement: &placement,
        gather,
    };

    let auto = simulate(
        &map_genome(&autorac_best(dataset), &tech, MapStyle::Smart)?,
        Some(&fe),
        &wl,
    );
    let nasrec = simulate(
        &map_genome(&nasrec_like(dataset), &tech, MapStyle::Naive)?,
        Some(&fe),
        &wl,
    );
    let rerec = simulate(
        &crate::baselines::rerec_model(dataset, &tech)?,
        Some(&fe),
        &wl,
    );
    let w = genome_stats_pooled(&autorac_best(dataset), TABLE3_POOLING)?;
    let cpu = CpuModel::default().report(&w, 16);
    let nmp = RecNmpModel::default().report(&w, 16);

    let rows = vec![
        Table3Row {
            against: "CPU".into(),
            area_saving: None,
            power_eff: auto.power_eff_vs(&cpu),
            speedup: auto.speedup_vs(&cpu),
            paper: (None, 66.87, 22.83),
        },
        Table3Row {
            against: "RecNMP [9]".into(),
            area_saving: None,
            power_eff: auto.power_eff_vs(&nmp),
            speedup: auto.speedup_vs(&nmp),
            paper: (None, 12.48, 3.36),
        },
        Table3Row {
            against: "NASRec [32]".into(),
            area_saving: Some(auto.area_saving_vs(&nasrec)),
            power_eff: auto.power_eff_vs(&nasrec),
            speedup: auto.speedup_vs(&nasrec),
            paper: (Some(1.68), 2.39, 3.17),
        },
        Table3Row {
            against: "ReREC [22]".into(),
            area_saving: None,
            power_eff: auto.power_eff_vs(&rerec),
            speedup: auto.speedup_vs(&rerec),
            paper: (None, 1.57, 1.28),
        },
    ];
    println!("\nTable 3: hardware metrics of AutoRAC against baselines ({dataset})");
    println!(
        "{:<14} {:>12} {:>18} {:>16}",
        "Against", "Area Savings", "Power Efficiency", "Speedup"
    );
    for r in &rows {
        println!(
            "{:<14} {:>12} {:>9.2}x (paper {:>5.2}) {:>7.2}x (paper {:>5.2})",
            r.against,
            r.area_saving
                .map(|a| format!("{a:.2}x"))
                .unwrap_or_else(|| "-".into()),
            r.power_eff,
            r.paper.1,
            r.speedup,
            r.paper.2,
        );
    }
    println!(
        "AutoRAC: {:.0} inf/s | {:.2} W | compute {:.2} mm² (+{:.1} mm² memory tiles)",
        auto.throughput_rps,
        auto.power_mw / 1e3,
        auto.area_mm2,
        auto.mem_area_mm2
    );
    Ok((rows, auto))
}

// ---------------------------------------------------------------------------
// Figure 2 — LogLoss vs weight bit-width
// ---------------------------------------------------------------------------

pub fn fig2(artifacts: &Path) -> crate::Result<Vec<(usize, f64)>> {
    let j = Json::read_file(&artifacts.join("calibration/fig2.json"))?;
    let mut pts: Vec<(usize, f64)> = j
        .as_obj()
        .unwrap_or(&[])
        .iter()
        .filter_map(|(k, v)| Some((k.parse().ok()?, v.as_f64()?)))
        .collect();
    pts.sort_by(|a, b| b.0.cmp(&a.0));
    println!("\nFigure 2: Criteo test LogLoss vs weight bit-width");
    let min = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let max = pts.iter().map(|p| p.1).fold(0.0, f64::max);
    for (bits, ll) in &pts {
        let frac = if max > min { (ll - min) / (max - min) } else { 0.0 };
        let bar = "#".repeat(4 + (40.0 * frac) as usize);
        println!("  {bits:>2} bits  {ll:.4}  {bar}");
    }
    Ok(pts)
}

// ---------------------------------------------------------------------------
// Figure 5 — search criterion trajectory
// ---------------------------------------------------------------------------

pub fn fig5(cfg: SearchConfig) -> crate::Result<(Vec<f64>, Genome)> {
    let mut search = ParallelSearch::new(cfg, Surrogate::load_default())?;
    let best = search.run()?;
    let drop = search.trace.pct_drop();
    let cs = search.cache_stats();
    println!(
        "\nFigure 5: % criterion drop over {} generations ({} evaluations, \
         {} worker(s), cache hit-rate {:.1}%)",
        drop.len() - 1,
        search.trace.evaluations,
        search.cfg.workers.max(1),
        100.0 * cs.hit_rate()
    );
    let step = (drop.len() / 24).max(1);
    let worst = drop.iter().copied().fold(0.0f64, f64::min);
    for (g, d) in drop.iter().enumerate().step_by(step) {
        let frac = if worst < 0.0 { d / worst } else { 0.0 };
        let bar = "#".repeat((46.0 * frac) as usize);
        println!("  gen {g:>4}  {d:>7.2}%  {bar}");
    }
    println!(
        "best criterion {:.4} (loss {:.4}, 1/thr {:.3e}, area {:.2} mm², power {:.0} mW)",
        best.criterion, best.test_loss, best.metrics[0], best.metrics[1], best.metrics[2]
    );
    Ok((drop, best.genome))
}

// ---------------------------------------------------------------------------
// Figure 6 — best discovered architecture
// ---------------------------------------------------------------------------

pub fn fig6(genome: &Genome) -> String {
    use crate::nas::{DenseOp, Interaction, SparseOp};
    let mut out = String::new();
    out += &format!(
        "\nFigure 6: best model discovered ({}, d_emb={}, PIM xbar={} dac={} cell={} adc={})\n",
        genome.name,
        genome.d_emb,
        genome.pim.xbar,
        genome.pim.dac_bits,
        genome.pim.cell_bits,
        genome.pim.adc_bits
    );
    for (i, b) in genome.blocks.iter().enumerate() {
        let dense = match b.dense_op {
            DenseOp::Fc => format!("FC-{}({}b)", b.dense_dim, b.dense_wbits),
            DenseOp::Dp => format!("DP-{}({}b)", b.dense_dim, b.dense_wbits),
        };
        let sparse = match b.sparse_op {
            SparseOp::Efc => format!("EFC-{}({}b)", b.sparse_features, b.sparse_wbits),
            SparseOp::Identity => "pass".to_string(),
        };
        let inter = match b.interaction {
            Interaction::None => "".to_string(),
            Interaction::Fm => format!(" + FM({}b)", b.inter_wbits),
            Interaction::Dsi => format!(" + DSI({}b)", b.inter_wbits),
        };
        out += &format!(
            "  block {i}: dense[{}]◄{:?}  sparse[{}]◄{:?}{}\n",
            dense, b.dense_in, sparse, b.sparse_in, inter
        );
    }
    out += &format!("  final FC ({}b) → sigmoid\n", genome.final_wbits);
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_rows_reproduce_paper_shape() {
        let (rows, auto) = table3("criteo").unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.speedup > 1.0, "{}: speedup {}", r.against, r.speedup);
            assert!(r.power_eff > 1.0, "{}: powereff {}", r.against, r.power_eff);
            // within 3× of the paper's factor on the speedup axis
            let ratio = r.speedup / r.paper.2;
            assert!(
                (0.33..3.0).contains(&ratio),
                "{}: speedup {} vs paper {}",
                r.against,
                r.speedup,
                r.paper.2
            );
        }
        assert!(auto.throughput_rps > 1e5);
    }

    #[test]
    fn fig6_renders_reference_genome() {
        let s = fig6(&autorac_best("criteo"));
        assert!(s.contains("block 0"));
        assert!(s.contains("final FC"));
        assert!(s.contains("FM"));
    }

    #[test]
    fn fig5_quick_search_improves() {
        let cfg = SearchConfig {
            generations: 8,
            population: 10,
            children_per_gen: 4,
            sample_size: 4,
            sim_requests: 16,
            ..SearchConfig::default()
        };
        let (drop, best) = fig5(cfg).unwrap();
        assert_eq!(drop[0], 0.0);
        assert!(*drop.last().unwrap() <= 0.0);
        best.validate().unwrap();
    }
}
