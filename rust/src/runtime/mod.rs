//! Runtime (S14): PJRT client wrapper + artifact registry + ATNS reader.
//! Python never runs here — artifacts were lowered at build time.

pub mod atns;
pub mod client;
