//! Runtime (S14): PJRT client wrapper + artifact registry + ATNS reader.
//! Python never runs here — artifacts were lowered at build time.

pub mod atns;
pub mod client;
// Offline PJRT stand-in. To link the real bindings instead, replace this
// line with `pub use ::xla;` and add the crate to Cargo.toml — client.rs
// is written against the real API surface.
pub mod xla;
