//! ATNS binary tensor container — rust reader (writer lives in
//! `python/compile/atns.py`; see that module for the format spec).
//!
//! Used for trained embedding tables (memory tiles) and the train-step
//! initial parameters (e2e example). Little-endian throughout.

use std::io::Read;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I64,
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// raw little-endian payload
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> crate::Result<Vec<f32>> {
        crate::ensure!(self.dtype == Dtype::F32, "{}: not f32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> crate::Result<Vec<i32>> {
        crate::ensure!(self.dtype == Dtype::I32, "{}: not i32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: Vec<Tensor>,
}

impl TensorFile {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn read(path: &std::path::Path) -> crate::Result<TensorFile> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| crate::err!("opening {}: {e}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).map_err(|e| crate::err!("{}: {e}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> crate::Result<TensorFile> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> crate::Result<&[u8]> {
            crate::ensure!(*pos + n <= buf.len(), "truncated at byte {}", *pos);
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let u32le = |pos: &mut usize| -> crate::Result<u32> {
            let b = take(pos, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        crate::ensure!(take(&mut pos, 4)? == b"ATNS", "bad magic");
        let version = u32le(&mut pos)?;
        crate::ensure!(version == 1, "unsupported version {version}");
        let count = u32le(&mut pos)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = u32le(&mut pos)? as usize;
            let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())?;
            let hdr = take(&mut pos, 2)?;
            let dtype = match hdr[0] {
                0 => Dtype::F32,
                1 => Dtype::I32,
                2 => Dtype::I64,
                d => crate::bail!("{name}: unknown dtype {d}"),
            };
            let ndim = hdr[1] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32le(&mut pos)? as usize);
            }
            let nbytes = {
                let b = take(&mut pos, 8)?;
                u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
                    as usize
            };
            let elem = match dtype {
                Dtype::F32 | Dtype::I32 => 4,
                Dtype::I64 => 8,
            };
            let expect: usize = shape.iter().product::<usize>() * elem;
            crate::ensure!(
                nbytes == expect,
                "{name}: payload {nbytes} != shape {shape:?} × {elem}"
            );
            let data = take(&mut pos, nbytes)?.to_vec();
            tensors.push(Tensor {
                name,
                dtype,
                shape,
                data,
            });
        }
        crate::ensure!(pos == buf.len(), "trailing bytes after last tensor");
        Ok(TensorFile { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an ATNS byte blob (mirrors the python writer; also used by
    /// other test modules).
    pub fn write_atns(tensors: &[(&str, Dtype, Vec<usize>, Vec<u8>)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(b"ATNS");
        out.extend(1u32.to_le_bytes());
        out.extend((tensors.len() as u32).to_le_bytes());
        for (name, dtype, shape, data) in tensors {
            out.extend((name.len() as u32).to_le_bytes());
            out.extend(name.as_bytes());
            out.push(match dtype {
                Dtype::F32 => 0,
                Dtype::I32 => 1,
                Dtype::I64 => 2,
            });
            out.push(shape.len() as u8);
            for &d in shape {
                out.extend((d as u32).to_le_bytes());
            }
            out.extend((data.len() as u64).to_le_bytes());
            out.extend(data);
        }
        out
    }

    #[test]
    fn roundtrip() {
        let vals: Vec<u8> = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let blob = write_atns(&[("emb/0", Dtype::F32, vec![2, 3], vals)]);
        let tf = TensorFile::parse(&blob).unwrap();
        assert_eq!(tf.tensors.len(), 1);
        let t = tf.get("emb/0").unwrap();
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(TensorFile::parse(b"NOPE").is_err());
        let blob = write_atns(&[("x", Dtype::F32, vec![1], 0f32.to_le_bytes().to_vec())]);
        assert!(TensorFile::parse(&blob[..blob.len() - 1]).is_err());
    }

    #[test]
    fn rejects_shape_payload_mismatch() {
        let blob = write_atns(&[("x", Dtype::F32, vec![3], vec![0u8; 8])]);
        assert!(TensorFile::parse(&blob).is_err());
    }

    #[test]
    fn dtype_guards() {
        let blob = write_atns(&[("x", Dtype::I32, vec![1], 7i32.to_le_bytes().to_vec())]);
        let tf = TensorFile::parse(&blob).unwrap();
        assert!(tf.get("x").unwrap().as_f32().is_err());
        assert_eq!(tf.get("x").unwrap().as_i32().unwrap(), vec![7]);
    }
}
