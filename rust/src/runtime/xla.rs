//! Offline stand-in for the `xla` PJRT binding crate (DESIGN.md §8,
//! docs/adr/001-offline-zero-deps.md).
//!
//! The build environment has no crates.io access and no PJRT shared
//! library, so this module reproduces exactly the API surface
//! `runtime::client` and the examples consume. Artifact *metadata* and
//! HLO text files can be opened and validated; `compile` (and therefore
//! execution) reports a clear error. Swapping in the real bindings is a
//! one-line change in `runtime/mod.rs` (`pub use ::xla;` instead of
//! `pub mod xla;`) — the call sites are already written against the real
//! crate's types. Tests that need execution gate on
//! [`AVAILABLE`] via `Runtime::pjrt_available()` and skip cleanly here.

/// Whether a real PJRT backend is linked in. The stub is never able to
/// execute; artifact-gated tests skip when this is `false`.
pub const AVAILABLE: bool = false;

/// Error type mirroring the binding crate's (consumed via `{:?}`).
#[derive(Clone, Debug)]
pub struct XlaError {
    pub msg: String,
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

impl From<XlaError> for crate::util::error::Error {
    fn from(e: XlaError) -> Self {
        crate::util::error::Error::msg(e.msg)
    }
}

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what}: PJRT backend not linked in this offline build \
             (stub runtime::xla; see DESIGN.md §8)"
        ),
    }
}

/// Element types a [`Literal`] can hold (the subset this crate feeds).
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Sealed helper so `Literal::vec1` / `to_vec` are generic over f32/i32
/// like the real crate's `NativeType`.
pub trait NativeType: Sized + Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor value. Fully functional in the stub (the literal
/// builders and their shape validation are pure host code).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    /// Reshape into a new literal (the stub clones the element buffer —
    /// fine off the real execution path); errors on element-count mismatch.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let expect: i64 = dims.iter().product();
        if expect as usize != self.len() {
            return Err(XlaError {
                msg: format!(
                    "reshape: {} elements cannot take shape {dims:?}",
                    self.len()
                ),
            });
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as `T` (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data).ok_or_else(|| XlaError {
            msg: "to_vec: literal holds a different element type".to_string(),
        })
    }

    /// Flatten a tuple literal. The stub never produces tuples (it never
    /// executes), so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("to_tuple"))
    }
}

/// Parsed HLO module handle. The stub validates that the artifact file
/// exists and is readable UTF-8 text, which keeps `autorac artifacts`
/// and registry listings honest without a compiler behind them.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text_len: usize,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path).map_err(|e| XlaError {
            msg: format!("reading HLO text {path}: {e}"),
        })?;
        Ok(HloModuleProto {
            text_len: text.len(),
        })
    }
}

/// Computation handle built from a parsed HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text_len: proto.text_len,
        }
    }
}

/// PJRT client handle. Construction succeeds (it is just a host handle)
/// so registries open and list; `compile` is where the stub stops.
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient {
            platform: "cpu (offline stub — no PJRT linked)".to_string(),
        })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compile"))
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle. Unreachable in the stub (compile errors),
/// but the full call-site API type-checks against it.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn i32_literals_work() {
        let l = Literal::vec1(&[5i32, 6]).reshape(&[2, 1]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, 6]);
    }

    #[test]
    fn client_opens_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        let comp = XlaComputation { text_len: 0 };
        let e = c.compile(&comp).unwrap_err();
        assert!(format!("{e:?}").contains("PJRT backend not linked"), "{e:?}");
        assert!(!AVAILABLE);
    }
}
