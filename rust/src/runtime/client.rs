//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the CPU PJRT client. Python never runs here — this is the request
//! path. Pattern follows /opt/xla-example/load_hlo (HLO TEXT interchange;
//! see that README for why serialized protos are rejected).

use crate::runtime::xla;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Artifact metadata (from artifacts/meta.json).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub dataset: String,
    pub batch: usize,
    /// train-step artifacts: parameter feed order
    pub param_order: Vec<String>,
}

/// The artifact registry + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Whether a real PJRT backend is linked in. `false` in the offline
    /// build (stub `runtime::xla`): registries open and artifacts parse,
    /// but compilation/execution is unavailable — execution-dependent
    /// tests and flows gate on this and skip cleanly.
    pub fn pjrt_available() -> bool {
        xla::AVAILABLE
    }

    /// Open the artifact directory (reads meta.json; compiles lazily).
    pub fn open(dir: &Path) -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| crate::err!("PJRT cpu client: {e:?}"))?;
        let meta = Json::read_file(&dir.join("meta.json"))?;
        let mut metas = HashMap::new();
        if let Some(arts) = meta.get("artifacts").and_then(Json::as_obj) {
            for (name, a) in arts {
                metas.insert(
                    name.clone(),
                    ArtifactMeta {
                        name: name.clone(),
                        kind: a.req_str("kind")?.to_string(),
                        dataset: a.req_str("dataset")?.to_string(),
                        batch: a.req_usize("batch")?,
                        param_order: a
                            .get("param_order")
                            .and_then(Json::as_arr)
                            .map(|v| {
                                v.iter()
                                    .filter_map(|s| s.as_str())
                                    .map(String::from)
                                    .collect()
                            })
                            .unwrap_or_default(),
                    },
                );
            }
        }
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            metas,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.metas.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Inference artifact name for (dataset, batch).
    pub fn model_name(dataset: &str, batch: usize) -> String {
        format!("model_{dataset}_b{batch}")
    }

    /// Compile (once) and cache an artifact's executable.
    pub fn ensure_compiled(&mut self, name: &str) -> crate::Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        crate::ensure!(path.exists(), "missing artifact {}", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )
        .map_err(|e| crate::err!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::err!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.executables.get(name).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| crate::err!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| crate::err!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| crate::err!("untuple {name}: {e:?}"))
    }

    /// Run batched CTR inference: dense `[B×nd]` and gathered sparse
    /// `[B×Ns×d]` row-major f32 → probabilities `[B]`.
    pub fn infer(
        &mut self,
        name: &str,
        dense: &[f32],
        dense_dims: [usize; 2],
        sparse: &[f32],
        sparse_dims: [usize; 3],
    ) -> crate::Result<Vec<f32>> {
        let d_lit = lit_f32(dense, &[dense_dims[0] as i64, dense_dims[1] as i64])?;
        let s_lit = lit_f32(
            sparse,
            &[
                sparse_dims[0] as i64,
                sparse_dims[1] as i64,
                sparse_dims[2] as i64,
            ],
        )?;
        let out = self.execute(name, &[d_lit, s_lit])?;
        crate::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        out[0]
            .to_vec::<f32>()
            .map_err(|e| crate::err!("probs: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a row-major slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    crate::ensure!(
        expect as usize == data.len(),
        "shape {dims:?} != {} elements",
        data.len()
    );
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| crate::err!("reshape {dims:?}: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    crate::ensure!(
        expect as usize == data.len(),
        "shape {dims:?} != {} elements",
        data.len()
    );
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| crate::err!("reshape {dims:?}: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_validate_shapes() {
        assert!(lit_f32(&[1.0, 2.0], &[2, 1]).is_ok());
        assert!(lit_f32(&[1.0, 2.0], &[3, 1]).is_err());
        assert!(lit_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }

    // Artifact-dependent tests live in rust/tests/runtime_artifacts.rs
    // (they need `make artifacts` to have run).
}
