//! Memory-tile cost model: what one batched embedding gather costs in
//! latency/energy given the bank placement (the paper's memory tiles are
//! ReRAM used as dense storage, read-only at inference).

use super::placement::Placement;
use super::store::EmbeddingStore;
use crate::pim::{Buffer, TechParams};

/// Gather cost for one request (all fields of one record) or one batch.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GatherCost {
    pub latency_ns: f64,
    pub energy_pj: f64,
    /// bank-conflict serialization depth that produced the latency
    pub conflict_depth: usize,
}

/// Priced memory-tile array for one dataset.
pub struct MemoryTileModel {
    pub n_banks: usize,
    /// one bank's row buffer+array access characteristics
    pub bank: Buffer,
    pub row_bytes: usize,
    pub area_mm2: f64,
    pub leakage_mw: f64,
    /// one embedding-row activation: the bank reads a full row-width
    /// line in a single array access (ReRAM storage mode)
    pub row_act_ns: f64,
    pub row_read_pj: f64,
    /// NoC cost of moving one gathered row to the compute tiles
    noc_pj_per_row: f64,
    noc_ns: f64,
}

impl MemoryTileModel {
    pub fn new(store: &EmbeddingStore, n_banks: usize, tech: &TechParams) -> Self {
        Self::with_rows(store.total_rows(), store.d_emb, n_banks, tech)
    }

    /// Size memory tiles for an explicit row count. Table 3 uses the
    /// REAL benchmark cardinalities here (Criteo ≈ 33.8 M rows → ~4 GB
    /// of ReRAM): the compute side is independent of table size, but
    /// chip power/area are dominated by the storage arrays at that
    /// scale — exactly the regime the paper's power numbers reflect.
    pub fn with_rows(
        total_rows: usize,
        d_emb: usize,
        n_banks: usize,
        tech: &TechParams,
    ) -> Self {
        let row_bytes = d_emb * 4;
        let total_bytes = total_rows * row_bytes;
        let bank_bytes = total_bytes.div_ceil(n_banks);
        let bank = Buffer::new(bank_bytes);
        // ReRAM-as-storage density: 4F² cells at 2 bits/cell →
        // 4 cells/byte; ×1.3 wiring, plus per-bank periphery.
        let f_m = tech.f_nm * 1e-9;
        let mm2_per_byte = 4.0 * (tech.cell_area_f2 * f_m * f_m * 1e6) * 1.3;
        let periphery_mm2 = 0.02 * n_banks as f64; // sense amps + decode
        let area_mm2 = total_bytes as f64 * mm2_per_byte + periphery_mm2;
        let leakage_mw = 0.5 * n_banks as f64; // ReRAM is non-volatile
        // Row activation: full row-width sense in one access; latency
        // grows weakly (√) with bank capacity (longer bit lines).
        let cap_factor = (bank_bytes as f64 / (1 << 20) as f64).max(1.0).sqrt();
        MemoryTileModel {
            n_banks,
            bank,
            row_bytes,
            area_mm2,
            leakage_mw,
            row_act_ns: 18.0 * cap_factor.min(4.0),
            row_read_pj: 0.5 * row_bytes as f64,
            noc_pj_per_row: tech.noc_byte_pj * row_bytes as f64,
            noc_ns: tech.noc_hop_ns,
        }
    }

    /// Bank count sized to capacity (≈ one bank per 32 MB, ≥ the
    /// requested minimum) — what a real-scale design would provision.
    pub fn banks_for(total_rows: usize, d_emb: usize, min_banks: usize) -> usize {
        let bytes = total_rows * d_emb * 4;
        (bytes / (32 << 20)).max(min_banks)
    }

    /// Real-dataset row counts (the public benchmarks' table sizes).
    pub fn real_scale_rows(dataset: &str) -> usize {
        match dataset {
            "criteo" => 33_800_000,
            "avazu" => 9_400_000,
            "kdd" => 6_100_000,
            _ => 1_000_000,
        }
    }

    /// Cost of gathering `rows` (global row ids) under `placement`.
    /// Lookups to distinct banks proceed in parallel; same-bank lookups
    /// serialize (the conflict depth).
    pub fn gather_cost(&self, rows: &[usize], placement: &Placement) -> GatherCost {
        let depth = placement.conflict_depth(rows);
        GatherCost {
            latency_ns: depth as f64 * self.row_act_ns + self.noc_ns,
            energy_pj: rows.len() as f64 * (self.row_read_pj + self.noc_pj_per_row),
            conflict_depth: depth,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile;
    use crate::embeddings::placement::Strategy;

    fn setup() -> (EmbeddingStore, MemoryTileModel, Placement, Placement) {
        let p = profile("criteo").unwrap();
        let store = EmbeddingStore::random(&p, 32, 9);
        let tech = TechParams::default();
        let tiles = MemoryTileModel::new(&store, 16, &tech);
        let freqs = Placement::zipf_freqs(&store.cards, p.zipf_alpha);
        let aa = Placement::build(&freqs, 16, Strategy::AccessAware);
        let co = Placement::build(&freqs, 16, Strategy::Contiguous);
        (store, tiles, aa, co)
    }

    #[test]
    fn conflict_free_gather_is_one_bank_cycle() {
        let (_, tiles, aa, _) = setup();
        // single row: depth 1
        let c = tiles.gather_cost(&[0], &aa);
        assert_eq!(c.conflict_depth, 1);
        assert!(c.latency_ns > 0.0);
    }

    #[test]
    fn access_aware_gathers_hot_batch_faster() {
        let (store, tiles, aa, co) = setup();
        // hottest row of every field (the worst case for contiguous)
        let rows: Vec<usize> = (0..store.n_fields())
            .map(|j| store.global_row(j, 0))
            .collect();
        let c_aa = tiles.gather_cost(&rows, &aa);
        let c_co = tiles.gather_cost(&rows, &co);
        assert!(
            c_aa.latency_ns < c_co.latency_ns,
            "aa {} vs co {}",
            c_aa.latency_ns,
            c_co.latency_ns
        );
    }

    #[test]
    fn energy_scales_with_rows_not_conflicts() {
        let (store, tiles, aa, _) = setup();
        let rows: Vec<usize> = (0..store.n_fields())
            .map(|j| store.global_row(j, 0))
            .collect();
        let half = &rows[..rows.len() / 2];
        let c_full = tiles.gather_cost(&rows, &aa);
        let c_half = tiles.gather_cost(half, &aa);
        let ratio = c_full.energy_pj / c_half.energy_pj;
        assert!((ratio - 2.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn real_scale_memory_tiles_dominate_chip_area() {
        let tech = TechParams::default();
        let rows = MemoryTileModel::real_scale_rows("criteo");
        let m = MemoryTileModel::with_rows(rows, 32, 32, &tech);
        // 33.8M × 128B ≈ 4.3 GB of ReRAM ≈ tens of mm² at 32nm 4F²/2bit
        assert!(m.area_mm2 > 20.0 && m.area_mm2 < 300.0, "{}", m.area_mm2);
    }
}
