//! Embedding table storage + gather (the functional half of the memory
//! tiles; the cost half is `tilecost`).

use crate::data::Profile;
use crate::runtime::atns::TensorFile;
use crate::util::rng::{seed_from_name, Rng};

/// One table's random init rows — THE recipe (substream name + scale)
/// the monolithic store and the zero-copy sharded path must share:
/// their bit-identity contract is differential-tested, and each table
/// having its own substream is what lets a shard generate only the
/// tables it owns.
pub(crate) fn random_table(seed: u64, field: usize, card: usize, d_emb: usize) -> Vec<f32> {
    let mut r = Rng::new(seed_from_name(seed, &format!("servemb/{field}")));
    (0..card * d_emb).map(|_| (r.normal() * 0.05) as f32).collect()
}

/// Resolve a wire-level id against a table of `card` rows: in-range ids
/// map to themselves, everything else — negative sentinels (the standard
/// missing-feature encoding in CTR logs) and ids past the table — maps
/// to row 0, the designated OOV row. Returns `(row, was_oob)`.
///
/// Row 0 rather than a clamp is deliberate: the old path converted the
/// id with `as usize` and clamped to `cards[j] - 1`, so every negative
/// id wrapped huge and silently aliased the LAST row of its table — one
/// arbitrary trained row absorbing all missing features (and the row a
/// popularity-driven cache would then pin as the hottest in the system).
/// Every gather path — monolithic, sharded, cached — resolves through
/// this one function, so their bit-identity contracts carry the same
/// OOV semantics.
#[inline]
pub fn resolve_id(id: i32, card: usize) -> (usize, bool) {
    if id >= 0 && (id as usize) < card {
        (id as usize, false)
    } else {
        (0, true)
    }
}

/// Construction-time guard shared by every store/shard builder: a
/// zero-row table can serve nothing (not even row 0, the OOV row) and
/// used to surface as a `cards[j] - 1` underflow panic mid-gather.
pub(crate) fn validate_cards(cards: &[usize]) -> crate::Result<()> {
    for (j, &c) in cards.iter().enumerate() {
        crate::ensure!(c > 0, "table {j} has cardinality 0 (cannot hold the OOV row)");
    }
    Ok(())
}

/// All embedding tables for one dataset, flattened per field.
pub struct EmbeddingStore {
    pub d_emb: usize,
    /// per-field tables, row-major `[cards[j] × d_emb]`
    tables: Vec<Vec<f32>>,
    pub cards: Vec<usize>,
}

impl EmbeddingStore {
    /// Load trained tables from an `embeddings_<ds>.bin` artifact.
    pub fn from_atns(tf: &TensorFile) -> crate::Result<EmbeddingStore> {
        let mut tables = Vec::new();
        let mut cards = Vec::new();
        let mut d_emb = 0usize;
        for j in 0.. {
            let Some(t) = tf.get(&format!("emb/{j}")) else {
                break;
            };
            crate::ensure!(t.shape.len() == 2, "emb/{j}: expected 2-D");
            let (c, d) = (t.shape[0], t.shape[1]);
            crate::ensure!(d_emb == 0 || d == d_emb, "emb/{j}: dim mismatch");
            d_emb = d;
            cards.push(c);
            tables.push(t.as_f32()?);
        }
        crate::ensure!(!tables.is_empty(), "no emb/<j> tensors found");
        validate_cards(&cards)?;
        Ok(EmbeddingStore {
            d_emb,
            tables,
            cards,
        })
    }

    /// Random tables (tests / serving without trained artifacts).
    pub fn random(profile: &Profile, d_emb: usize, seed: u64) -> EmbeddingStore {
        validate_cards(&profile.cards).expect("profile has a zero-row table");
        let tables = profile
            .cards
            .iter()
            .enumerate()
            .map(|(j, &c)| random_table(seed, j, c, d_emb))
            .collect();
        EmbeddingStore {
            d_emb,
            tables,
            cards: profile.cards.clone(),
        }
    }

    pub fn n_fields(&self) -> usize {
        self.tables.len()
    }

    /// Total rows across all fields.
    pub fn total_rows(&self) -> usize {
        self.cards.iter().sum()
    }

    /// One embedding row.
    pub fn row(&self, field: usize, id: usize) -> &[f32] {
        let d = self.d_emb;
        &self.tables[field][id * d..(id + 1) * d]
    }

    /// Gather a batch: ids is row-major [batch × n_fields]; output is
    /// [batch × n_fields × d_emb] appended to `out`. Out-of-range ids
    /// resolve to row 0 (see [`resolve_id`]); returns how many did.
    pub fn gather(&self, ids: &[i32], batch: usize, out: &mut Vec<f32>) -> usize {
        let nf = self.n_fields();
        debug_assert_eq!(ids.len(), batch * nf);
        out.reserve(batch * nf * self.d_emb);
        let mut oob = 0usize;
        for b in 0..batch {
            for j in 0..nf {
                let (id, was_oob) = resolve_id(ids[b * nf + j], self.cards[j]);
                oob += was_oob as usize;
                out.extend_from_slice(self.row(j, id));
            }
        }
        oob
    }

    /// Raw rows of one table (row-major `[cards[j] × d_emb]`) — the unit
    /// the sharding layer clones per replica.
    pub fn table(&self, field: usize) -> &[f32] {
        &self.tables[field]
    }

    /// Gather selected `(fields[k], ids[k])` pairs of ONE record into a
    /// zero-filled `[n_fields × d_emb]` block appended to `out` (slots
    /// of untouched fields stay zero — the engine's padding value).
    /// With `fields = 0..n_fields` this is element-identical to
    /// `gather` with batch 1. Out-of-range ids resolve to row 0 (see
    /// [`resolve_id`]); returns how many did.
    pub fn gather_fields(&self, fields: &[u32], ids: &[i32], out: &mut Vec<f32>) -> usize {
        debug_assert_eq!(fields.len(), ids.len());
        let nf = self.n_fields();
        // Full request (the default serving path): straight append —
        // the zero-fill below would be memset immediately overwritten.
        if fields.len() == nf
            && fields.iter().enumerate().all(|(k, &f)| f as usize == k)
        {
            return self.gather(ids, 1, out);
        }
        let d = self.d_emb;
        let base = out.len();
        out.resize(base + nf * d, 0.0);
        let mut oob = 0usize;
        for (k, &f) in fields.iter().enumerate() {
            let j = f as usize;
            if j >= nf {
                continue;
            }
            let (id, was_oob) = resolve_id(ids[k], self.cards[j]);
            oob += was_oob as usize;
            out[base + j * d..base + (j + 1) * d].copy_from_slice(self.row(j, id));
        }
        oob
    }

    /// Global row index of (field, id) — the unit the placement stripes.
    pub fn global_row(&self, field: usize, id: usize) -> usize {
        self.cards[..field].iter().sum::<usize>() + id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile;

    #[test]
    fn random_store_has_profile_shape() {
        let p = profile("criteo").unwrap();
        let s = EmbeddingStore::random(&p, 32, 1);
        assert_eq!(s.n_fields(), 26);
        assert_eq!(s.d_emb, 32);
        assert_eq!(s.row(0, 0).len(), 32);
        assert_eq!(s.total_rows(), p.cards.iter().sum::<usize>());
    }

    #[test]
    fn gather_layout_is_row_major() {
        let p = profile("kdd").unwrap();
        let s = EmbeddingStore::random(&p, 16, 2);
        let ids: Vec<i32> = (0..2 * s.n_fields()).map(|i| (i % 3) as i32).collect();
        let mut out = Vec::new();
        s.gather(&ids, 2, &mut out);
        assert_eq!(out.len(), 2 * s.n_fields() * 16);
        // spot-check element (batch 1, field 2)
        let nf = s.n_fields();
        let want = s.row(2, ids[nf + 2] as usize);
        let got = &out[(nf + 2) * 16..(nf + 3) * 16];
        assert_eq!(got, want);
    }

    #[test]
    fn global_row_offsets_accumulate() {
        let p = profile("criteo").unwrap();
        let s = EmbeddingStore::random(&p, 16, 3);
        assert_eq!(s.global_row(0, 5), 5);
        assert_eq!(s.global_row(1, 0), p.cards[0]);
        assert_eq!(s.global_row(2, 1), p.cards[0] + p.cards[1] + 1);
    }

    #[test]
    fn gather_fields_full_set_matches_gather() {
        let p = profile("criteo").unwrap();
        let s = EmbeddingStore::random(&p, 8, 9);
        let nf = s.n_fields();
        let ids: Vec<i32> = (0..nf as i32).map(|i| i % 7).collect();
        let fields: Vec<u32> = (0..nf as u32).collect();
        let mut a = Vec::new();
        s.gather(&ids, 1, &mut a);
        let mut b = Vec::new();
        s.gather_fields(&fields, &ids, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn gather_fields_partial_zero_fills_missing() {
        let p = profile("kdd").unwrap();
        let s = EmbeddingStore::random(&p, 4, 2);
        let mut out = Vec::new();
        s.gather_fields(&[1, 3], &[2, 0], &mut out);
        assert_eq!(out.len(), s.n_fields() * 4);
        assert!(out[0..4].iter().all(|&x| x == 0.0)); // field 0 untouched
        assert_eq!(&out[4..8], s.row(1, 2));
        assert_eq!(&out[12..16], s.row(3, 0));
    }

    #[test]
    fn out_of_range_ids_resolve_to_the_oov_row() {
        let p = profile("kdd").unwrap();
        let s = EmbeddingStore::random(&p, 8, 4);
        let nf = s.n_fields();
        // the canonical hostile set: negative sentinel, extreme
        // negative, exactly card, past card — all must land on row 0
        for hostile in [-1i32, i32::MIN, i32::MAX] {
            let ids = vec![hostile; nf];
            let mut out = Vec::new();
            let oob = s.gather(&ids, 1, &mut out);
            assert_eq!(oob, nf, "every id is OOV");
            for j in 0..nf {
                assert_eq!(&out[j * 8..(j + 1) * 8], s.row(j, 0), "id {hostile}");
            }
        }
        // per-table boundary cases: card and card+7 are OOV, card-1 not
        for j in 0..nf {
            let c = s.cards[j];
            assert_eq!(resolve_id(c as i32, c), (0, true));
            assert_eq!(resolve_id((c + 7) as i32, c), (0, true));
            assert_eq!(resolve_id(c as i32 - 1, c), (c - 1, false));
            assert_eq!(resolve_id(0, c), (0, false));
        }
    }

    #[test]
    fn gather_fields_counts_oob_like_gather() {
        let p = profile("kdd").unwrap();
        let s = EmbeddingStore::random(&p, 4, 6);
        // partial request mixing valid, negative, and past-card ids
        let fields = [0u32, 2, 5];
        let cards = [s.cards[0], s.cards[2], s.cards[5]];
        let ids = [1i32, -1, cards[2] as i32];
        let mut out = Vec::new();
        let oob = s.gather_fields(&fields, &ids, &mut out);
        assert_eq!(oob, 2);
        assert_eq!(&out[2 * 4..3 * 4], s.row(2, 0), "negative → OOV row");
        assert_eq!(&out[5 * 4..6 * 4], s.row(5, 0), "past card → OOV row");
        assert_eq!(&out[0..4], s.row(0, 1), "valid id untouched");
    }

    #[test]
    fn zero_cardinality_table_is_rejected_at_construction() {
        assert!(validate_cards(&[5, 0, 3]).is_err());
        assert!(validate_cards(&[5, 1, 3]).is_ok());
        assert!(validate_cards(&[]).is_ok());
    }
}
