//! Tiered hot-row cache + intra-batch coalescing above [`ShardedStore`]
//! (S29/S30, DESIGN.md §7.10).
//!
//! RecNMP and ProactivePIM (PAPERS.md) locate the recommender serving
//! win inside the embedding gather: a small zipf head absorbs most
//! lookups, the same rows recur within a compiled batch, and the hot
//! set is predictable enough to prefetch. This module is that tier for
//! the serving stack:
//!
//! * [`HotRowCache`] — a bounded, zipf-profile-seeded cache of the
//!   hottest rows across every table, packed into one compact arena
//!   (the hot head of a ~20k-row store fits in L2 where the scattered
//!   full tables do not). Admission is priority-driven: row `r` of
//!   table `j` scores `(1/(r+1)^α) / H(card_j, α)` — its predicted
//!   share of the table's traffic normalised to a probability, so
//!   priorities are comparable ACROSS tables. Build-time
//!   [`prefetch`](HotCacheConfig::prefetch) loads the predicted global
//!   head set (ProactivePIM-style shared-row preloading); online
//!   [`HotRowCache::offer`] admits with min-priority eviction during
//!   warmup. After warmup the cache is immutable and lock-free: workers
//!   share it behind an `Arc`, and the serving hot path takes no locks —
//!   the store is static, a static store has a static optimal cache, so
//!   online admission during serving would buy contention and nothing
//!   else.
//! * [`BatchGatherer`] — RecNMP-style batch coalescing: each unique
//!   `(table, id)` pair in a compiled batch is fetched exactly once
//!   (cache first, then local shard, then cross-shard), staged in a
//!   unique-row arena, and scattered to every requesting slot.
//!   Epoch-stamped dedup arrays make the per-batch reset free, and
//!   every arena persists across batches — allocation-free after
//!   warmup, per the PR 5 serving contract.
//!
//! The whole tier is behaviour-transparent: gathers with the cache on
//! or off are bit-identical to [`ShardedStore::gather_from`], pinned by
//! the differential property suite in `rust/tests/hotcache_prop.rs`.

use super::sharding::{harmonic, ShardedStore};
use super::store::resolve_id;
use std::sync::atomic::{AtomicU64, Ordering};

/// Sentinel in [`HotRowCache::slot_of`] / epoch stamps: not resident.
const NOT_RESIDENT: u32 = u32::MAX;

/// How a [`HotRowCache`] is provisioned.
#[derive(Clone, Copy, Debug)]
pub struct HotCacheConfig {
    /// maximum resident rows (0 disables the cache entirely)
    pub capacity: usize,
    /// preload the predicted global head set at build time
    /// ([`head_rows_per_table`]) — the ProactivePIM move; `false`
    /// starts cold and relies on [`HotRowCache::offer`]
    pub prefetch: bool,
}

/// Lock-free hit/miss/eviction counters (relaxed; exact totals are
/// reconciled per batch through `GatherStats` → `Metrics`).
#[derive(Debug, Default)]
pub struct CacheStats {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Per-table sizes of the globally-hottest `n` rows under zipf(α):
/// `out[j]` head rows of table `j` belong to the global top-`n` by
/// admission priority `(1/(r+1)^α) / H(card_j, α)`. Within a table the
/// priority strictly decreases with row rank, so each table's share is
/// always a prefix of its rows — which is what lets the cache, the
/// cache-aware `ShardMap::build_cached`, and the property suite all
/// describe the same set by counts alone. Ties break toward the lower
/// table index, then the lower row, deterministically.
pub fn head_rows_per_table(cards: &[usize], alpha: f64, n: usize) -> Vec<usize> {
    let nt = cards.len();
    let mut counts = vec![0usize; nt];
    if n == 0 || nt == 0 {
        return counts;
    }
    // only the first min(card, n) rows of any table can reach the top n
    let mut cand: Vec<(f64, usize, usize)> = Vec::new();
    for (j, &c) in cards.iter().enumerate() {
        let h = harmonic(c, alpha);
        for r in 0..c.min(n) {
            cand.push((1.0 / ((r + 1) as f64).powf(alpha) / h, j, r));
        }
    }
    cand.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap()
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
    });
    for &(_, j, _) in cand.iter().take(n) {
        counts[j] += 1;
    }
    counts
}

/// A bounded cache of hot embedding rows shared by every worker.
///
/// Two-phase lifecycle: a mutable WARM phase (construction, `prefetch`,
/// [`offer`](HotRowCache::offer)) where admission and eviction happen,
/// then an immutable SERVING phase behind an `Arc` where
/// [`lookup`](HotRowCache::lookup) is the only operation — reads plus
/// two relaxed counters, no locks.
pub struct HotRowCache {
    d_emb: usize,
    capacity: usize,
    alpha: f64,
    /// global per-table cardinalities
    cards: Vec<usize>,
    /// prefix sums of `cards`: global row of `(j, id)` is `offsets[j] + id`
    offsets: Vec<usize>,
    /// per-table zipf normaliser `H(card, α)`
    hnorm: Vec<f64>,
    /// global row → slot index (`NOT_RESIDENT` when absent)
    slot_of: Vec<u32>,
    /// slot → (global row, admission priority)
    slots: Vec<(u32, f64)>,
    /// slot `s`'s embedding at `rows[s*d_emb .. (s+1)*d_emb]`
    rows: Vec<f32>,
    pub stats: CacheStats,
}

impl HotRowCache {
    /// Build over `store`'s row space. With `prefetch` the predicted
    /// head set is resident on return (never evicting — the set is
    /// sized to `capacity`); without it the cache starts cold.
    pub fn new(store: &ShardedStore, alpha: f64, cfg: HotCacheConfig) -> HotRowCache {
        let cards = store.cards.clone();
        let total = store.total_rows();
        assert!(
            total < NOT_RESIDENT as usize,
            "row space exceeds the u32 slot index"
        );
        let mut offsets = Vec::with_capacity(cards.len());
        let mut acc = 0usize;
        for &c in &cards {
            offsets.push(acc);
            acc += c;
        }
        let hnorm = cards.iter().map(|&c| harmonic(c, alpha)).collect();
        let capacity = cfg.capacity.min(total);
        let mut cache = HotRowCache {
            d_emb: store.d_emb,
            capacity,
            alpha,
            cards,
            offsets,
            hnorm,
            slot_of: vec![NOT_RESIDENT; total],
            slots: Vec::with_capacity(capacity),
            rows: Vec::with_capacity(capacity * store.d_emb),
            stats: CacheStats::default(),
        };
        if cfg.prefetch && capacity > 0 {
            let head = head_rows_per_table(&cache.cards, alpha, capacity);
            for (j, &take) in head.iter().enumerate() {
                for r in 0..take {
                    cache.offer(store, j, r);
                }
            }
        }
        cache
    }

    /// Admission priority of `(table, id)`: the row's predicted share of
    /// its table's traffic under zipf(α), a probability in (0, 1] —
    /// finite and positive, so comparisons are total.
    fn priority(&self, table: usize, id: usize) -> f64 {
        1.0 / ((id + 1) as f64).powf(self.alpha) / self.hnorm[table]
    }

    #[inline]
    fn global(&self, table: usize, id: usize) -> usize {
        debug_assert!(id < self.cards[table], "offer/lookup take resolved ids");
        self.offsets[table] + id
    }

    /// WARM phase: offer `(table, id)` for admission. Admits into free
    /// capacity directly; at capacity it evicts the minimum-priority
    /// resident iff the offered row is strictly hotter. Returns whether
    /// the row is resident afterwards because of this call.
    pub fn offer(&mut self, store: &ShardedStore, table: usize, id: usize) -> bool {
        if self.capacity == 0 {
            return false;
        }
        let g = self.global(table, id);
        if self.slot_of[g] != NOT_RESIDENT {
            return false; // already resident
        }
        let p = self.priority(table, id);
        let row = store.shards[store.map.primary(table)]
            .row(table, id)
            .expect("shard map primary must hold the table");
        let d = self.d_emb;
        if self.slots.len() < self.capacity {
            let s = self.slots.len();
            self.slots.push((g as u32, p));
            self.rows.extend_from_slice(row);
            self.slot_of[g] = s as u32;
            return true;
        }
        // full: linear-scan the victim (warm-phase only — O(capacity)
        // here buys a zero-bookkeeping serving phase)
        let mut victim = 0usize;
        for s in 1..self.slots.len() {
            if self.slots[s].1 < self.slots[victim].1 {
                victim = s;
            }
        }
        let (vg, vp) = self.slots[victim];
        if p <= vp {
            return false; // colder than everything resident
        }
        self.slot_of[vg as usize] = NOT_RESIDENT;
        self.slots[victim] = (g as u32, p);
        self.rows[victim * d..(victim + 1) * d].copy_from_slice(row);
        self.slot_of[g] = victim as u32;
        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// SERVING phase: the row of `(table, id)` if resident. `id` must
    /// already be resolved in-range (see
    /// [`resolve_id`](super::store::resolve_id)). Counts a hit or miss.
    #[inline]
    pub fn lookup(&self, table: usize, id: usize) -> Option<&[f32]> {
        let s = self.slot_of[self.global(table, id)];
        if s == NOT_RESIDENT {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        let (s, d) = (s as usize, self.d_emb);
        Some(&self.rows[s * d..(s + 1) * d])
    }

    /// Residency without touching the hit/miss counters (tests,
    /// placement accounting).
    pub fn resident(&self, table: usize, id: usize) -> bool {
        self.slot_of[self.global(table, id)] != NOT_RESIDENT
    }

    /// Resident rows (never exceeds [`capacity`](HotRowCache::capacity)).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn d_emb(&self) -> usize {
        self.d_emb
    }

    /// Resident head-row counts per table (for cache-aware placement:
    /// `ShardMap::build_cached` charges replicas only for the uncached
    /// remainder of each table).
    pub fn resident_per_table(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cards.len()];
        for &(g, _) in &self.slots {
            // binary search the owning table by offset
            let g = g as usize;
            let j = match self.offsets.binary_search(&g) {
                Ok(j) => j,
                Err(j) => j - 1,
            };
            counts[j] += 1;
        }
        counts
    }
}

/// Per-batch gather ledger. Every requested row is served exactly once:
/// `requested == cache_hits + local + remote + coalesced + degraded`,
/// and with a cache attached `cache_misses == local + remote +
/// degraded` (the misses are precisely the rows that fell through to
/// the shards — or, in brownout, were zero-filled instead).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GatherStats {
    /// valid `(field, id)` pairs requested (pre-dedup)
    pub requested: usize,
    /// unique rows gathered from the local shard
    pub local: usize,
    /// unique rows fetched cross-shard
    pub remote: usize,
    /// unique rows served straight from the hot cache
    pub cache_hits: usize,
    /// unique rows the cache did not hold (0 with no cache attached)
    pub cache_misses: usize,
    /// duplicate occurrences served by the scatter instead of a fetch
    pub coalesced: usize,
    /// out-of-range ids resolved to row 0, counted per occurrence
    pub oob: usize,
    /// brownout (S33): cross-shard rows skipped in degraded mode and
    /// served as zeros, counted per occurrence (0 outside brownout)
    pub degraded: usize,
}

impl GatherStats {
    /// The conservation invariant above, as a checkable predicate
    /// (degraded rows are a served-as-zero leg, so they extend both
    /// sides the same way remote rows would).
    pub fn balanced(&self) -> bool {
        self.requested
            == self.cache_hits + self.local + self.remote + self.coalesced + self.degraded
            && (self.cache_hits + self.cache_misses == 0
                || self.cache_misses == self.local + self.remote + self.degraded)
    }
}

/// Batch-coalescing gather engine, one per worker. All state persists
/// across batches (allocation-free after warmup); the epoch stamp makes
/// "clear the dedup index" a single increment.
pub struct BatchGatherer {
    /// prefix sums of the table cardinalities (global-row keying)
    offsets: Vec<usize>,
    /// global row → epoch it was last staged in
    seen_epoch: Vec<u32>,
    /// global row → its slot in `uniq` for the stamped epoch
    seen_pos: Vec<u32>,
    epoch: u32,
    /// staging arena for this batch's unique rows, append-only within a
    /// batch — duplicates scatter from here, so a later write to the
    /// same output slot (repeated field in one record) can never corrupt
    /// what other slots copy
    uniq: Vec<f32>,
}

impl BatchGatherer {
    pub fn new(cards: &[usize]) -> BatchGatherer {
        let total: usize = cards.iter().sum();
        let mut offsets = Vec::with_capacity(cards.len());
        let mut acc = 0usize;
        for &c in cards {
            offsets.push(acc);
            acc += c;
        }
        BatchGatherer {
            offsets,
            seen_epoch: vec![0; total],
            seen_pos: vec![0; total],
            epoch: 0,
            uniq: Vec::new(),
        }
    }

    /// Gather a whole compiled batch: for each `(fields, ids)` record a
    /// zero-filled `[n_fields × d_emb]` block is appended to `out`,
    /// exactly as [`ShardedStore::gather_from`] would per record — the
    /// output is bit-identical to that per-record path with any cache
    /// state, cold, warm, or absent (property-pinned). Unique rows are
    /// fetched once — cache, then local shard, then cross-shard — and
    /// duplicates are scattered from the staging arena.
    pub fn gather_batch<'a, I>(
        &mut self,
        store: &ShardedStore,
        cache: Option<&HotRowCache>,
        local: usize,
        requests: I,
        out: &mut Vec<f32>,
    ) -> GatherStats
    where
        I: IntoIterator<Item = (&'a [u32], &'a [i32])>,
    {
        self.gather_batch_with(&store.map, store, cache, local, requests, out)
    }

    /// [`BatchGatherer::gather_batch`] through an explicit ownership
    /// view — the failover path (see
    /// [`ShardMap::promote`](super::sharding::ShardMap::promote)):
    /// after a worker death the survivors' coalesced gathers route
    /// cross-shard fetches by the promoted map. Output bytes are
    /// view-independent (replicas are byte-identical); only the
    /// local/remote accounting and fetch targets move.
    pub fn gather_batch_with<'a, I>(
        &mut self,
        map: &super::sharding::ShardMap,
        store: &ShardedStore,
        cache: Option<&HotRowCache>,
        local: usize,
        requests: I,
        out: &mut Vec<f32>,
    ) -> GatherStats
    where
        I: IntoIterator<Item = (&'a [u32], &'a [i32])>,
    {
        self.gather_batch_mode(map, store, cache, local, requests, out, false)
    }

    /// [`BatchGatherer::gather_batch_with`] with an explicit brownout
    /// switch (S33). `degraded = true` skips every cross-shard fetch:
    /// cache hits and locally-owned rows are served bit-identically to
    /// the normal path, but a row whose owner is a remote shard is left
    /// zero-filled and counted in [`GatherStats::degraded`] (per
    /// occurrence — degraded rows are not staged for coalescing, so
    /// duplicates count too). `degraded = false` is exactly
    /// `gather_batch_with`.
    #[allow(clippy::too_many_arguments)]
    pub fn gather_batch_mode<'a, I>(
        &mut self,
        map: &super::sharding::ShardMap,
        store: &ShardedStore,
        cache: Option<&HotRowCache>,
        local: usize,
        requests: I,
        out: &mut Vec<f32>,
        degraded: bool,
    ) -> GatherStats
    where
        I: IntoIterator<Item = (&'a [u32], &'a [i32])>,
    {
        // new epoch invalidates every stamp at once; on u32 wrap, clear
        // the stamps for real so an ancient stamp can never alias
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.seen_epoch.fill(0);
            self.epoch = 1;
        }
        self.uniq.clear();
        let d = store.d_emb;
        let nf = store.n_fields();
        let mut st = GatherStats::default();
        for (fields, ids) in requests {
            debug_assert_eq!(fields.len(), ids.len());
            let base = out.len();
            out.resize(base + nf * d, 0.0);
            for (k, &f) in fields.iter().enumerate() {
                let j = f as usize;
                if j >= nf {
                    continue;
                }
                let (id, was_oob) = resolve_id(ids[k], store.cards[j]);
                st.oob += was_oob as usize;
                st.requested += 1;
                let g = self.offsets[j] + id;
                let dst = base + j * d;
                if self.seen_epoch[g] == self.epoch {
                    // coalesced: scatter the staged copy, no fetch
                    st.coalesced += 1;
                    let pos = self.seen_pos[g] as usize * d;
                    out[dst..dst + d].copy_from_slice(&self.uniq[pos..pos + d]);
                    continue;
                }
                // first sighting this batch: fetch once
                let mut row: Option<&[f32]> = None;
                if let Some(c) = cache {
                    if let Some(r) = c.lookup(j, id) {
                        st.cache_hits += 1;
                        row = Some(r);
                    } else {
                        st.cache_misses += 1;
                    }
                }
                let row = match row {
                    Some(r) => r,
                    None if map.owns(local, j) => {
                        st.local += 1;
                        store.shards[local]
                            .row(j, id)
                            .expect("shard map owner must hold the table")
                    }
                    None if degraded => {
                        // brownout: the owner is a remote shard — skip
                        // the fetch, leave the zero fill, and do NOT
                        // stage the row (a zero must never be scattered
                        // as if it were the real thing after pressure
                        // clears mid-batch... and duplicates of a
                        // skipped row are skipped rows too)
                        st.degraded += 1;
                        continue;
                    }
                    None => {
                        st.remote += 1;
                        store.shards[map.primary(j)]
                            .row(j, id)
                            .expect("shard map owner must hold the table")
                    }
                };
                let pos = self.uniq.len() / d;
                self.uniq.extend_from_slice(row);
                self.seen_epoch[g] = self.epoch;
                self.seen_pos[g] = pos as u32;
                out[dst..dst + d].copy_from_slice(row);
            }
        }
        debug_assert!(st.balanced(), "gather ledger out of balance: {st:?}");
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile;
    use crate::embeddings::{ShardMap, ShardPolicy};

    fn sharded(name: &str, n_shards: usize) -> ShardedStore {
        let p = profile(name).unwrap();
        let map = ShardMap::for_profile(&p, n_shards, ShardPolicy::HotReplicated);
        ShardedStore::random(&p, 8, 42, map)
    }

    #[test]
    fn prefetch_fills_to_capacity_with_the_head_set() {
        let s = sharded("kdd", 2);
        let cap = 64;
        let c = HotRowCache::new(
            &s,
            1.35,
            HotCacheConfig {
                capacity: cap,
                prefetch: true,
            },
        );
        assert_eq!(c.len(), cap);
        assert_eq!(c.stats.evictions(), 0, "prefetch is sized to capacity");
        // the resident set is exactly the predicted head set
        let head = head_rows_per_table(&s.cards, 1.35, cap);
        assert_eq!(c.resident_per_table(), head);
        for (j, &take) in head.iter().enumerate() {
            for r in 0..take {
                assert!(c.resident(j, r), "head row ({j}, {r}) missing");
            }
        }
    }

    #[test]
    fn lookup_returns_the_store_row_bit_identically() {
        let s = sharded("kdd", 3);
        let c = HotRowCache::new(
            &s,
            1.35,
            HotCacheConfig {
                capacity: 128,
                prefetch: true,
            },
        );
        let mut hits = 0;
        for j in 0..s.n_fields() {
            for r in 0..4.min(s.cards[j]) {
                if let Some(row) = c.lookup(j, r) {
                    let want = s.shards[s.map.primary(j)].row(j, r).unwrap();
                    assert_eq!(row, want);
                    hits += 1;
                }
            }
        }
        assert!(hits > 0, "a 128-row cache must hold some 4-row heads");
        assert_eq!(c.stats.hits(), hits);
    }

    #[test]
    fn cold_offers_evict_strictly_colder_rows() {
        let s = sharded("kdd", 2);
        let mut c = HotRowCache::new(
            &s,
            1.35,
            HotCacheConfig {
                capacity: 4,
                prefetch: false,
            },
        );
        assert!(c.is_empty());
        // fill with the COLDEST rows of table 0, then offer hotter ones:
        // each must evict (ascending priority ⇒ every offer beats the min)
        let card = s.cards[0];
        for r in (card - 4..card).rev() {
            assert!(c.offer(&s, 0, r));
        }
        assert_eq!((c.len(), c.stats.evictions()), (4, 0));
        for r in 0..4 {
            assert!(c.offer(&s, 0, r), "hotter row {r} must be admitted");
        }
        assert_eq!(c.stats.evictions(), 4);
        assert_eq!(c.len(), 4, "occupancy bounded by capacity");
        // now resident: rows 0..4; a cold row bounces
        assert!(!c.offer(&s, 0, card - 1));
        for r in 0..4 {
            assert!(c.resident(0, r));
        }
    }

    #[test]
    fn head_rows_per_table_is_conserved_and_prefix_shaped() {
        let cards = vec![10usize, 500, 3, 80];
        let total: usize = cards.iter().sum();
        for n in [0usize, 1, 7, 64, 1000] {
            let head = head_rows_per_table(&cards, 1.25, n);
            assert_eq!(head.iter().sum::<usize>(), n.min(total));
            for (j, &h) in head.iter().enumerate() {
                assert!(h <= cards[j]);
            }
        }
        // hotter (smaller) tables get their heads first
        let head = head_rows_per_table(&cards, 1.25, 4);
        assert!(head[2] >= 1, "3-row table has the hottest head: {head:?}");
    }

    #[test]
    fn coalescing_batch_matches_per_record_gather() {
        let s = sharded("kdd", 3);
        let nf = s.n_fields();
        let fields: Vec<u32> = (0..nf as u32).collect();
        // duplicate-heavy batch: same hot ids repeated + one OOV id
        let recs: Vec<Vec<i32>> = (0..6)
            .map(|b| (0..nf).map(|j| ((j + b) % 3) as i32 - 1).collect())
            .collect();
        let mut want = Vec::new();
        let (mut wl, mut wr, mut woob) = (0, 0, 0);
        for ids in &recs {
            let (l, r, o) = s.gather_from(1, &fields, ids, &mut want);
            wl += l;
            wr += r;
            woob += o;
        }
        let mut g = BatchGatherer::new(&s.cards);
        let mut got = Vec::new();
        let st = g.gather_batch(
            &s,
            None,
            1,
            recs.iter().map(|ids| (fields.as_slice(), ids.as_slice())),
            &mut got,
        );
        assert_eq!(got, want);
        assert_eq!(st.oob, woob);
        assert_eq!(st.requested, wl + wr);
        assert!(st.coalesced > 0, "repeated ids must coalesce");
        assert!(st.balanced(), "{st:?}");
    }

    #[test]
    fn degraded_gather_serves_local_rows_and_zeros_remote() {
        let s = sharded("kdd", 3);
        let nf = s.n_fields();
        let d = s.d_emb;
        let local = 1;
        let fields: Vec<u32> = (0..nf as u32).collect();
        let recs: Vec<Vec<i32>> = (0..4)
            .map(|b| (0..nf).map(|j| ((j + b) % 3) as i32).collect())
            .collect();
        let mut g = BatchGatherer::new(&s.cards);
        let mut normal = Vec::new();
        let st_n = g.gather_batch(
            &s,
            None,
            local,
            recs.iter().map(|ids| (fields.as_slice(), ids.as_slice())),
            &mut normal,
        );
        let mut g = BatchGatherer::new(&s.cards);
        let mut got = Vec::new();
        let st_d = g.gather_batch_mode(
            &s.map,
            &s,
            None,
            local,
            recs.iter().map(|ids| (fields.as_slice(), ids.as_slice())),
            &mut got,
            true,
        );
        // locally-owned tables are served bit-identically; every
        // remote-owned slot is a zero fill
        for b in 0..recs.len() {
            for j in 0..nf {
                let at = b * nf * d + j * d;
                if s.map.owns(local, j) {
                    assert_eq!(
                        &got[at..at + d],
                        &normal[at..at + d],
                        "local table {j} must be exact in brownout"
                    );
                } else {
                    assert!(
                        got[at..at + d].iter().all(|&x| x == 0.0),
                        "remote table {j} must be zero-filled"
                    );
                }
            }
        }
        assert_eq!(st_d.remote, 0, "brownout fetches nothing cross-shard");
        assert!(st_d.degraded > 0);
        assert_eq!(st_d.requested, st_n.requested);
        assert_eq!(st_d.local, st_n.local, "local service is unchanged");
        assert!(st_d.balanced(), "{st_d:?}");
        // degraded = false is exactly the normal path
        let mut g = BatchGatherer::new(&s.cards);
        let mut again = Vec::new();
        let st = g.gather_batch_mode(
            &s.map,
            &s,
            None,
            local,
            recs.iter().map(|ids| (fields.as_slice(), ids.as_slice())),
            &mut again,
            false,
        );
        assert_eq!(again, normal);
        assert_eq!(st, st_n);
    }

    #[test]
    fn repeated_fields_in_one_record_stay_last_write_wins() {
        // hostile records where a repeated field overwrites its own
        // output slot between a row's first fetch and a later coalesced
        // repeat — the scatter must serve the STAGED copy, not whatever
        // the output slot currently holds. [2, 2, 2]/[5, 1, 5] is the
        // sharp case: by the third pair, slot 2 holds row 1's embedding,
        // but the coalesced (2, 5) must still produce row 5.
        let s = sharded("kdd", 2);
        for (fields, ids) in [
            (vec![2u32, 2, 3], vec![5i32, 1, 5]),
            (vec![2u32, 2, 2], vec![5i32, 1, 5]),
        ] {
            let mut want = Vec::new();
            s.gather_from(0, &fields, &ids, &mut want);
            let mut g = BatchGatherer::new(&s.cards);
            let mut got = Vec::new();
            let st = g.gather_batch(
                &s,
                None,
                0,
                std::iter::once((&fields[..], &ids[..])),
                &mut got,
            );
            assert_eq!(got, want, "last-write-wins must match gather_from");
            assert_eq!(st.requested, 3);
            assert!(st.balanced());
        }
    }
}
