//! Access-aware bank placement (paper §3.3).
//!
//! "An offline access-aware mechanism reorganizes embeddings by their
//! frequency of occurrence, placing them in round-robin fashion across
//! different banks to avoid conflicts."
//!
//! Under a zipf access distribution the hot rows dominate traffic; if
//! they are striped round-robin by frequency rank, the hottest rows of a
//! batch land on distinct banks. The contrast strategy (`Contiguous`)
//! fills banks table-by-table, so co-occurring hot heads of neighbouring
//! fields collide — the ablation bench quantifies the gap.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// frequency-ranked round-robin (the paper's scheme)
    AccessAware,
    /// rows in table order, banks filled contiguously
    Contiguous,
}

/// Bank assignment for every global embedding row.
pub struct Placement {
    pub n_banks: usize,
    pub strategy: Strategy,
    bank_of: Vec<u32>,
}

impl Placement {
    /// Build from per-row access frequencies (same indexing as
    /// `EmbeddingStore::global_row`). Frequencies come either from the
    /// zipf prior (offline) or from measured counters.
    pub fn build(freqs: &[f64], n_banks: usize, strategy: Strategy) -> Placement {
        assert!(n_banks > 0);
        let n = freqs.len();
        let mut bank_of = vec![0u32; n];
        match strategy {
            Strategy::AccessAware => {
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    freqs[b].partial_cmp(&freqs[a]).unwrap().then(a.cmp(&b))
                });
                for (rank, &row) in order.iter().enumerate() {
                    bank_of[row] = (rank % n_banks) as u32;
                }
            }
            Strategy::Contiguous => {
                let per = n.div_ceil(n_banks);
                for (row, b) in bank_of.iter_mut().enumerate() {
                    *b = (row / per) as u32;
                }
            }
        }
        Placement {
            n_banks,
            strategy,
            bank_of,
        }
    }

    #[inline]
    pub fn bank(&self, global_row: usize) -> usize {
        self.bank_of[global_row] as usize
    }

    /// Serialization depth of a batch of lookups: lookups to the same
    /// bank serialize, so the gather takes `max_bank_count` bank cycles.
    pub fn conflict_depth(&self, rows: &[usize]) -> usize {
        let mut counts = vec![0usize; self.n_banks];
        for &r in rows {
            counts[self.bank(r)] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }

    /// Expected zipf-prior frequencies for a field layout (offline mode:
    /// no measured counters needed — the generator's distribution IS the
    /// workload distribution).
    pub fn zipf_freqs(cards: &[usize], alpha: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(cards.iter().sum());
        for &c in cards {
            for k in 1..=c {
                out.push(1.0 / (k as f64).powf(alpha));
            }
        }
        out
    }
}

/// Monte-carlo comparison helper used by tests and the ablation bench:
/// average conflict depth of gathering `batch` records' worth of lookups
/// (one zipf draw per field per record) at once.
pub fn avg_conflict_depth(
    p: &Placement,
    cards: &[usize],
    alpha: f64,
    batch: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    use crate::util::rng::Zipf;
    let zipfs: Vec<Zipf> = cards.iter().map(|&c| Zipf::new(c, alpha)).collect();
    let offsets: Vec<usize> = cards
        .iter()
        .scan(0usize, |acc, &c| {
            let o = *acc;
            *acc += c;
            Some(o)
        })
        .collect();
    let mut total = 0usize;
    for _ in 0..trials {
        let mut rows = Vec::with_capacity(batch * cards.len());
        for _ in 0..batch {
            rows.extend(
                zipfs
                    .iter()
                    .zip(&offsets)
                    .map(|(z, &o)| o + z.sample(rng)),
            );
        }
        total += p.conflict_depth(&rows);
    }
    total as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_spreads_hot_rows() {
        // 4 fields × 8 rows, hot row first in each field
        let cards = [8usize; 4];
        let freqs = Placement::zipf_freqs(&cards, 1.2);
        let p = Placement::build(&freqs, 4, Strategy::AccessAware);
        // the four hottest rows (rank 0..3) must be on distinct banks
        let hot: Vec<usize> = (0..4).map(|f| f * 8).collect();
        let banks: std::collections::BTreeSet<usize> =
            hot.iter().map(|&r| p.bank(r)).collect();
        assert_eq!(banks.len(), 4, "hot heads collide: {banks:?}");
    }

    #[test]
    fn contiguous_collides_on_hot_heads() {
        let cards = [8usize; 4];
        let freqs = Placement::zipf_freqs(&cards, 1.2);
        let p = Placement::build(&freqs, 4, Strategy::Contiguous);
        // per=8 → each field exactly one bank → heads of fields 0..3 are
        // on banks 0..3 — but two lookups within one field collide.
        assert_eq!(p.bank(0), 0);
        assert_eq!(p.bank(7), 0);
    }

    #[test]
    fn access_aware_beats_contiguous_on_zipf_traffic() {
        // Realistic (criteo-like) varied cardinalities: contiguous bank
        // boundaries then pile the hot heads of several small tables into
        // the same bank, which batched gathers hit simultaneously.
        let cards: Vec<usize> = crate::data::profile("criteo").unwrap().cards;
        let alpha = 1.25;
        let freqs = Placement::zipf_freqs(&cards, alpha);
        let aa = Placement::build(&freqs, 8, Strategy::AccessAware);
        let co = Placement::build(&freqs, 8, Strategy::Contiguous);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let d_aa = avg_conflict_depth(&aa, &cards, alpha, 4, 200, &mut r1);
        let d_co = avg_conflict_depth(&co, &cards, alpha, 4, 200, &mut r2);
        assert!(
            d_aa < 0.8 * d_co,
            "access-aware {d_aa} should clearly beat contiguous {d_co}"
        );
    }

    #[test]
    fn conflict_depth_counts_serialization() {
        let freqs = vec![1.0; 8];
        let p = Placement::build(&freqs, 4, Strategy::Contiguous);
        // rows 0,1 are on bank 0 (per=2): depth 2
        assert_eq!(p.conflict_depth(&[0, 1]), 2);
        // rows 0,2 on different banks: depth 1
        assert_eq!(p.conflict_depth(&[0, 2]), 1);
        assert_eq!(p.conflict_depth(&[]), 0);
    }

    #[test]
    fn every_row_gets_a_bank_in_range() {
        let freqs = Placement::zipf_freqs(&[100, 50, 25], 1.1);
        for strat in [Strategy::AccessAware, Strategy::Contiguous] {
            let p = Placement::build(&freqs, 6, strat);
            for r in 0..175 {
                assert!(p.bank(r) < 6);
            }
        }
    }
}
