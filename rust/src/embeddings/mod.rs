//! Embedding memory-tile subsystem (paper §3.3, S10).
//!
//! Memory tiles hold the embedding tables in a static, read-only state.
//! An offline access-aware mechanism reorders rows by access frequency
//! and stripes them round-robin across banks so concurrent lookups in a
//! batch land on different banks (conflict-free for the hot head of the
//! zipf distribution).

pub mod placement;
pub mod store;
pub mod tilecost;

pub use placement::{Placement, Strategy};
pub use store::EmbeddingStore;
pub use tilecost::{GatherCost, MemoryTileModel};
