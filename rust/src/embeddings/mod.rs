//! Embedding memory-tile subsystem (paper §3.3, S10).
//!
//! Memory tiles hold the embedding tables in a static, read-only state.
//! An offline access-aware mechanism reorders rows by access frequency
//! and stripes them round-robin across banks so concurrent lookups in a
//! batch land on different banks (conflict-free for the hot head of the
//! zipf distribution).
//!
//! `sharding` (S18) lifts the same idea one level up: tables are
//! partitioned across serving workers (with hot tables replicated) so
//! the coordinator can keep gathers local to the memory tiles that own
//! them — see DESIGN.md §7.5.

pub mod hotcache;
pub mod placement;
pub mod sharding;
pub mod store;
pub mod tilecost;

pub use hotcache::{
    head_rows_per_table, BatchGatherer, CacheStats, GatherStats, HotCacheConfig, HotRowCache,
};
pub use placement::{Placement, Strategy};
pub use sharding::{EmbeddingShard, ShardMap, ShardPolicy, ShardedStore};
pub use store::{resolve_id, EmbeddingStore};
pub use tilecost::{GatherCost, MemoryTileModel};
