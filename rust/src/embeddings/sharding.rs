//! Table-granular sharding of the embedding layer (S18).
//!
//! The paper's §4.1 behavioral model treats the embedding front-end as
//! the shared bottleneck resource; RecNMP/UpDLRM (PAPERS.md) show that
//! *where* a sparse gather lands dominates recommender serving latency.
//! This module splits one dataset's table profile into per-worker
//! [`EmbeddingShard`]s so the coordinator can keep gathers next to the
//! worker that owns the tables (ShardAffinity routing), and assemble the
//! rest cross-shard. Three placement policies:
//!
//! * [`ShardPolicy::RoundRobinTables`] — table `j` on shard `j % n`;
//! * [`ShardPolicy::CapacityBalanced`] — LPT greedy bin-packing by row
//!   count (largest table first onto the least-loaded shard), which
//!   keeps every shard within 2× of the ideal row load (property-tested
//!   in `rust/tests/sharding_prop.rs`);
//! * [`ShardPolicy::HotReplicated`] — capacity-balanced, then the
//!   tables with the most skewed access (largest zipf head share from
//!   `data::profile`, i.e. the small tables whose few rows absorb most
//!   lookups) are replicated on EVERY shard until the replica budget
//!   (15% extra rows) is spent — trading a little capacity for
//!   conflict-free local gathers on the hot tables.
//!
//! Row values are the unit of truth: a shard's table is byte-identical
//! to the monolithic [`EmbeddingStore`] table, so a gather assembled
//! across shards is element-identical to the monolithic gather (pinned
//! by a differential property test).

use super::store::{resolve_id, EmbeddingStore};
use crate::data::Profile;

/// Extra rows `HotReplicated` may spend on replicas, as a fraction of
/// the unreplicated total.
pub const REPLICA_BUDGET: f64 = 0.15;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    /// table `j` → shard `j % n_shards`
    RoundRobinTables,
    /// LPT greedy: largest table first onto the least-loaded shard
    CapacityBalanced,
    /// capacity-balanced + hottest (most skewed) tables on every shard
    HotReplicated,
}

impl ShardPolicy {
    /// Parse a CLI spelling ("round-robin" | "balanced" | "hot").
    pub fn parse(s: &str) -> crate::Result<ShardPolicy> {
        Ok(match s {
            "round-robin" | "rr" => ShardPolicy::RoundRobinTables,
            "balanced" | "capacity" => ShardPolicy::CapacityBalanced,
            "hot" | "hot-replicated" => ShardPolicy::HotReplicated,
            other => crate::bail!(
                "unknown placement `{other}` (round-robin|balanced|hot)"
            ),
        })
    }
}

/// Which shard(s) own a replica of each table.
#[derive(Clone, Debug)]
pub struct ShardMap {
    pub n_shards: usize,
    pub policy: ShardPolicy,
    /// `owners[table]` — sorted, deduplicated shard ids
    owners: Vec<Vec<u32>>,
}

impl ShardMap {
    /// Place `cards.len()` tables on `n_shards` shards. `zipf_alpha` is
    /// the within-table access skew (only `HotReplicated` uses it).
    pub fn build(
        cards: &[usize],
        zipf_alpha: f64,
        n_shards: usize,
        policy: ShardPolicy,
    ) -> ShardMap {
        ShardMap::build_cached(cards, zipf_alpha, n_shards, policy, &[])
    }

    /// [`ShardMap::build`] with a hot-row cache in the picture:
    /// `cached_rows[j]` head rows of table `j` live in a shared
    /// [`HotRowCache`](super::hotcache::HotRowCache) tier that every
    /// worker reads locally, so those rows are charged against the
    /// `HotReplicated` replica budget ONLY ONCE (the cache copy) instead
    /// of once per shard — replicating a partially-cached table costs
    /// just its uncached remainder. An empty slice (or any other policy)
    /// reduces to the plain placement.
    pub fn build_cached(
        cards: &[usize],
        zipf_alpha: f64,
        n_shards: usize,
        policy: ShardPolicy,
        cached_rows: &[usize],
    ) -> ShardMap {
        assert!(n_shards > 0, "n_shards must be > 0");
        let nt = cards.len();
        let mut owners: Vec<Vec<u32>> = vec![Vec::new(); nt];
        match policy {
            ShardPolicy::RoundRobinTables => {
                for (j, o) in owners.iter_mut().enumerate() {
                    o.push((j % n_shards) as u32);
                }
            }
            ShardPolicy::CapacityBalanced | ShardPolicy::HotReplicated => {
                // LPT: biggest table first onto the least-loaded shard
                // (ties: lower shard id), deterministic.
                let mut order: Vec<usize> = (0..nt).collect();
                order.sort_by(|&a, &b| cards[b].cmp(&cards[a]).then(a.cmp(&b)));
                let mut load = vec![0usize; n_shards];
                for &j in &order {
                    let s = (0..n_shards)
                        .min_by_key(|&s| (load[s], s))
                        .unwrap();
                    owners[j].push(s as u32);
                    load[s] += cards[j];
                }
                if policy == ShardPolicy::HotReplicated && n_shards > 1 {
                    // Head share of a zipf(α) table with c rows is
                    // 1/H(c,α): small tables concentrate their traffic
                    // on the fewest rows — replicate those first.
                    //
                    // Budget arithmetic is exact to the row: the float
                    // budget is ROUNDED, not truncated (`as usize`
                    // floored away up to one row of budget per build,
                    // and made the spent-vs-allowed bound asymmetric
                    // with the property suite's own rounding).
                    //
                    // The pass is first-fit-decreasing over the heat
                    // order: a table whose replica cost exceeds the
                    // REMAINING budget is skipped and the scan continues
                    // to colder tables. The alternative (stop at the
                    // first misfit) strands the whole tail of the budget
                    // whenever one large-but-hot table lands early; FFD
                    // instead spends it on the hottest tables that fit.
                    // The replicated set is therefore exactly a prefix
                    // of `heat_order` FILTERED to tables that fit as the
                    // scan reaches them — pinned by
                    // `hot_replication_budget_is_exact_and_first_fit_by_heat`.
                    let total: usize = cards.iter().sum();
                    let mut budget =
                        (total as f64 * REPLICA_BUDGET).round() as usize;
                    for j in heat_order(cards, zipf_alpha) {
                        // rows already resident in the shared cache tier
                        // are local everywhere; a replica only pays for
                        // the uncached remainder
                        let cached =
                            cached_rows.get(j).copied().unwrap_or(0);
                        let extra = cards[j].saturating_sub(cached)
                            * (n_shards - 1);
                        let already = owners[j].len();
                        if already == n_shards || extra > budget {
                            continue;
                        }
                        budget -= extra;
                        owners[j] = (0..n_shards as u32).collect();
                    }
                }
            }
        }
        for o in owners.iter_mut() {
            o.sort_unstable();
            o.dedup();
        }
        ShardMap {
            n_shards,
            policy,
            owners,
        }
    }

    /// Placement for a dataset profile.
    pub fn for_profile(
        p: &Profile,
        n_shards: usize,
        policy: ShardPolicy,
    ) -> ShardMap {
        ShardMap::build(&p.cards, p.zipf_alpha, n_shards, policy)
    }

    pub fn n_tables(&self) -> usize {
        self.owners.len()
    }

    /// Sorted shard ids owning a replica of `table`.
    pub fn owners(&self, table: usize) -> &[u32] {
        &self.owners[table]
    }

    /// First (primary) owner of `table`.
    pub fn primary(&self, table: usize) -> usize {
        self.owners[table][0] as usize
    }

    pub fn owns(&self, shard: usize, table: usize) -> bool {
        self.owners[table].binary_search(&(shard as u32)).is_ok()
    }

    /// Tables with a replica on `shard` (ascending).
    pub fn tables_of(&self, shard: usize) -> Vec<usize> {
        (0..self.n_tables())
            .filter(|&j| self.owns(shard, j))
            .collect()
    }

    /// Rows stored on `shard` under this placement.
    pub fn rows_of(&self, shard: usize, cards: &[usize]) -> usize {
        (0..self.n_tables())
            .filter(|&j| self.owns(shard, j))
            .map(|j| cards[j])
            .sum()
    }

    /// Re-derive the ownership view after shard failures (S32): each
    /// table's owner list is filtered to the surviving shards —
    /// promoting `HotReplicated` replicas, which are byte-identical to
    /// the lost copy by construction, so gathers through the promoted
    /// map stay bit-identical to the monolithic store (pinned by
    /// `rust/tests/failover_prop.rs`). A table whose EVERY owner died
    /// keeps its original owners: the rows are still resident in
    /// process memory (shards are data, workers are compute), so the
    /// data-resident fallback keeps them readable cross-shard instead
    /// of poisoning `gather_from`'s owner-must-hold-the-table
    /// invariant. Promotion is a pure function of the ORIGINAL map and
    /// the CURRENT dead set, so repeated deaths compose in any order.
    pub fn promote(&self, dead: &[bool]) -> ShardMap {
        assert_eq!(
            dead.len(),
            self.n_shards,
            "dead set must cover every shard"
        );
        let owners = self
            .owners
            .iter()
            .map(|os| {
                let live: Vec<u32> = os
                    .iter()
                    .copied()
                    .filter(|&s| !dead[s as usize])
                    .collect();
                if live.is_empty() {
                    os.clone()
                } else {
                    live
                }
            })
            .collect();
        ShardMap {
            n_shards: self.n_shards,
            policy: self.policy,
            owners,
        }
    }

    /// Fraction of `fields` that `shard` can serve locally (1.0 when
    /// `fields` is empty — nothing needs to travel).
    pub fn local_fraction(&self, shard: usize, fields: &[u32]) -> f64 {
        if fields.is_empty() {
            return 1.0;
        }
        let local = fields
            .iter()
            .filter(|&&f| (f as usize) < self.n_tables() && self.owns(shard, f as usize))
            .count();
        local as f64 / fields.len() as f64
    }
}

/// Generalized harmonic number `H(c, α) = Σ_{k=1..c} 1/k^α` — the zipf
/// normaliser. `1/H(c, α)` is the head row's share of a table's traffic,
/// the heat score replication and cache admission both rank by.
pub fn harmonic(c: usize, alpha: f64) -> f64 {
    (1..=c.max(1)).map(|k| 1.0 / (k as f64).powf(alpha)).sum()
}

/// Tables by descending predicted head share `1/H(card, α)` (ties:
/// lower index first) — exactly the order the `HotReplicated` pass
/// spends its replica budget in, exported so property tests and the
/// cache tier can re-derive it independently.
pub fn heat_order(cards: &[usize], alpha: f64) -> Vec<usize> {
    let mut heat: Vec<(usize, f64)> = (0..cards.len())
        .map(|j| (j, 1.0 / harmonic(cards[j], alpha)))
        .collect();
    heat.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    heat.into_iter().map(|(j, _)| j).collect()
}

/// One worker's slice of the embedding layer: the tables its shard
/// owns, byte-identical to the monolithic store's tables.
pub struct EmbeddingShard {
    pub shard_id: usize,
    pub d_emb: usize,
    /// global per-table cardinalities (all tables, owned or not)
    pub cards: Vec<usize>,
    /// `tables[j]` is `Some(rows)` iff this shard owns table `j`
    tables: Vec<Option<Vec<f32>>>,
}

impl EmbeddingShard {
    /// Carve this shard's tables out of a monolithic store.
    pub fn from_store(
        store: &EmbeddingStore,
        map: &ShardMap,
        shard_id: usize,
    ) -> EmbeddingShard {
        let tables = (0..store.n_fields())
            .map(|j| map.owns(shard_id, j).then(|| store.table(j).to_vec()))
            .collect();
        EmbeddingShard {
            shard_id,
            d_emb: store.d_emb,
            cards: store.cards.clone(),
            tables,
        }
    }

    /// Generate ONLY the owned tables, row-identical to
    /// `EmbeddingStore::random(profile, d_emb, seed)` — each table has
    /// its own substream (shared `random_table` recipe), so skipping
    /// unowned tables is free.
    pub fn random(
        profile: &Profile,
        d_emb: usize,
        seed: u64,
        map: &ShardMap,
        shard_id: usize,
    ) -> EmbeddingShard {
        let tables = profile
            .cards
            .iter()
            .enumerate()
            .map(|(j, &c)| {
                map.owns(shard_id, j)
                    .then(|| super::store::random_table(seed, j, c, d_emb))
            })
            .collect();
        EmbeddingShard {
            shard_id,
            d_emb,
            cards: profile.cards.clone(),
            tables,
        }
    }

    pub fn owns(&self, table: usize) -> bool {
        table < self.tables.len() && self.tables[table].is_some()
    }

    /// One local row; `None` when this shard has no replica of `table`.
    /// `id` is normally already resolved in-range (see
    /// [`resolve_id`](super::store::resolve_id)); a raw out-of-range id
    /// falls back to row 0 — the OOV row — matching the monolithic
    /// store's semantics, never the old clamp-to-last aliasing.
    pub fn row(&self, table: usize, id: usize) -> Option<&[f32]> {
        let t = self.tables.get(table)?.as_ref()?;
        let d = self.d_emb;
        let id = if id < self.cards[table] { id } else { 0 };
        Some(&t[id * d..(id + 1) * d])
    }

    /// Rows resident on this shard.
    pub fn local_rows(&self) -> usize {
        self.tables
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_some())
            .map(|(j, _)| self.cards[j])
            .sum()
    }
}

/// All shards of one dataset plus the map — what the coordinator hands
/// to its workers. Worker `i` gathers from the perspective of shard
/// `i % n_shards`: owned tables are local reads, the rest are
/// cross-shard fetches (counted, so routing quality is measurable).
pub struct ShardedStore {
    pub map: ShardMap,
    pub shards: Vec<EmbeddingShard>,
    pub d_emb: usize,
    pub cards: Vec<usize>,
}

impl ShardedStore {
    /// Shard an existing monolithic store (rows are cloned per replica).
    pub fn build(store: &EmbeddingStore, map: ShardMap) -> ShardedStore {
        let shards = (0..map.n_shards)
            .map(|s| EmbeddingShard::from_store(store, &map, s))
            .collect();
        ShardedStore {
            d_emb: store.d_emb,
            cards: store.cards.clone(),
            shards,
            map,
        }
    }

    /// Random tables without materializing the monolithic store first;
    /// row-identical to sharding `EmbeddingStore::random` directly.
    pub fn random(
        profile: &Profile,
        d_emb: usize,
        seed: u64,
        map: ShardMap,
    ) -> ShardedStore {
        let shards = (0..map.n_shards)
            .map(|s| EmbeddingShard::random(profile, d_emb, seed, &map, s))
            .collect();
        ShardedStore {
            d_emb,
            cards: profile.cards.clone(),
            shards,
            map,
        }
    }

    pub fn n_fields(&self) -> usize {
        self.cards.len()
    }

    /// Rows across all tables (replicas not counted) — the global-row
    /// index space the cache tier and coalescer are keyed by.
    pub fn total_rows(&self) -> usize {
        self.cards.iter().sum()
    }

    /// Assemble one record's gather from the perspective of shard
    /// `local`: a zero-filled `[n_fields × d_emb]` block is appended to
    /// `out`, with row `ids[k]` of table `fields[k]` written at that
    /// field's slot. Returns `(local_rows, remote_rows, oob_ids)` — a
    /// row served by any shard other than `local` counts as one
    /// cross-shard fetch, and every out-of-range id (resolved to row 0,
    /// the OOV row, via [`resolve_id`](super::store::resolve_id)) is
    /// counted in the third slot.
    ///
    /// With `fields = 0..n_fields` the block is element-identical to
    /// `EmbeddingStore::gather` for the same ids (batch 1).
    pub fn gather_from(
        &self,
        local: usize,
        fields: &[u32],
        ids: &[i32],
        out: &mut Vec<f32>,
    ) -> (usize, usize, usize) {
        self.gather_from_with(&self.map, local, fields, ids, out)
    }

    /// [`ShardedStore::gather_from`] through an explicit ownership view
    /// — the failover path: after a worker dies, survivors gather
    /// through the [`ShardMap::promote`]d map so cross-shard fetches
    /// target live replicas instead of the dead shard. Values are
    /// placement-independent (replicas are byte-identical), so any view
    /// whose owners hold their tables yields the same bytes.
    pub fn gather_from_with(
        &self,
        map: &ShardMap,
        local: usize,
        fields: &[u32],
        ids: &[i32],
        out: &mut Vec<f32>,
    ) -> (usize, usize, usize) {
        debug_assert_eq!(fields.len(), ids.len());
        let nf = self.n_fields();
        let d = self.d_emb;
        let base = out.len();
        out.resize(base + nf * d, 0.0);
        let (mut n_local, mut n_remote, mut n_oob) = (0usize, 0usize, 0usize);
        for (k, &f) in fields.iter().enumerate() {
            let j = f as usize;
            if j >= nf {
                continue;
            }
            // shared OOV semantics with the monolithic gather: negative
            // or past-card ids resolve to row 0, bit-identically
            let (id, was_oob) = resolve_id(ids[k], self.cards[j]);
            n_oob += was_oob as usize;
            let serve = if map.owns(local, j) {
                n_local += 1;
                local
            } else {
                n_remote += 1;
                map.primary(j)
            };
            let row = self.shards[serve]
                .row(j, id)
                .expect("shard map owner must hold the table");
            out[base + j * d..base + (j + 1) * d].copy_from_slice(row);
        }
        (n_local, n_remote, n_oob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile;

    #[test]
    fn round_robin_tables_is_modulo() {
        let p = profile("criteo").unwrap();
        let m = ShardMap::for_profile(&p, 4, ShardPolicy::RoundRobinTables);
        for j in 0..m.n_tables() {
            assert_eq!(m.owners(j), &[(j % 4) as u32]);
        }
    }

    #[test]
    fn capacity_balanced_partitions_all_tables() {
        let p = profile("criteo").unwrap();
        let m = ShardMap::for_profile(&p, 3, ShardPolicy::CapacityBalanced);
        let mut seen = 0usize;
        for s in 0..3 {
            seen += m.tables_of(s).len();
        }
        assert_eq!(seen, p.n_sparse()); // exactly-one owner per table
        let rows: Vec<usize> = (0..3).map(|s| m.rows_of(s, &p.cards)).collect();
        let ideal = p.cards.iter().sum::<usize>() / 3;
        for &r in &rows {
            assert!(r <= 2 * ideal.max(*p.cards.iter().max().unwrap()));
        }
    }

    #[test]
    fn hot_replication_replicates_small_skewed_tables() {
        let p = profile("criteo").unwrap();
        let m = ShardMap::for_profile(&p, 4, ShardPolicy::HotReplicated);
        let replicated: Vec<usize> =
            (0..m.n_tables()).filter(|&j| m.owners(j).len() == 4).collect();
        assert!(!replicated.is_empty(), "budget should afford some replicas");
        // the replicated set must be the small tables (hot heads)
        let max_rep = replicated.iter().map(|&j| p.cards[j]).max().unwrap();
        let max_card = *p.cards.iter().max().unwrap();
        assert!(max_rep < max_card);
        // budget respected (exact rounding, not truncation)
        let total: usize = p.cards.iter().sum();
        let stored: usize = (0..4).map(|s| m.rows_of(s, &p.cards)).sum();
        assert!(stored <= total + (total as f64 * REPLICA_BUDGET).round() as usize);
    }

    #[test]
    fn cached_head_rows_stretch_the_replica_budget() {
        let p = profile("criteo").unwrap();
        let plain = ShardMap::for_profile(&p, 4, ShardPolicy::HotReplicated);
        // pretend a cache pins the 64 hottest rows of every table: each
        // replica cost drops by 64·(n-1), and the placement must follow
        // the SAME first-fit-decreasing walk with those discounted costs
        // (mirror-simulated here — the discount can re-shuffle which
        // tables fit, so "superset of plain" is NOT the contract; the
        // documented walk is)
        let cached = vec![64usize; p.n_sparse()];
        let m = ShardMap::build_cached(
            &p.cards,
            p.zipf_alpha,
            4,
            ShardPolicy::HotReplicated,
            &cached,
        );
        let total: usize = p.cards.iter().sum();
        let mut remaining = (total as f64 * REPLICA_BUDGET).round() as usize;
        let mut expect = vec![false; p.n_sparse()];
        for j in heat_order(&p.cards, p.zipf_alpha) {
            let extra = p.cards[j].saturating_sub(64) * 3;
            if extra <= remaining {
                remaining -= extra;
                expect[j] = true;
            }
        }
        let mut replicated = 0usize;
        for j in 0..m.n_tables() {
            assert_eq!(
                m.owners(j).len() == 4,
                expect[j],
                "table {j} diverges from the discounted FFD walk"
            );
            replicated += (m.owners(j).len() == 4) as usize;
        }
        assert!(replicated > 0, "the discount must afford some replicas");
        // an empty cache slice is exactly the plain build
        let zero =
            ShardMap::build_cached(&p.cards, p.zipf_alpha, 4, ShardPolicy::HotReplicated, &[]);
        for j in 0..zero.n_tables() {
            assert_eq!(zero.owners(j), plain.owners(j));
        }
    }

    #[test]
    fn promote_filters_dead_owners_and_keeps_orphans_readable() {
        let p = profile("criteo").unwrap();
        let m = ShardMap::for_profile(&p, 4, ShardPolicy::HotReplicated);
        // shard 2 dies: replicated tables lose exactly that owner,
        // sole-owner tables of shard 2 keep it (data-resident fallback)
        let promoted = m.promote(&[false, false, true, false]);
        for j in 0..m.n_tables() {
            let before = m.owners(j);
            let after = promoted.owners(j);
            assert!(!after.is_empty(), "table {j} lost all owners");
            if before == [2] {
                assert_eq!(after, before, "orphan table {j} must stay readable");
            } else {
                assert!(!after.contains(&2), "table {j} still lists the dead shard");
                assert!(
                    after.iter().all(|s| before.contains(s)),
                    "promotion must never invent owners"
                );
            }
            // the owner-holds-the-table invariant survives either way
            for &s in after {
                assert!(m.owns(s as usize, j));
            }
        }
        // no deaths ⇒ identity
        let same = m.promote(&[false; 4]);
        for j in 0..m.n_tables() {
            assert_eq!(same.owners(j), m.owners(j));
        }
    }

    #[test]
    fn gather_with_promoted_map_is_bit_identical() {
        let p = profile("kdd").unwrap();
        let store = EmbeddingStore::random(&p, 8, 11);
        let m = ShardMap::for_profile(&p, 3, ShardPolicy::HotReplicated);
        let sharded = ShardedStore::build(&store, m);
        let promoted = sharded.map.promote(&[false, true, false]);
        let nf = p.n_sparse();
        let fields: Vec<u32> = (0..nf as u32).collect();
        let ids: Vec<i32> = (0..nf as i32).map(|i| i % 7).collect();
        let mut mono = Vec::new();
        store.gather(&ids, 1, &mut mono);
        for local in [0, 2] {
            let mut out = Vec::new();
            sharded.gather_from_with(&promoted, local, &fields, &ids, &mut out);
            assert_eq!(out, mono, "promoted gather diverged (local {local})");
        }
    }

    #[test]
    fn local_fraction_counts_owned_tables() {
        let m = ShardMap::build(&[10, 10, 10, 10], 1.2, 2, ShardPolicy::RoundRobinTables);
        // shard 0 owns tables 0, 2
        assert_eq!(m.local_fraction(0, &[0, 2]), 1.0);
        assert_eq!(m.local_fraction(0, &[1, 3]), 0.0);
        assert_eq!(m.local_fraction(0, &[0, 1]), 0.5);
        assert_eq!(m.local_fraction(0, &[]), 1.0);
    }

    #[test]
    fn sharded_gather_matches_monolithic_full_fields() {
        let p = profile("kdd").unwrap();
        let store = EmbeddingStore::random(&p, 8, 11);
        let m = ShardMap::for_profile(&p, 3, ShardPolicy::CapacityBalanced);
        let sharded = ShardedStore::build(&store, m);
        let nf = p.n_sparse();
        let fields: Vec<u32> = (0..nf as u32).collect();
        let ids: Vec<i32> = (0..nf as i32).map(|i| i % 5).collect();
        let mut mono = Vec::new();
        store.gather(&ids, 1, &mut mono);
        for local in 0..3 {
            let mut out = Vec::new();
            let (l, r, oob) = sharded.gather_from(local, &fields, &ids, &mut out);
            assert_eq!(out, mono);
            assert_eq!(l + r, nf);
            assert_eq!(oob, 0);
        }
    }

    #[test]
    fn random_shard_rows_match_random_store() {
        let p = profile("avazu").unwrap();
        let store = EmbeddingStore::random(&p, 4, 99);
        let m = ShardMap::for_profile(&p, 2, ShardPolicy::HotReplicated);
        for s in 0..2 {
            let shard = EmbeddingShard::random(&p, 4, 99, &m, s);
            for j in 0..p.n_sparse() {
                if shard.owns(j) {
                    assert_eq!(shard.row(j, 0).unwrap(), store.row(j, 0));
                    let last = p.cards[j] - 1;
                    assert_eq!(shard.row(j, last).unwrap(), store.row(j, last));
                }
            }
        }
    }

    #[test]
    fn out_of_range_ids_resolve_like_monolithic() {
        let p = profile("kdd").unwrap();
        let store = EmbeddingStore::random(&p, 8, 5);
        let m = ShardMap::for_profile(&p, 2, ShardPolicy::RoundRobinTables);
        let sharded = ShardedStore::build(&store, m);
        let nf = p.n_sparse();
        let fields: Vec<u32> = (0..nf as u32).collect();
        for hostile in [-1i32, i32::MIN, i32::MAX] {
            let ids = vec![hostile; nf];
            let mut mono = Vec::new();
            let mono_oob = store.gather(&ids, 1, &mut mono);
            let mut out = Vec::new();
            let (_, _, oob) = sharded.gather_from(0, &fields, &ids, &mut out);
            assert_eq!(out, mono, "id {hostile}");
            assert_eq!(oob, nf);
            assert_eq!(mono_oob, nf);
            // and all of it is the row-0 OOV embedding
            for j in 0..nf {
                assert_eq!(&out[j * 8..(j + 1) * 8], store.row(j, 0));
            }
        }
    }
}
