//! Serving metrics: counters + constant-memory latency histograms,
//! shared across workers behind a light mutex (snapshots are cheap; the
//! hot path records two integers).

use crate::util::stats::LogHistogram;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    batched_requests: u64,
    e2e: LogHistogram,
    queue: LogHistogram,
    exec: LogHistogram,
}

pub struct Metrics {
    inner: Mutex<Inner>,
    started: Mutex<Instant>,
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub elapsed_s: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Mutex::new(Instant::now()),
        }
    }

    /// Restart the throughput clock — called by the coordinator once all
    /// workers are ready, so executable-compile time (tens of seconds
    /// for the crossbar-emulation HLO) does not dilute the rates.
    pub fn reset_clock(&self) {
        *self.started.lock().unwrap() = Instant::now();
    }

    pub fn on_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn on_batch(&self, size: usize, queue_ns: u64, exec_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_requests += size as u64;
        m.queue.record_ns(queue_ns);
        m.exec.record_ns(exec_ns);
    }

    pub fn on_response(&self, e2e_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.responses += 1;
        m.e2e.record_ns(e2e_ns);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let elapsed = self.started.lock().unwrap().elapsed().as_secs_f64();
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            batches: m.batches,
            mean_batch: if m.batches == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.batches as f64
            },
            throughput_rps: m.responses as f64 / elapsed.max(1e-9),
            e2e_p50_us: m.e2e.quantile_ns(0.5) as f64 / 1e3,
            e2e_p99_us: m.e2e.quantile_ns(0.99) as f64 / 1e3,
            queue_p99_us: m.queue.quantile_ns(0.99) as f64 / 1e3,
            exec_p50_us: m.exec.quantile_ns(0.5) as f64 / 1e3,
            elapsed_s: elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_request();
        }
        m.on_batch(8, 1_000, 50_000);
        m.on_batch(2, 2_000, 30_000);
        for _ in 0..10 {
            m.on_response(100_000);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.responses, 10);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
        assert!(s.e2e_p50_us >= 100.0);
        assert!(s.throughput_rps > 0.0);
    }
}
