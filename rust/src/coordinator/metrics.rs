//! Serving metrics: counters + constant-memory latency histograms,
//! shared across workers behind a light mutex (snapshots are cheap; the
//! hot path records two integers). Admission control (rejected/shed)
//! and the sharded gather path (local vs cross-shard rows) report here,
//! and worker queue-depth gauges are registered at startup so a
//! snapshot shows instantaneous backpressure per worker.

use crate::embeddings::hotcache::GatherStats;
use crate::pim::FaultCounts;
use crate::util::stats::LogHistogram;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    batched_requests: u64,
    /// admission control: turned away at the door (queue at capacity)
    rejected: u64,
    /// load shedding: dequeued too late and dropped by the worker
    shed: u64,
    /// requests lost to engine failures (whole batch dropped)
    failed: u64,
    /// deadline propagation (S33): requests dropped at dequeue because
    /// their end-to-end deadline had already passed
    expired: u64,
    /// of `rejected`, those turned away because no worker could meet
    /// the request's deadline budget (depth × EWMA admission check)
    deadline_rejected: u64,
    /// hedged dispatch (S33): duplicate copies issued / copies that
    /// won their gate / copies that lost it (non-ledger — the winner
    /// books the terminal leg)
    hedges: u64,
    hedges_won: u64,
    hedge_suppressed: u64,
    /// brownout (S33): responses served in cache-only degraded mode,
    /// rows skipped (zero-filled) by degraded gathers, and distinct
    /// brownout entries
    degraded_responses: u64,
    degraded_rows: u64,
    brownout_entries: u64,
    /// sharded gather accounting (rows served locally vs fetched from
    /// a peer shard)
    local_rows: u64,
    remote_rows: u64,
    /// out-of-range ids resolved to the row-0 OOV embedding
    oob_ids: u64,
    /// hot-row cache tier (S29): lookups split by outcome, plus
    /// warm-phase evictions copied in once at startup
    cache_hits: u64,
    cache_misses: u64,
    cache_evictions: u64,
    /// duplicate rows served by the batch coalescer's scatter (S30)
    coalesced_rows: u64,
    /// device fault tolerance (S34): ABFT detection events (a tile can
    /// be counted more than once across repair re-runs), spare-tile
    /// repairs, and responses computed on a degraded (unrepairable)
    /// bank — non-ledger: a corrupted response is still a response
    tiles_faulty: u64,
    tiles_repaired: u64,
    corrupted_responses: u64,
    e2e: LogHistogram,
    queue: LogHistogram,
    exec: LogHistogram,
}

pub struct Metrics {
    inner: Mutex<Inner>,
    started: Mutex<Instant>,
    /// per-worker queue-depth gauges (registered by the coordinator)
    depths: Mutex<Vec<Arc<AtomicUsize>>>,
    /// per-worker liveness flags (flipped by the router or the worker's
    /// lifecycle guard when a worker dies)
    alive: Mutex<Vec<Arc<AtomicBool>>>,
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub rejected: u64,
    pub shed: u64,
    /// requests dropped because the engine failed their batch
    pub failed: u64,
    /// requests dropped at dequeue with their deadline already blown
    pub expired: u64,
    /// of `rejected`, those refused by the deadline admission check
    pub deadline_rejected: u64,
    /// hedge copies issued / won / suppressed (S33)
    pub hedges: u64,
    pub hedges_won: u64,
    pub hedge_suppressed: u64,
    /// brownout accounting: degraded-mode responses, zero-filled rows,
    /// and distinct brownout entries
    pub degraded_responses: u64,
    pub degraded_rows: u64,
    pub brownout_entries: u64,
    /// embedding rows gathered on the worker's own shard
    pub local_rows: u64,
    /// embedding rows fetched cross-shard
    pub remote_rows: u64,
    /// out-of-range ids resolved to the row-0 OOV embedding
    pub oob_ids: u64,
    /// hot-row cache lookups that hit / missed (both 0 with no cache)
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// warm-phase cache evictions (final once serving starts — the
    /// cache is immutable after warmup)
    pub cache_evictions: u64,
    /// duplicate rows the batch coalescer served without a fetch
    pub coalesced_rows: u64,
    /// ABFT checksum mismatches flagged on the device (S34) — detection
    /// events, so repair re-runs can count the same tile again
    pub tiles_faulty: u64,
    /// corrupted tiles remapped onto spare tiles and reprogrammed
    pub tiles_repaired: u64,
    /// responses served from a degraded bank (flagged corruption, no
    /// spare left to repair it) — non-ledger, parallels `degraded_responses`
    pub corrupted_responses: u64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub elapsed_s: f64,
    /// instantaneous queue depth per worker at snapshot time
    pub worker_depths: Vec<usize>,
    /// per-worker liveness at snapshot time (parallel to `worker_depths`)
    pub workers_alive: Vec<bool>,
}

impl MetricsSnapshot {
    /// Fraction of gathered rows that crossed shards (0 when nothing
    /// was gathered through the sharded path).
    pub fn cross_shard_frac(&self) -> f64 {
        let total = self.local_rows + self.remote_rows;
        if total == 0 {
            0.0
        } else {
            self.remote_rows as f64 / total as f64
        }
    }

    /// Fraction of cache lookups that hit (0 when the cache saw no
    /// traffic — disabled or never consulted).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Workers still accepting requests at snapshot time.
    pub fn live_workers(&self) -> usize {
        self.workers_alive.iter().filter(|&&a| a).count()
    }

    /// The conservation ledger, as a checkable predicate: every request
    /// is answered, rejected, shed, failed, or expired — nothing
    /// vanishes, even across a worker crash or a hedged duplicate (the
    /// gate admits exactly one terminal booking per request).
    pub fn ledger_ok(&self) -> bool {
        self.requests
            == self.responses + self.rejected + self.shed + self.failed + self.expired
    }

    /// Fraction of accepted-and-answered traffic that was hedged.
    pub fn hedge_rate(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.hedges as f64 / self.responses as f64
        }
    }

    /// Fraction of arriving requests turned away or shed.
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            (self.rejected + self.shed) as f64 / self.requests as f64
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Mutex::new(Instant::now()),
            depths: Mutex::new(Vec::new()),
            alive: Mutex::new(Vec::new()),
        }
    }

    /// Restart the throughput clock — called by the coordinator once all
    /// workers are ready, so executable-compile time (tens of seconds
    /// for the crossbar-emulation HLO) does not dilute the rates.
    pub fn reset_clock(&self) {
        *self.started.lock().unwrap() = Instant::now();
    }

    /// Expose worker `i`'s queue-depth counter in snapshots. Called once
    /// per worker at coordinator startup, in worker order.
    pub fn register_worker_depth(&self, depth: Arc<AtomicUsize>) {
        self.depths.lock().unwrap().push(depth);
    }

    /// Expose worker `i`'s liveness flag in snapshots. Called once per
    /// worker at coordinator startup, in worker order.
    pub fn register_worker_alive(&self, alive: Arc<AtomicBool>) {
        self.alive.lock().unwrap().push(alive);
    }

    /// Lightweight read of the failed counter (one lock, no histogram
    /// work) — the scenario probe polls this per accepted request to
    /// classify sends as pre- or post-crash.
    pub fn failed_count(&self) -> u64 {
        self.inner.lock().unwrap().failed
    }

    pub fn on_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn on_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn on_shed(&self, n: usize) {
        self.inner.lock().unwrap().shed += n as u64;
    }

    pub fn on_failed(&self, n: usize) {
        self.inner.lock().unwrap().failed += n as u64;
    }

    /// Book `n` requests dropped at dequeue with their deadline blown.
    pub fn on_expired(&self, n: usize) {
        self.inner.lock().unwrap().expired += n as u64;
    }

    /// Deadline admission refusal: a `rejected` ledger leg, with the
    /// deadline sub-cause counted alongside.
    pub fn on_deadline_rejected(&self) {
        let mut m = self.inner.lock().unwrap();
        m.rejected += 1;
        m.deadline_rejected += 1;
    }

    /// One hedge copy issued (non-ledger: the copy is not a request).
    pub fn on_hedge(&self) {
        self.inner.lock().unwrap().hedges += 1;
    }

    /// A hedge copy won its gate and produced the response.
    pub fn on_hedge_won(&self) {
        self.inner.lock().unwrap().hedges_won += 1;
    }

    /// A duplicate copy lost its gate and was dropped unbooked.
    pub fn on_hedge_suppressed(&self) {
        self.inner.lock().unwrap().hedge_suppressed += 1;
    }

    /// One brownout batch: `n` responses served cache/local-only, with
    /// `rows` remote rows skipped (zero-filled).
    pub fn on_degraded(&self, n: usize, rows: usize) {
        let mut m = self.inner.lock().unwrap();
        m.degraded_responses += n as u64;
        m.degraded_rows += rows as u64;
    }

    /// The brownout controller flipped from clear to active.
    pub fn on_brownout_entry(&self) {
        self.inner.lock().unwrap().brownout_entries += 1;
    }

    /// One-lock read of the brownout pressure inputs: `(requests,
    /// expired + shed + rejected)` — the governor diffs successive
    /// reads to estimate the windowed bad-outcome fraction.
    pub fn pressure_counts(&self) -> (u64, u64) {
        let m = self.inner.lock().unwrap();
        (m.requests, m.expired + m.shed + m.rejected)
    }

    /// Record one batch's gather ledger: locality, cache outcomes,
    /// coalesced duplicates, and OOV resolutions — one lock for all six.
    pub fn on_gather(&self, gs: &GatherStats) {
        let mut m = self.inner.lock().unwrap();
        m.local_rows += gs.local as u64;
        m.remote_rows += gs.remote as u64;
        m.oob_ids += gs.oob as u64;
        m.cache_hits += gs.cache_hits as u64;
        m.cache_misses += gs.cache_misses as u64;
        m.coalesced_rows += gs.coalesced as u64;
    }

    /// Copy in the cache's warm-phase eviction count (called once at
    /// startup; the serving-phase cache never evicts).
    pub fn on_cache_evictions(&self, n: u64) {
        self.inner.lock().unwrap().cache_evictions += n;
    }

    /// Book one worker's drained device-fault counters (S34): ABFT
    /// detections, spare-tile repairs, and rows served degraded — one
    /// lock for all three.
    pub fn on_device_faults(&self, fc: &FaultCounts) {
        let mut m = self.inner.lock().unwrap();
        m.tiles_faulty += fc.tiles_faulty;
        m.tiles_repaired += fc.tiles_repaired;
        m.corrupted_responses += fc.corrupt_rows;
    }

    pub fn on_batch(&self, size: usize, queue_ns: u64, exec_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_requests += size as u64;
        m.queue.record_ns(queue_ns);
        m.exec.record_ns(exec_ns);
    }

    pub fn on_response(&self, e2e_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.responses += 1;
        m.e2e.record_ns(e2e_ns);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let elapsed = self.started.lock().unwrap().elapsed().as_secs_f64();
        let worker_depths = self
            .depths
            .lock()
            .unwrap()
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .collect();
        let workers_alive = self
            .alive
            .lock()
            .unwrap()
            .iter()
            .map(|a| a.load(Ordering::Acquire))
            .collect();
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            batches: m.batches,
            rejected: m.rejected,
            shed: m.shed,
            failed: m.failed,
            expired: m.expired,
            deadline_rejected: m.deadline_rejected,
            hedges: m.hedges,
            hedges_won: m.hedges_won,
            hedge_suppressed: m.hedge_suppressed,
            degraded_responses: m.degraded_responses,
            degraded_rows: m.degraded_rows,
            brownout_entries: m.brownout_entries,
            local_rows: m.local_rows,
            remote_rows: m.remote_rows,
            oob_ids: m.oob_ids,
            cache_hits: m.cache_hits,
            cache_misses: m.cache_misses,
            cache_evictions: m.cache_evictions,
            coalesced_rows: m.coalesced_rows,
            tiles_faulty: m.tiles_faulty,
            tiles_repaired: m.tiles_repaired,
            corrupted_responses: m.corrupted_responses,
            mean_batch: if m.batches == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.batches as f64
            },
            throughput_rps: m.responses as f64 / elapsed.max(1e-9),
            e2e_p50_us: m.e2e.quantile_ns(0.5) as f64 / 1e3,
            e2e_p99_us: m.e2e.quantile_ns(0.99) as f64 / 1e3,
            queue_p99_us: m.queue.quantile_ns(0.99) as f64 / 1e3,
            exec_p50_us: m.exec.quantile_ns(0.5) as f64 / 1e3,
            elapsed_s: elapsed,
            worker_depths,
            workers_alive,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_request();
        }
        m.on_batch(8, 1_000, 50_000);
        m.on_batch(2, 2_000, 30_000);
        for _ in 0..10 {
            m.on_response(100_000);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.responses, 10);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
        assert!(s.e2e_p50_us >= 100.0);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn admission_and_gather_counters() {
        let m = Metrics::new();
        for _ in 0..4 {
            m.on_request();
        }
        m.on_rejected();
        m.on_shed(2);
        m.on_gather(&GatherStats {
            requested: 40,
            local: 30,
            remote: 10,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.shed, 2);
        assert_eq!((s.local_rows, s.remote_rows), (30, 10));
        assert!((s.cross_shard_frac() - 0.25).abs() < 1e-12);
        assert!((s.shed_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.cache_hit_rate(), 0.0, "no cache traffic yet");
    }

    #[test]
    fn cache_and_oov_counters_accumulate() {
        let m = Metrics::new();
        m.on_gather(&GatherStats {
            requested: 100,
            local: 10,
            remote: 5,
            cache_hits: 60,
            cache_misses: 15,
            coalesced: 25,
            oob: 3,
        });
        m.on_gather(&GatherStats {
            requested: 20,
            cache_hits: 20,
            ..Default::default()
        });
        m.on_cache_evictions(7);
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 80);
        assert_eq!(s.cache_misses, 15);
        assert_eq!(s.cache_evictions, 7);
        assert_eq!(s.coalesced_rows, 25);
        assert_eq!(s.oob_ids, 3);
        assert!((s.cache_hit_rate() - 80.0 / 95.0).abs() < 1e-12);
    }

    #[test]
    fn extended_ledger_and_tail_counters() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_request();
        }
        for _ in 0..5 {
            m.on_response(1_000);
        }
        m.on_rejected();
        m.on_deadline_rejected();
        m.on_shed(1);
        m.on_failed(1);
        m.on_expired(1);
        m.on_hedge();
        m.on_hedge_won();
        m.on_hedge_suppressed();
        m.on_degraded(3, 12);
        m.on_brownout_entry();
        let s = m.snapshot();
        assert_eq!(s.expired, 1);
        assert_eq!(s.rejected, 2, "deadline refusal is a rejected leg");
        assert_eq!(s.deadline_rejected, 1);
        assert!(s.ledger_ok(), "5 + 2 + 1 + 1 + 1 must balance 10: {s:?}");
        assert_eq!((s.hedges, s.hedges_won, s.hedge_suppressed), (1, 1, 1));
        assert!((s.hedge_rate() - 0.2).abs() < 1e-12);
        assert_eq!((s.degraded_responses, s.degraded_rows), (3, 12));
        assert_eq!(s.brownout_entries, 1);
        assert_eq!(m.pressure_counts(), (10, 4));
        m.on_expired(1);
        assert!(!m.snapshot().ledger_ok(), "expired is a ledger leg");
    }

    #[test]
    fn device_fault_counters_accumulate_off_ledger() {
        let m = Metrics::new();
        for _ in 0..4 {
            m.on_request();
        }
        for _ in 0..4 {
            m.on_response(1_000);
        }
        m.on_device_faults(&FaultCounts {
            tiles_faulty: 3,
            tiles_repaired: 2,
            corrupt_rows: 4,
        });
        m.on_device_faults(&FaultCounts {
            tiles_faulty: 1,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!(s.tiles_faulty, 4);
        assert_eq!(s.tiles_repaired, 2);
        assert_eq!(s.corrupted_responses, 4);
        assert!(
            s.ledger_ok(),
            "corrupted responses are still responses — not a ledger leg"
        );
    }

    #[test]
    fn worker_depth_gauges_report() {
        let m = Metrics::new();
        let d0 = Arc::new(AtomicUsize::new(0));
        let d1 = Arc::new(AtomicUsize::new(0));
        m.register_worker_depth(d0.clone());
        m.register_worker_depth(d1.clone());
        d1.store(7, Ordering::Relaxed);
        assert_eq!(m.snapshot().worker_depths, vec![0, 7]);
    }

    #[test]
    fn liveness_flags_and_ledger_report() {
        let m = Metrics::new();
        let a0 = Arc::new(AtomicBool::new(true));
        let a1 = Arc::new(AtomicBool::new(true));
        m.register_worker_alive(a0.clone());
        m.register_worker_alive(a1.clone());
        for _ in 0..5 {
            m.on_request();
        }
        m.on_response(1_000);
        m.on_rejected();
        m.on_shed(1);
        m.on_failed(2);
        a1.store(false, Ordering::Release);
        let s = m.snapshot();
        assert_eq!(s.workers_alive, vec![true, false]);
        assert_eq!(s.live_workers(), 1);
        assert!(s.ledger_ok(), "1 + 1 + 1 + 2 must balance 5");
        assert_eq!(m.failed_count(), 2);
        m.on_request();
        assert!(!m.snapshot().ledger_ok());
    }
}
