//! Gray-failure tail tolerance (S33): the shared state machines behind
//! deadline admission, hedged dispatch, slow-worker quarantine, and
//! brownout degradation.
//!
//! A *gray* failure is a worker that is alive yet slow — a straggling
//! PIM bank, a saturated queue, a degraded shard. Fail-stop crashes are
//! handled by the S31/S32 machinery (slot closure + replica promotion);
//! this module bounds how long a request can be held hostage by a
//! worker that never dies:
//!
//! * [`HedgeGate`] — one atomic claim per logical request. The primary
//!   copy and its hedge race; the FIRST terminal outcome (response,
//!   shed, expiry, failure, drain) claims the gate and books the
//!   ledger, the loser books only the non-ledger `hedge_suppressed`
//!   counter. This is the duplicate-suppression argument: a swap on an
//!   `AtomicBool` admits exactly one winner under any interleaving, so
//!   no request is ever answered twice and the extended conservation
//!   ledger (`requests == responses + rejected + shed + failed +
//!   expired`) stays exact under hedging.
//! * [`FleetHealth`] — per-worker EWMA of service time feeding a
//!   three-state breaker (healthy → probation → quarantined). Each
//!   worker writes only its own atomics (its serving thread is the
//!   sole recorder), routers read all of them.
//! * [`HedgeBudget`] — a token budget capping hedges at
//!   `max(1, accepted × hedge_budget)`, so a uniformly sick fleet
//!   cannot melt down from retry amplification.
//!
//! Everything here is inert unless [`CoordinatorConfig::tail`] is set
//! (`None` by default ⇒ bit-identical pre-existing behavior).
//!
//! [`CoordinatorConfig::tail`]: super::server::CoordinatorConfig

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Tail-tolerance knobs, attached via `CoordinatorConfig::tail`.
#[derive(Clone, Debug)]
pub struct TailConfig {
    /// hedge a request still unanswered after this queue age
    pub hedge_after: Duration,
    /// hedges issued ≤ `max(1, accepted × hedge_budget)`
    pub hedge_budget: f64,
    /// governor cadence (hedge scan + brownout pressure evaluation)
    pub tick: Duration,
    /// a service-time sample is "slow" when it exceeds
    /// `slow_factor ×` the best *peer* EWMA
    pub slow_factor: f64,
    /// consecutive slow samples per breaker demotion (and consecutive
    /// fast samples to graduate probation)
    pub strikes: u32,
    /// with a quarantined worker present, every `probe_interval`-th
    /// pick is diverted to it as trickle probe traffic
    pub probe_interval: u64,
    /// enter brownout when windowed bad-outcome pressure ≥ this
    pub brownout_enter: f64,
    /// exit brownout when windowed pressure ≤ this (hysteresis)
    pub brownout_exit: f64,
}

impl Default for TailConfig {
    fn default() -> TailConfig {
        TailConfig {
            hedge_after: Duration::from_millis(5),
            hedge_budget: 0.1,
            tick: Duration::from_millis(1),
            slow_factor: 4.0,
            strikes: 3,
            probe_interval: 64,
            brownout_enter: 0.2,
            brownout_exit: 0.05,
        }
    }
}

/// One logical request's terminal-outcome claim. `claim` is a single
/// atomic swap: exactly one caller ever sees `true`, under any thread
/// interleaving — the winner books the ledger and replies, every loser
/// stands down.
#[derive(Default)]
pub struct HedgeGate {
    claimed: AtomicBool,
}

impl HedgeGate {
    pub fn new() -> HedgeGate {
        HedgeGate::default()
    }

    /// Try to claim the terminal outcome; `true` for exactly one caller.
    pub fn claim(&self) -> bool {
        !self.claimed.swap(true, Ordering::AcqRel)
    }

    /// Non-consuming read (the governor prunes claimed pending entries).
    pub fn is_claimed(&self) -> bool {
        self.claimed.load(Ordering::Acquire)
    }
}

/// The claim handle carried by each enqueued copy of a request, plus
/// which copy this is (the hedge books `hedges_won` when it wins).
#[derive(Clone)]
pub struct HedgeTag {
    pub gate: Arc<HedgeGate>,
    pub is_hedge: bool,
}

/// Hedge token budget: `try_take` admits the k-th hedge only while
/// `k ≤ max(1, accepted × frac)` — a CAS loop, so concurrent takers
/// never overshoot the cap.
pub struct HedgeBudget {
    frac: f64,
    issued: AtomicU64,
}

impl HedgeBudget {
    pub fn new(frac: f64) -> HedgeBudget {
        HedgeBudget {
            frac: frac.max(0.0),
            issued: AtomicU64::new(0),
        }
    }

    /// Take one hedge token against the current accepted count.
    pub fn try_take(&self, accepted: u64) -> bool {
        let cap = ((accepted as f64 * self.frac) as u64).max(1);
        self.issued
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |i| {
                (i < cap).then_some(i + 1)
            })
            .is_ok()
    }

    /// Hedges issued so far.
    pub fn issued(&self) -> u64 {
        self.issued.load(Ordering::Relaxed)
    }
}

/// Breaker state of one worker. The routing rank is the discriminant:
/// healthy workers are preferred, probation workers rank after them,
/// quarantined workers receive no normal traffic at all (only trickle
/// probes reach them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Healthy,
    Probation,
    Quarantined,
}

impl BreakerState {
    fn from_u8(v: u8) -> BreakerState {
        match v {
            0 => BreakerState::Healthy,
            1 => BreakerState::Probation,
            _ => BreakerState::Quarantined,
        }
    }

    /// Routing rank: lower is preferred.
    pub fn rank(self) -> u8 {
        self as u8
    }
}

/// One worker's health cell. The owning worker's serving thread is the
/// only writer (per-batch `record`); routers and the admission check
/// read concurrently.
struct WorkerHealth {
    /// EWMA of per-request service time, ns, as f64 bits; 0.0 = no
    /// samples yet
    ewma_ns: AtomicU64,
    /// `BreakerState` discriminant
    state: AtomicU8,
    slow_strikes: AtomicU32,
    fast_strikes: AtomicU32,
}

impl WorkerHealth {
    fn new() -> WorkerHealth {
        WorkerHealth {
            ewma_ns: AtomicU64::new(0.0f64.to_bits()),
            state: AtomicU8::new(BreakerState::Healthy as u8),
            slow_strikes: AtomicU32::new(0),
            fast_strikes: AtomicU32::new(0),
        }
    }
}

/// Router-side fleet health: per-worker service-time EWMAs and breaker
/// states, plus the probe ticket counter the router's trickle-probe
/// diversion draws from.
///
/// State machine (k = `strikes`):
///
/// ```text
///            k slow samples          k slow samples
///  Healthy ────────────────► Probation ─────────────► Quarantined
///     ▲                          │  ▲                      │
///     └──── k fast samples ──────┘  └── 1 fast (probe) ────┘
/// ```
///
/// "Slow" is *relative*: a sample is slow when it exceeds
/// `slow_factor ×` the minimum EWMA among the OTHER workers — a
/// straggler is never judged against its own inflated history, and a
/// uniformly loaded fleet (everyone equally slow) quarantines no one.
pub struct FleetHealth {
    workers: Vec<WorkerHealth>,
    slow_factor: f64,
    strikes: u32,
    probe_interval: u64,
    probes: AtomicU64,
}

impl FleetHealth {
    pub fn new(n_workers: usize, cfg: &TailConfig) -> FleetHealth {
        FleetHealth {
            workers: (0..n_workers).map(|_| WorkerHealth::new()).collect(),
            slow_factor: cfg.slow_factor.max(1.0),
            strikes: cfg.strikes.max(1),
            probe_interval: cfg.probe_interval.max(1),
            probes: AtomicU64::new(0),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn state(&self, w: usize) -> BreakerState {
        BreakerState::from_u8(self.workers[w].state.load(Ordering::Acquire))
    }

    /// Routing rank of worker `w` (lower preferred; 2 = quarantined).
    pub fn rank(&self, w: usize) -> u8 {
        self.workers[w].state.load(Ordering::Acquire)
    }

    /// Worker `w`'s service-time EWMA in ns (`None` before any sample).
    pub fn ewma_ns(&self, w: usize) -> Option<f64> {
        let e = f64::from_bits(self.workers[w].ewma_ns.load(Ordering::Relaxed));
        (e > 0.0).then_some(e)
    }

    /// Record one per-request service-time sample for worker `w` and
    /// run the breaker transition. Called from worker `w`'s serving
    /// thread only (single writer per cell).
    pub fn record(&self, w: usize, sample_ns: u64) {
        let h = &self.workers[w];
        let s = sample_ns as f64;
        // EWMA update first, so the admission ETA always reflects the
        // newest sample (decay 0.8 — a few batches of history)
        let old = f64::from_bits(h.ewma_ns.load(Ordering::Relaxed));
        let blended = if old > 0.0 { 0.8 * old + 0.2 * s } else { s };
        h.ewma_ns.store(blended.to_bits(), Ordering::Relaxed);
        // best PEER ewma: the judgment baseline excludes this worker
        let mut best: Option<f64> = None;
        for (i, o) in self.workers.iter().enumerate() {
            if i == w {
                continue;
            }
            let e = f64::from_bits(o.ewma_ns.load(Ordering::Relaxed));
            if e > 0.0 {
                best = Some(best.map_or(e, |b: f64| b.min(e)));
            }
        }
        // solo workers (or an all-cold fleet) have no one to be slower
        // than — no breaker movement until a peer has samples
        let Some(best) = best else { return };
        if s > self.slow_factor * best {
            h.fast_strikes.store(0, Ordering::Relaxed);
            let k = h.slow_strikes.fetch_add(1, Ordering::Relaxed) + 1;
            if k >= self.strikes {
                h.slow_strikes.store(0, Ordering::Relaxed);
                let next = match self.state(w) {
                    BreakerState::Healthy => BreakerState::Probation,
                    _ => BreakerState::Quarantined,
                };
                h.state.store(next as u8, Ordering::Release);
            }
        } else {
            h.slow_strikes.store(0, Ordering::Relaxed);
            match self.state(w) {
                BreakerState::Quarantined => {
                    // probe success: rejoin at probation, and forget the
                    // inflated history so the admission ETA recovers too
                    h.ewma_ns.store(s.to_bits(), Ordering::Relaxed);
                    h.fast_strikes.store(0, Ordering::Relaxed);
                    h.state
                        .store(BreakerState::Probation as u8, Ordering::Release);
                }
                BreakerState::Probation => {
                    let k = h.fast_strikes.fetch_add(1, Ordering::Relaxed) + 1;
                    if k >= self.strikes {
                        h.fast_strikes.store(0, Ordering::Relaxed);
                        h.state
                            .store(BreakerState::Healthy as u8, Ordering::Release);
                    }
                }
                BreakerState::Healthy => {}
            }
        }
    }

    /// Draw one probe ticket (the router diverts a pick to a
    /// quarantined worker when `ticket % probe_interval == 0`).
    pub fn probe_ticket(&self) -> u64 {
        self.probes.fetch_add(1, Ordering::Relaxed)
    }

    pub fn probe_interval(&self) -> u64 {
        self.probe_interval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_admits_exactly_one_claim() {
        let g = HedgeGate::new();
        assert!(!g.is_claimed());
        assert!(g.claim());
        assert!(g.is_claimed());
        assert!(!g.claim());
        assert!(!g.claim());
    }

    #[test]
    fn gate_admits_exactly_one_claim_under_threads() {
        for _ in 0..50 {
            let g = Arc::new(HedgeGate::new());
            let wins: Vec<_> = (0..4)
                .map(|_| {
                    let g = g.clone();
                    std::thread::spawn(move || g.claim())
                })
                .collect();
            let n: usize =
                wins.into_iter().filter(|h| h.join().unwrap()).count();
            assert_eq!(n, 1, "exactly one thread may win the claim");
        }
    }

    #[test]
    fn budget_caps_hedges_at_the_accepted_fraction() {
        let b = HedgeBudget::new(0.1);
        // max(1, 100 × 0.1) = 10 tokens
        let taken = (0..50).filter(|_| b.try_take(100)).count();
        assert_eq!(taken, 10);
        assert_eq!(b.issued(), 10);
        // the floor: even with nothing accepted yet, one hedge may go
        let b = HedgeBudget::new(0.1);
        assert!(b.try_take(0));
        assert!(!b.try_take(0));
    }

    fn cfg(strikes: u32) -> TailConfig {
        TailConfig {
            strikes,
            slow_factor: 4.0,
            ..TailConfig::default()
        }
    }

    #[test]
    fn breaker_demotes_promotes_and_recovers() {
        let h = FleetHealth::new(2, &cfg(2));
        // seed worker 1 as the fast peer baseline: 1ms per request
        h.record(1, 1_000_000);
        assert_eq!(h.state(1), BreakerState::Healthy);
        // worker 0 turns slow: 10ms ≫ 4 × 1ms. Two strikes → probation,
        // two more → quarantined.
        h.record(0, 10_000_000);
        assert_eq!(h.state(0), BreakerState::Healthy, "one strike is noise");
        h.record(0, 10_000_000);
        assert_eq!(h.state(0), BreakerState::Probation);
        h.record(0, 10_000_000);
        h.record(0, 10_000_000);
        assert_eq!(h.state(0), BreakerState::Quarantined);
        // one fast probe sample rejoins at probation, EWMA reset
        h.record(0, 1_000_000);
        assert_eq!(h.state(0), BreakerState::Probation);
        assert!(h.ewma_ns(0).unwrap() < 2_000_000.0, "history forgotten");
        // two consecutive fast samples graduate back to healthy
        h.record(0, 1_000_000);
        h.record(0, 1_000_000);
        assert_eq!(h.state(0), BreakerState::Healthy);
    }

    #[test]
    fn a_fast_sample_resets_the_slow_streak() {
        let h = FleetHealth::new(2, &cfg(2));
        h.record(1, 1_000_000);
        h.record(0, 10_000_000); // strike 1
        h.record(0, 1_000_000); // streak broken
        h.record(0, 10_000_000); // strike 1 again
        assert_eq!(h.state(0), BreakerState::Healthy);
    }

    #[test]
    fn a_solo_worker_is_never_quarantined() {
        let h = FleetHealth::new(1, &cfg(1));
        for _ in 0..10 {
            h.record(0, u64::MAX / 2);
        }
        assert_eq!(h.state(0), BreakerState::Healthy, "no peer, no judgment");
    }

    #[test]
    fn a_uniformly_slow_fleet_quarantines_no_one() {
        let h = FleetHealth::new(3, &cfg(1));
        for _ in 0..20 {
            for w in 0..3 {
                h.record(w, 50_000_000);
            }
        }
        for w in 0..3 {
            assert_eq!(h.state(w), BreakerState::Healthy, "worker {w}");
        }
    }
}
