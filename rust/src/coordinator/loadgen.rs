//! Deterministic load generation for the serving stack (S19).
//!
//! Two arrival processes over `util::rng` (both deterministic by seed
//! in *what* they send; wall-clock timing is inherently physical):
//!
//! * **open loop** — Poisson arrivals at a target rate (exponential
//!   inter-arrival gaps), the regime where queues actually grow and the
//!   latency/throughput knee appears;
//! * **closed loop** — a fixed number of outstanding requests, the
//!   regime that measures capacity.
//!
//! Request *content* comes from the procedural `data::Generator`
//! (record `k` of the dataset profile), and `coverage < 1.0` draws a
//! per-request subset of tables — the multi-tower traffic shape that
//! makes shard-affinity routing meaningful (a request touching every
//! table looks identical to every shard).
//!
//! Since PR 6 the generator is split in two: [`build_schedule`]
//! materialises the entire request stream (content AND open-loop send
//! times) up front, and the drivers — in-process [`run`] or socket
//! [`run_socket`] — merely replay it. That split is what makes the
//! transports comparable: the same `(profile, seed, cfg)` produces the
//! byte-identical schedule no matter how it is delivered, pinned by the
//! schedule-determinism regression in `rust/tests/coordinator_e2e.rs`.

use super::engine::{CrashAfter, InferenceEngine, SlowAfter};
use super::metrics::Metrics;
use super::net::{NetClient, WireResponse};
use super::server::{Admission, Coordinator, Request};
use crate::data::{Generator, Profile};
use crate::util::json_lazy::WireRequest;
use crate::util::rng::{seed_from_name, Rng};
use crate::util::stats::Quantiles;
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Poisson arrivals at `rps` requests/second
    OpenLoop { rps: f64 },
    /// keep `concurrency` requests outstanding
    ClosedLoop { concurrency: usize },
}

#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    pub n_requests: usize,
    pub arrival: Arrival,
    /// seeds both the record stream and the table-subset draws
    pub seed: u64,
    /// fraction of tables each request touches (1.0 = all; the subset
    /// is drawn per request, at least one table)
    pub coverage: f64,
    /// fraction of ids replaced by the `-1` missing-feature sentinel
    /// (the hostile traffic shape of real CTR logs; 0.0 = none, and the
    /// schedule stays bit-identical to the pre-OOV generator)
    pub oov_frac: f64,
    /// per-request end-to-end deadline budget in microseconds (S33);
    /// 0 — the default — sends no deadline at all, keeping schedules
    /// and wire lines bit-identical to the pre-deadline generator. The
    /// value is a constant, not an RNG draw, so turning it on never
    /// perturbs the seeded content stream.
    pub deadline_us: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            n_requests: 1000,
            arrival: Arrival::ClosedLoop { concurrency: 64 },
            seed: 7,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        }
    }
}

/// What the run produced (latency/locality live in `Metrics`).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// responses received by the load generator
    pub completed: usize,
    /// answered with a structured `deadline_exceeded` reply (S33) —
    /// neither completed nor lost: the client heard back, just not with
    /// a score
    pub expired: usize,
    /// accepted but never answered (shed by the worker or dropped by an
    /// engine failure) — always `accepted - completed - expired`
    pub lost: usize,
}

/// Client-measured wire statistics from [`run_socket`] (the server's
/// own e2e percentiles live in `MetricsSnapshot`; these additionally
/// include both socket hops and the response encode/decode).
#[derive(Clone, Debug, Default)]
pub struct WireStats {
    pub wire_p50_us: f64,
    pub wire_p99_us: f64,
    /// completed responses per second of wall clock
    pub client_rps: f64,
    pub elapsed_s: f64,
}

/// One fully-materialised entry of the request stream: content plus the
/// absolute open-loop send time (`at_ns` after run start; 0 under a
/// closed loop, where admission — not the clock — paces sends).
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduledRequest {
    pub k: u64,
    pub at_ns: u64,
    pub dense: Vec<f32>,
    /// table ids touched, strictly ascending
    pub fields: Vec<u32>,
    pub ids: Vec<i32>,
    /// end-to-end deadline budget in microseconds; 0 = none (S33)
    pub deadline_us: u64,
}

impl ScheduledRequest {
    /// Transport-level view (the line `run_socket` puts on the wire).
    pub fn to_wire(&self) -> WireRequest {
        WireRequest {
            id: self.k,
            dense: self.dense.clone(),
            tables: self.fields.clone(),
            ids: self.ids.clone(),
            deadline_us: (self.deadline_us > 0).then_some(self.deadline_us),
        }
    }

    fn into_request(self, tx: &mpsc::Sender<super::server::Response>) -> Request {
        let deadline =
            (self.deadline_us > 0).then(|| Duration::from_micros(self.deadline_us));
        Request::partial(self.k, self.dense, self.fields, self.ids, tx.clone())
            .with_deadline(deadline)
    }
}

/// Content of request `k` of the deterministic stream. `rng` drives the
/// subset draw only, so record content stays pinned to `(profile, seed,
/// k)` regardless of coverage.
fn make_content(
    gen: &mut Generator,
    rng: &mut Rng,
    coverage: f64,
    oov_frac: f64,
    k: usize,
) -> (Vec<f32>, Vec<u32>, Vec<i32>) {
    let (dense, ids_full) = gen.features(k);
    let nf = ids_full.len();
    let (fields, mut ids): (Vec<u32>, Vec<i32>) = if coverage >= 1.0 || nf == 0 {
        (
            (0..nf as u32).collect(),
            ids_full.iter().map(|&x| x as i32).collect(),
        )
    } else {
        let m = ((nf as f64 * coverage).round() as usize).clamp(1, nf);
        let mut fields: Vec<u32> = (0..nf as u32).collect();
        rng.shuffle(&mut fields);
        fields.truncate(m);
        fields.sort_unstable();
        let ids = fields
            .iter()
            .map(|&f| ids_full[f as usize] as i32)
            .collect();
        (fields, ids)
    };
    // Missing-feature injection: each id independently becomes the `-1`
    // sentinel with probability `oov_frac`. The draws happen ONLY when
    // the knob is on, so every `oov_frac == 0.0` schedule stays
    // bit-identical to schedules built before the knob existed.
    if oov_frac > 0.0 {
        for id in ids.iter_mut() {
            if rng.chance(oov_frac) {
                *id = -1;
            }
        }
    }
    (dense, fields, ids)
}

/// Materialise the full request stream for `(profile, cfg)`. The RNG
/// draw order is fixed — open loop draws the arrival gap, then the
/// content, per request — so schedules are bit-identical across calls,
/// transports, and processes for the same seed.
pub fn build_schedule(
    profile: &Profile,
    cfg: &LoadGenConfig,
) -> crate::Result<Vec<ScheduledRequest>> {
    if let Arrival::OpenLoop { rps } = cfg.arrival {
        crate::ensure!(rps > 0.0, "open-loop rps must be > 0");
    }
    let mut gen = Generator::new(profile.clone(), cfg.seed);
    let mut rng = Rng::new(seed_from_name(cfg.seed, "loadgen"));
    let mut out = Vec::with_capacity(cfg.n_requests);
    let mut next_ns = 0f64;
    for k in 0..cfg.n_requests {
        let at_ns = match cfg.arrival {
            Arrival::OpenLoop { rps } => {
                // exponential gap: -ln(1-u)/λ  (u ∈ [0,1) keeps ln finite)
                next_ns += -(1.0 - rng.f64()).ln() / rps * 1e9;
                next_ns as u64
            }
            Arrival::ClosedLoop { .. } => 0,
        };
        let (dense, fields, ids) =
            make_content(&mut gen, &mut rng, cfg.coverage, cfg.oov_frac, k);
        out.push(ScheduledRequest {
            k: k as u64,
            at_ns,
            dense,
            fields,
            ids,
            deadline_us: cfg.deadline_us,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Failure-scenario matrix (S31/S32)
// ---------------------------------------------------------------------------

/// Named traffic/failure shapes for `serve-bench --scenario` (§SH of
/// EXPERIMENTS.md). Every scenario is a deterministic transform of the
/// base schedule plus — for [`Scenario::WorkerCrash`] — a fault armed
/// in one worker's engine; the load generator itself never randomises
/// beyond the seeded base stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// the base schedule, untransformed
    Steady,
    /// middle third of the run arrives `surge`× faster (open loop)
    FlashCrowd,
    /// middle third hammers the first `storm_rows` rows of every table
    HotKeyStorm,
    /// steady offered load while `crash_worker` dies mid-run
    WorkerCrash,
    /// sinusoidal rate swing across the run (open loop)
    Diurnal,
    /// steady offered load while `slow_worker` goes gray mid-run (S33):
    /// correct answers, tens of ms late — the shape hedged dispatch and
    /// quarantine exist for. The schedule itself is untransformed.
    SlowWorker,
    /// flash-crowd surge PLUS a gray worker: sustained deadline
    /// pressure, the shape the brownout controller exists for (S33)
    Brownout,
    /// steady offered load on PIM engines with seeded stuck-at cell
    /// faults injected at program time (S34): ABFT checksums detect,
    /// spare tiles repair, and the verdict demands bit-identical
    /// scores. The schedule itself is untransformed.
    CellFault,
}

impl Scenario {
    pub fn parse(s: &str) -> crate::Result<Scenario> {
        Ok(match s {
            "steady" => Scenario::Steady,
            "flash-crowd" => Scenario::FlashCrowd,
            "hot-key-storm" => Scenario::HotKeyStorm,
            "worker-crash" => Scenario::WorkerCrash,
            "diurnal" => Scenario::Diurnal,
            "slow-worker" => Scenario::SlowWorker,
            "brownout" => Scenario::Brownout,
            "cell-fault" => Scenario::CellFault,
            other => crate::bail!(
                "unknown scenario {other:?} \
                 (steady|flash-crowd|hot-key-storm|worker-crash|diurnal\
                 |slow-worker|brownout|cell-fault)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::FlashCrowd => "flash-crowd",
            Scenario::HotKeyStorm => "hot-key-storm",
            Scenario::WorkerCrash => "worker-crash",
            Scenario::Diurnal => "diurnal",
            Scenario::SlowWorker => "slow-worker",
            Scenario::Brownout => "brownout",
            Scenario::CellFault => "cell-fault",
        }
    }
}

/// Tunables for one scenario run. [`ScenarioSpec::new`] carries the
/// defaults the CLI exposes; every field is plain data so a spec clones
/// cheaply into engine-factory closures.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub scenario: Scenario,
    /// flash-crowd rate multiplier over the middle third
    pub surge: f64,
    /// hot-key-storm: ids collapse to `id % storm_rows.min(card)`
    pub storm_rows: usize,
    /// worker-crash: which worker dies
    pub crash_worker: usize,
    /// worker-crash: wall-clock fuse, used when `crash_after_batches`
    /// is `None`
    pub crash_after: Duration,
    /// worker-crash: deterministic fuse — die after serving this many
    /// batches. Wins over the wall-clock fuse; what the tests and the
    /// verify smoke use, since a quick run can outrace any deadline.
    pub crash_after_batches: Option<usize>,
    /// slow-worker/brownout: which worker goes gray
    pub slow_worker: usize,
    /// slow-worker/brownout: batches served at full speed before the
    /// straggling starts (deterministic fuse, like `crash_after_batches`)
    pub slow_after_batches: usize,
    /// slow-worker/brownout: fixed extra latency per straggling batch
    pub slow_delay: Duration,
    /// slow-worker/brownout: seeded jitter added on top of `slow_delay`
    pub slow_jitter: Duration,
    /// cell-fault: per-cell stuck-at probability injected at program
    /// time (0.0 = pristine devices, even under the cell-fault scenario)
    pub fault_rate: f64,
    /// cell-fault: root seed for the per-worker/per-bank fault streams
    pub fault_seed: u64,
    /// cell-fault: spare tiles reserved per weight bank for repair
    pub spare_tiles: usize,
}

impl ScenarioSpec {
    pub fn new(scenario: Scenario) -> ScenarioSpec {
        ScenarioSpec {
            scenario,
            surge: 8.0,
            storm_rows: 4,
            crash_worker: 1,
            crash_after: Duration::from_millis(60),
            crash_after_batches: None,
            slow_worker: 0,
            slow_after_batches: 2,
            slow_delay: Duration::from_millis(20),
            slow_jitter: Duration::from_millis(2),
            fault_rate: 0.0,
            fault_seed: 0xFA17,
            spare_tiles: 4,
        }
    }
}

/// Rewrite open-loop send times by transforming per-request gaps and
/// re-accumulating — times stay monotone whatever `f` returns. Gaps are
/// integer-valued nanoseconds, so the identity transform is bit-exact.
fn reshape_gaps(sched: &mut [ScheduledRequest], f: impl Fn(usize, f64) -> f64) {
    let mut prev = 0u64;
    let mut acc = 0f64;
    for (k, sr) in sched.iter_mut().enumerate() {
        let gap = sr.at_ns.saturating_sub(prev) as f64;
        prev = sr.at_ns;
        acc += f(k, gap).max(0.0);
        sr.at_ns = acc as u64;
    }
}

/// The base schedule with the scenario's transform applied.
/// [`Scenario::Steady`] and [`Scenario::WorkerCrash`] stay bit-identical
/// to [`build_schedule`] — a crash perturbs the SERVER, never the
/// offered load — pinned by tests below.
pub fn build_scenario_schedule(
    profile: &Profile,
    cfg: &LoadGenConfig,
    spec: &ScenarioSpec,
) -> crate::Result<Vec<ScheduledRequest>> {
    let mut sched = build_schedule(profile, cfg)?;
    let n = sched.len();
    let (a, b) = (n / 3, 2 * n / 3);
    match spec.scenario {
        // fault scenarios perturb the SERVER (engine wrappers or the
        // programmed devices), never the offered load — their schedules
        // stay bit-identical to base
        Scenario::Steady
        | Scenario::WorkerCrash
        | Scenario::SlowWorker
        | Scenario::CellFault => {}
        Scenario::FlashCrowd | Scenario::Brownout => {
            let surge = spec.surge.max(1.0);
            reshape_gaps(&mut sched, |k, g| {
                if (a..b).contains(&k) {
                    g / surge
                } else {
                    g
                }
            });
        }
        Scenario::Diurnal => {
            let nf = n.max(1) as f64;
            reshape_gaps(&mut sched, |k, g| {
                let phase = 2.0 * std::f64::consts::PI * k as f64 / nf;
                g / (1.0 + 0.75 * phase.sin())
            });
        }
        Scenario::HotKeyStorm => {
            for sr in &mut sched[a..b] {
                for (f, id) in sr.fields.iter().zip(sr.ids.iter_mut()) {
                    // negative ids are the OOV sentinel — leave them
                    if *id >= 0 {
                        let card = profile.cards[*f as usize];
                        let rows = spec.storm_rows.clamp(1, card.max(1));
                        *id %= rows as i32;
                    }
                }
            }
        }
    }
    Ok(sched)
}

/// Arms one worker's engine with a [`CrashAfter`] fuse; every other
/// worker's engine passes through untouched. Construct once per run —
/// the wall-clock deadline anchors at injector construction (≈ bench
/// start), not at each worker's own spawn time.
pub struct CrashInjector {
    worker: usize,
    after_batches: Option<usize>,
    deadline: Instant,
}

impl CrashInjector {
    /// `None` for scenarios without a fault.
    pub fn new(spec: &ScenarioSpec) -> Option<CrashInjector> {
        if spec.scenario != Scenario::WorkerCrash {
            return None;
        }
        Some(CrashInjector {
            worker: spec.crash_worker,
            after_batches: spec.crash_after_batches,
            deadline: Instant::now() + spec.crash_after,
        })
    }

    /// Wrap worker `i`'s engine — identity for every worker but the
    /// victim. Call from inside the coordinator's `make_engine` factory.
    pub fn arm(
        &self,
        i: usize,
        engine: Box<dyn InferenceEngine>,
    ) -> Box<dyn InferenceEngine> {
        if i != self.worker {
            return engine;
        }
        match self.after_batches {
            Some(nb) => Box::new(CrashAfter::after_batches(engine, nb)),
            None => Box::new(CrashAfter::at_deadline(engine, self.deadline)),
        }
    }
}

/// Arms one worker's engine with a [`SlowAfter`] gray fault (S33):
/// bit-identical outputs, tens of milliseconds late. The engine-wrapper
/// twin of [`CrashInjector`], for the scenarios where the worker is
/// SLOW rather than DEAD — the failure mode breakers built on liveness
/// flags cannot see.
pub struct SlowInjector {
    worker: usize,
    after_batches: usize,
    delay: Duration,
    jitter: Duration,
}

impl SlowInjector {
    /// `None` for scenarios without a gray fault.
    pub fn new(spec: &ScenarioSpec) -> Option<SlowInjector> {
        if !matches!(
            spec.scenario,
            Scenario::SlowWorker | Scenario::Brownout
        ) {
            return None;
        }
        Some(SlowInjector {
            worker: spec.slow_worker,
            after_batches: spec.slow_after_batches,
            delay: spec.slow_delay,
            jitter: spec.slow_jitter,
        })
    }

    /// Wrap worker `i`'s engine — identity for every worker but the
    /// victim. Call from inside the coordinator's `make_engine` factory.
    pub fn arm(
        &self,
        i: usize,
        engine: Box<dyn InferenceEngine>,
    ) -> Box<dyn InferenceEngine> {
        if i != self.worker {
            return engine;
        }
        Box::new(SlowAfter::new(
            engine,
            self.after_batches,
            self.delay,
            self.jitter,
            // fixed seed: the jitter stream is deterministic per run
            0x510_u64 ^ i as u64,
        ))
    }
}

/// Splits a run's accepts/completions into pre- and post-crash
/// populations.
///
/// The crash is detected *from the ledger*: the first accept-time poll
/// where [`Metrics::failed_count`] has moved past its run-start
/// baseline marks every later accept as post-crash, and once tripped it
/// stays tripped. That works for both fuse kinds (deadline and
/// batch-count) without the probe knowing the trigger. Requests
/// accepted BEFORE the trip but answered after it count toward neither
/// side — they were offered to a fleet believed healthy.
pub struct ScenarioProbe {
    failed_at_start: u64,
    tripped: bool,
    /// schedule index -> accepted after the crash was observed
    post: Vec<bool>,
    pub post_crash_sent: usize,
    pub post_crash_completed: usize,
}

impl ScenarioProbe {
    pub fn new(metrics: &Metrics, n: usize) -> ScenarioProbe {
        ScenarioProbe {
            failed_at_start: metrics.failed_count(),
            tripped: false,
            post: vec![false; n],
            post_crash_sent: 0,
            post_crash_completed: 0,
        }
    }

    fn on_accepted(&mut self, k: u64, metrics: &Metrics) {
        if !self.tripped && metrics.failed_count() > self.failed_at_start {
            self.tripped = true;
        }
        if self.tripped {
            if let Some(p) = self.post.get_mut(k as usize) {
                if !*p {
                    *p = true;
                    self.post_crash_sent += 1;
                }
            }
        }
    }

    fn on_response(&mut self, id: u64) {
        if self.post.get(id as usize).copied().unwrap_or(false) {
            self.post_crash_completed += 1;
        }
    }
}

/// A [`run_scenario`] result: the plain report plus the post-crash
/// availability split (both zero when no fault fired).
#[derive(Clone, Debug, Default)]
pub struct ScenarioOutcome {
    pub report: LoadReport,
    pub post_crash_sent: usize,
    pub post_crash_completed: usize,
}

/// Drive one full scenario in-process: shaped schedule, probed replay.
/// Fault injection happens at coordinator construction (see
/// [`CrashInjector::arm`]); this function only shapes and measures.
pub fn run_scenario(
    coord: &Coordinator,
    profile: &Profile,
    cfg: &LoadGenConfig,
    spec: &ScenarioSpec,
) -> crate::Result<ScenarioOutcome> {
    let schedule = build_scenario_schedule(profile, cfg, spec)?;
    let mut probe = ScenarioProbe::new(&coord.metrics, schedule.len());
    let report = run_schedule_probed(coord, cfg, schedule, Some(&mut probe))?;
    Ok(ScenarioOutcome {
        report,
        post_crash_sent: probe.post_crash_sent,
        post_crash_completed: probe.post_crash_completed,
    })
}

/// The exact request lines a socket run sends, for parse benchmarking
/// and differential tests. `with_ctx` appends a deterministic cold
/// `ctx` payload (session hex, AB labels, timestamp, user agent) that
/// the scorer ignores — the traffic shape where lazy hot-field
/// extraction pays, since the tree parser must materialise it all.
pub fn wire_corpus(
    profile: &Profile,
    cfg: &LoadGenConfig,
    with_ctx: bool,
) -> crate::Result<Vec<String>> {
    let sched = build_schedule(profile, cfg)?;
    let mut rng = Rng::new(seed_from_name(cfg.seed, "wirectx"));
    Ok(sched
        .iter()
        .map(|sr| {
            let mut line = sr.to_wire().to_line();
            if with_ctx {
                line.truncate(line.len() - 2); // drop `}\n`
                line.push_str(",\"ctx\":{\"sess\":\"");
                for _ in 0..32 {
                    line.push(char::from_digit(rng.below(16) as u32, 16).unwrap());
                }
                line.push_str("\",\"ab\":[\"exp-");
                line.push_str(&rng.below(100).to_string());
                line.push_str("\",\"hold-");
                line.push_str(&rng.below(10).to_string());
                line.push_str("\"],\"ts\":");
                line.push_str(&(1_700_000_000_000u64 + rng.below(1_000_000_000)).to_string());
                line.push_str(
                    ",\"ua\":\"Mozilla/5.0 (X11; Linux x86_64) AppleWebKit/537.36\"}}\n",
                );
            }
            line
        })
        .collect())
}

fn wait_until(t0: Instant, at_ns: u64) {
    loop {
        let now = t0.elapsed().as_nanos() as u64;
        if now >= at_ns {
            break;
        }
        let wait = at_ns - now;
        if wait > 200_000 {
            std::thread::sleep(Duration::from_nanos(wait - 100_000));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Drive `cfg.n_requests` through the coordinator in-process; blocks
/// until every accepted request is either answered or shed, so the
/// returned report is an exact completed/lost split.
pub fn run(
    coord: &Coordinator,
    profile: &Profile,
    cfg: &LoadGenConfig,
) -> crate::Result<LoadReport> {
    let schedule = build_schedule(profile, cfg)?;
    run_schedule(coord, cfg, schedule)
}

/// Replay an already-built schedule against an in-process coordinator.
pub fn run_schedule(
    coord: &Coordinator,
    cfg: &LoadGenConfig,
    schedule: Vec<ScheduledRequest>,
) -> crate::Result<LoadReport> {
    run_schedule_probed(coord, cfg, schedule, None)
}

/// [`run_schedule`] with an optional [`ScenarioProbe`] observing every
/// accept and completion (the hooks cost nothing when `None`).
fn run_schedule_probed(
    coord: &Coordinator,
    cfg: &LoadGenConfig,
    schedule: Vec<ScheduledRequest>,
    mut probe: Option<&mut ScenarioProbe>,
) -> crate::Result<LoadReport> {
    let (tx, rx) = mpsc::channel();
    let mut rep = LoadReport::default();

    match cfg.arrival {
        Arrival::OpenLoop { .. } => {
            let t0 = Instant::now();
            for sr in schedule {
                wait_until(t0, sr.at_ns);
                rep.sent += 1;
                let k = sr.k;
                match coord.submit(sr.into_request(&tx))? {
                    Admission::Enqueued(_) => {
                        rep.accepted += 1;
                        if let Some(p) = probe.as_deref_mut() {
                            p.on_accepted(k, &coord.metrics);
                        }
                    }
                    // deadline-infeasible is a rejection leg on the
                    // server ledger; the client mirrors that
                    Admission::Rejected
                    | Admission::DeadlineInfeasible => rep.rejected += 1,
                }
            }
            drop(tx);
            for r in rx.iter() {
                if r.err.is_some() {
                    rep.expired += 1;
                } else {
                    rep.completed += 1;
                    if let Some(p) = probe.as_deref_mut() {
                        p.on_response(r.id);
                    }
                }
            }
            rep.lost = rep.accepted - rep.completed - rep.expired;
        }
        Arrival::ClosedLoop { concurrency } => {
            let n = schedule.len();
            let mut it = schedule.into_iter();
            let window = concurrency.max(1);
            // `outstanding` tracks window occupancy. Shed/failed
            // requests never answer, so on a poll timeout we release
            // exactly as many slots as the coordinator's shed+failed
            // counters confirm were lost — a merely-slow batch (exec
            // time > the poll interval) keeps its slots and the loop
            // keeps waiting, so concurrency stays a true bound.
            // (Assumes this loadgen is the coordinator's only producer,
            // which is how serve-bench runs it.)
            let mut outstanding = 0usize;
            // baseline the ghost ledger so losses from a previous run()
            // on the same coordinator are not forgiven against THIS
            // run's window
            let start = coord.metrics.snapshot();
            let mut forgiven = start.shed + start.failed;
            while rep.sent < n || outstanding > 0 {
                for r in rx.try_iter() {
                    outstanding = outstanding.saturating_sub(1);
                    if r.err.is_some() {
                        rep.expired += 1;
                    } else {
                        rep.completed += 1;
                        if let Some(p) = probe.as_deref_mut() {
                            p.on_response(r.id);
                        }
                    }
                }
                while rep.sent < n && outstanding < window {
                    let sr = it.next().expect("schedule holds n entries");
                    rep.sent += 1;
                    let k = sr.k;
                    match coord.submit(sr.into_request(&tx))? {
                        Admission::Enqueued(_) => {
                            rep.accepted += 1;
                            outstanding += 1;
                            if let Some(p) = probe.as_deref_mut() {
                                p.on_accepted(k, &coord.metrics);
                            }
                        }
                        Admission::Rejected
                        | Admission::DeadlineInfeasible => {
                            rep.rejected += 1
                        }
                    }
                }
                if outstanding == 0 {
                    continue; // whole window rejected; refill
                }
                match rx.recv_timeout(Duration::from_millis(300)) {
                    Ok(r) => {
                        outstanding -= 1;
                        if r.err.is_some() {
                            rep.expired += 1;
                        } else {
                            rep.completed += 1;
                            if let Some(p) = probe.as_deref_mut() {
                                p.on_response(r.id);
                            }
                        }
                    }
                    Err(_) => {
                        let snap = coord.metrics.snapshot();
                        let ghosts = (snap.shed + snap.failed)
                            .saturating_sub(forgiven);
                        let release = (ghosts as usize).min(outstanding);
                        forgiven += release as u64;
                        outstanding -= release;
                    }
                }
            }
            drop(tx);
            // Every accepted request still holds a reply sender until a
            // worker answers or drops it, so this drain terminates and
            // catches any straggler that raced the ghost accounting.
            for r in rx.iter() {
                if r.err.is_some() {
                    rep.expired += 1;
                } else {
                    rep.completed += 1;
                    if let Some(p) = probe.as_deref_mut() {
                        p.on_response(r.id);
                    }
                }
            }
            rep.lost = rep.accepted - rep.completed - rep.expired;
        }
    }
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Socket driver
// ---------------------------------------------------------------------------

struct ConnReport {
    sent: usize,
    rejected: usize,
    completed: usize,
    expired: usize,
    lat_us: Vec<f64>,
}

/// Saturating decrement (a late response must never underflow a window
/// slot that a stall-release already reclaimed).
fn release_slot(outstanding: &AtomicUsize) {
    let _ = outstanding.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
        v.checked_sub(1)
    });
}

fn drive_conn(
    addr: SocketAddr,
    part: Vec<(u64, u64, String)>,
    t0: Instant,
    window: usize,
) -> crate::Result<ConnReport> {
    let client = NetClient::connect(&addr)?;
    let (mut tx, mut rx) = client.split();
    let inflight: Arc<Mutex<HashMap<u64, Instant>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let outstanding = Arc::new(AtomicUsize::new(0));

    let recv = {
        let inflight = Arc::clone(&inflight);
        let outstanding = Arc::clone(&outstanding);
        std::thread::spawn(move || {
            let mut completed = 0usize;
            let mut rejected = 0usize;
            let mut expired = 0usize;
            let mut lat_us: Vec<f64> = Vec::new();
            loop {
                match rx.recv() {
                    Ok(Some(WireResponse::Ok { id, .. })) => {
                        if let Some(sent_at) = inflight.lock().unwrap().remove(&id)
                        {
                            lat_us.push(sent_at.elapsed().as_nanos() as f64 / 1e3);
                        }
                        completed += 1;
                        release_slot(&outstanding);
                    }
                    Ok(Some(WireResponse::Error { id, msg })) => {
                        if let Some(id) = id {
                            inflight.lock().unwrap().remove(&id);
                        }
                        // the wire collapses infeasible-at-admission and
                        // expired-at-dequeue into one structured error;
                        // the server's ledger keeps them distinct
                        if msg == "deadline_exceeded" {
                            expired += 1;
                        } else {
                            rejected += 1;
                        }
                        release_slot(&outstanding);
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            (completed, rejected, expired, lat_us)
        })
    };

    let mut sent = 0usize;
    for (k, at_ns, line) in part {
        if window != usize::MAX {
            // closed loop: wait for a slot; force-release after a long
            // stall, since a shed/failed request never answers (same
            // role as run_schedule's ghost accounting, without access
            // to the server's counters)
            let mut stalled = Instant::now();
            while outstanding.load(Ordering::Acquire) >= window {
                std::thread::sleep(Duration::from_micros(200));
                if stalled.elapsed() > Duration::from_secs(2) {
                    release_slot(&outstanding);
                    stalled = Instant::now();
                }
            }
        }
        if at_ns > 0 {
            wait_until(t0, at_ns);
        }
        inflight.lock().unwrap().insert(k, Instant::now());
        outstanding.fetch_add(1, Ordering::AcqRel);
        if tx.send_line(&line).is_err() {
            break; // server gone; the receiver will see EOF
        }
        sent += 1;
    }
    tx.finish();
    let (completed, rejected, expired, lat_us) = recv
        .join()
        .map_err(|_| crate::err!("socket receiver thread panicked"))?;
    Ok(ConnReport {
        sent,
        rejected,
        completed,
        expired,
        lat_us,
    })
}

/// Replay the deterministic schedule over `conns` real loopback
/// connections against a running `coordinator::net::NetServer` (or any
/// server speaking the wire protocol). Entry `k` always rides
/// connection `k % conns`, and open-loop send times stay on the ONE
/// global clock, so the offered stream is the same Poisson process
/// `run` offers in-process. Lines are pre-encoded before the clock
/// starts so encode cost never distorts pacing.
pub fn run_socket(
    addr: &SocketAddr,
    profile: &Profile,
    cfg: &LoadGenConfig,
    conns: usize,
) -> crate::Result<(LoadReport, WireStats)> {
    let conns = conns.max(1).min(cfg.n_requests.max(1));
    let schedule = build_schedule(profile, cfg)?;
    let mut parts: Vec<Vec<(u64, u64, String)>> =
        (0..conns).map(|_| Vec::new()).collect();
    for sr in &schedule {
        parts[(sr.k % conns as u64) as usize].push((
            sr.k,
            sr.at_ns,
            sr.to_wire().to_line(),
        ));
    }
    drop(schedule);
    let window = match cfg.arrival {
        Arrival::OpenLoop { .. } => usize::MAX,
        // split the global window across connections (ceil so small
        // windows never round a connection down to zero slots)
        Arrival::ClosedLoop { concurrency } => {
            (concurrency.max(1) + conns - 1) / conns
        }
    };

    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(conns);
    for part in parts {
        let addr = *addr;
        handles.push(std::thread::spawn(move || {
            drive_conn(addr, part, t0, window)
        }));
    }
    let mut rep = LoadReport::default();
    let mut q = Quantiles::new();
    for h in handles {
        let c = h
            .join()
            .map_err(|_| crate::err!("socket loadgen thread panicked"))??;
        rep.sent += c.sent;
        rep.rejected += c.rejected;
        rep.completed += c.completed;
        rep.expired += c.expired;
        for l in c.lat_us {
            q.push(l);
        }
    }
    rep.accepted = rep.sent - rep.rejected;
    rep.lost = rep
        .accepted
        .saturating_sub(rep.completed + rep.expired);
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = WireStats {
        wire_p50_us: if q.len() == 0 { 0.0 } else { q.median() },
        wire_p99_us: if q.len() == 0 { 0.0 } else { q.p99() },
        client_rps: rep.completed as f64 / elapsed.max(1e-9),
        elapsed_s: elapsed,
    };
    Ok((rep, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::server::{Coordinator, CoordinatorConfig};
    use crate::data::profile;
    use crate::embeddings::EmbeddingStore;
    use std::sync::Arc;

    fn coord(workers: usize) -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                n_workers: workers,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            |_| Ok(Box::new(MockEngine::new(16, 3, 10, 8))),
        )
        .unwrap()
    }

    #[test]
    fn closed_loop_completes_everything() {
        let c = coord(2);
        let rep = run(
            &c,
            &profile("kdd").unwrap(),
            &LoadGenConfig {
                n_requests: 120,
                arrival: Arrival::ClosedLoop { concurrency: 16 },
                seed: 11,
                coverage: 1.0,
                oov_frac: 0.0,
                deadline_us: 0,
            },
        )
        .unwrap();
        assert_eq!(rep.sent, 120);
        assert_eq!(rep.accepted, 120);
        assert_eq!(rep.completed, 120);
        assert_eq!(rep.rejected + rep.lost, 0);
        c.shutdown();
    }

    #[test]
    fn open_loop_fast_rate_completes() {
        let c = coord(1);
        let rep = run(
            &c,
            &profile("kdd").unwrap(),
            &LoadGenConfig {
                n_requests: 80,
                arrival: Arrival::OpenLoop { rps: 1e6 },
                seed: 5,
                coverage: 0.5,
                oov_frac: 0.0,
                deadline_us: 0,
            },
        )
        .unwrap();
        assert_eq!(rep.sent, 80);
        assert_eq!(rep.completed, 80);
        c.shutdown();
    }

    #[test]
    fn subset_draw_is_deterministic_by_seed() {
        let p = profile("kdd").unwrap();
        let draw = |seed: u64| -> Vec<Vec<u32>> {
            let mut gen = Generator::new(p.clone(), seed);
            let mut rng = Rng::new(seed_from_name(seed, "loadgen"));
            (0..20)
                .map(|k| make_content(&mut gen, &mut rng, 0.4, 0.0, k).1)
                .collect()
        };
        assert_eq!(draw(9), draw(9));
        assert_ne!(draw(9), draw(10));
        for f in draw(9) {
            assert_eq!(f.len(), 4); // 0.4 × 10 fields
            assert!(f.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        }
    }

    #[test]
    fn schedules_are_bit_identical_by_seed() {
        let p = profile("kdd").unwrap();
        for arrival in [
            Arrival::OpenLoop { rps: 5_000.0 },
            Arrival::ClosedLoop { concurrency: 8 },
        ] {
            let cfg = LoadGenConfig {
                n_requests: 40,
                arrival,
                seed: 13,
                coverage: 0.6,
                oov_frac: 0.0,
                deadline_us: 0,
            };
            let a = build_schedule(&p, &cfg).unwrap();
            let b = build_schedule(&p, &cfg).unwrap();
            assert_eq!(a, b);
            let other = build_schedule(
                &p,
                &LoadGenConfig {
                    seed: 14,
                    ..cfg.clone()
                },
            )
            .unwrap();
            assert_ne!(a, other);
        }
    }

    #[test]
    fn oov_injection_is_opt_in_and_preserves_clean_ids() {
        let p = profile("kdd").unwrap();
        let base = LoadGenConfig {
            n_requests: 40,
            arrival: Arrival::ClosedLoop { concurrency: 8 },
            seed: 17,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let clean = build_schedule(&p, &base).unwrap();
        assert!(
            clean.iter().all(|sr| sr.ids.iter().all(|&i| i >= 0)),
            "oov_frac 0.0 injects nothing"
        );
        let hostile = build_schedule(
            &p,
            &LoadGenConfig {
                oov_frac: 0.5,
                ..base.clone()
            },
        )
        .unwrap();
        let n_neg: usize = hostile
            .iter()
            .map(|sr| sr.ids.iter().filter(|&&i| i < 0).count())
            .sum();
        assert!(n_neg > 0, "oov_frac 0.5 must inject sentinels");
        // injection only replaces ids — fields and surviving ids match
        // the clean schedule exactly (full coverage: no subset draws)
        for (c, h) in clean.iter().zip(&hostile) {
            assert_eq!(c.fields, h.fields);
            for (&ic, &ih) in c.ids.iter().zip(&h.ids) {
                assert!(ih == ic || ih == -1, "clean {ic} became {ih}");
            }
        }
    }

    #[test]
    fn open_loop_send_times_are_monotone_nondecreasing() {
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 64,
            arrival: Arrival::OpenLoop { rps: 10_000.0 },
            seed: 3,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let sched = build_schedule(&p, &cfg).unwrap();
        assert!(sched.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        assert!(sched.iter().skip(1).all(|sr| sr.at_ns > 0));
    }

    #[test]
    fn wire_corpus_lines_decode_to_the_schedule() {
        use crate::util::json_lazy::{parse_request_traced, ParsePath};
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 24,
            arrival: Arrival::ClosedLoop { concurrency: 4 },
            seed: 21,
            coverage: 0.7,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let sched = build_schedule(&p, &cfg).unwrap();
        for with_ctx in [false, true] {
            let corpus = wire_corpus(&p, &cfg, with_ctx).unwrap();
            assert_eq!(corpus.len(), sched.len());
            for (line, sr) in corpus.iter().zip(&sched) {
                let (got, path) =
                    parse_request_traced(line.trim_end().as_bytes());
                assert_eq!(path, ParsePath::Lazy, "{line}");
                assert_eq!(got.unwrap(), sr.to_wire());
            }
        }
    }

    #[test]
    fn scenario_parse_round_trips() {
        for s in [
            Scenario::Steady,
            Scenario::FlashCrowd,
            Scenario::HotKeyStorm,
            Scenario::WorkerCrash,
            Scenario::Diurnal,
            Scenario::SlowWorker,
            Scenario::Brownout,
            Scenario::CellFault,
        ] {
            assert_eq!(Scenario::parse(s.name()).unwrap(), s);
        }
        assert!(Scenario::parse("bogus").is_err());
    }

    #[test]
    fn steady_and_worker_crash_schedules_match_base() {
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 60,
            arrival: Arrival::OpenLoop { rps: 20_000.0 },
            seed: 23,
            coverage: 0.8,
            oov_frac: 0.1,
            deadline_us: 0,
        };
        let base = build_schedule(&p, &cfg).unwrap();
        for sc in [
            Scenario::Steady,
            Scenario::WorkerCrash,
            Scenario::SlowWorker,
            Scenario::CellFault,
        ] {
            let got =
                build_scenario_schedule(&p, &cfg, &ScenarioSpec::new(sc))
                    .unwrap();
            assert_eq!(got, base, "{} must not reshape the load", sc.name());
        }
    }

    #[test]
    fn flash_crowd_compresses_middle_third_gaps() {
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 90,
            arrival: Arrival::OpenLoop { rps: 10_000.0 },
            seed: 29,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let base = build_schedule(&p, &cfg).unwrap();
        let spec = ScenarioSpec::new(Scenario::FlashCrowd);
        let surged = build_scenario_schedule(&p, &cfg, &spec).unwrap();
        let (n, a, b) = (base.len(), base.len() / 3, 2 * base.len() / 3);
        assert!(surged.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        // content untouched — only send times move
        for (s, o) in surged.iter().zip(&base) {
            assert_eq!((&s.dense, &s.fields, &s.ids), (&o.dense, &o.fields, &o.ids));
        }
        // first third untouched (integer gaps re-accumulate exactly)
        for k in 0..a {
            assert_eq!(surged[k].at_ns, base[k].at_ns);
        }
        // middle-third span shrinks by ~surge (±1ns rounding per gap)
        let span = |s: &[ScheduledRequest]| s[b - 1].at_ns - s[a - 1].at_ns;
        assert!(
            span(&surged) <= span(&base) / spec.surge as u64 + (b - a) as u64,
            "middle span {} vs base {}",
            span(&surged),
            span(&base)
        );
        // whole run finishes earlier
        assert!(surged[n - 1].at_ns < base[n - 1].at_ns);
    }

    #[test]
    fn hot_key_storm_remaps_only_the_middle_third() {
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 60,
            arrival: Arrival::ClosedLoop { concurrency: 8 },
            seed: 31,
            coverage: 1.0,
            oov_frac: 0.2,
            deadline_us: 0,
        };
        let base = build_schedule(&p, &cfg).unwrap();
        let spec = ScenarioSpec::new(Scenario::HotKeyStorm);
        let storm = build_scenario_schedule(&p, &cfg, &spec).unwrap();
        let (a, b) = (base.len() / 3, 2 * base.len() / 3);
        for (k, (s, o)) in storm.iter().zip(&base).enumerate() {
            assert_eq!(s.fields, o.fields);
            assert_eq!(s.dense, o.dense);
            assert_eq!(s.at_ns, o.at_ns);
            if !(a..b).contains(&k) {
                assert_eq!(s.ids, o.ids, "outside the storm ids are untouched");
                continue;
            }
            for (&f, (&sid, &oid)) in
                s.fields.iter().zip(s.ids.iter().zip(&o.ids))
            {
                if oid < 0 {
                    assert_eq!(sid, oid, "OOV sentinels survive the remap");
                } else {
                    let rows = spec.storm_rows.min(p.cards[f as usize]);
                    assert!(
                        (0..rows as i32).contains(&sid),
                        "storm id {sid} outside [0,{rows})"
                    );
                }
            }
        }
    }

    #[test]
    fn diurnal_schedule_is_deterministic_and_monotone() {
        let p = profile("kdd").unwrap();
        let cfg = LoadGenConfig {
            n_requests: 80,
            arrival: Arrival::OpenLoop { rps: 10_000.0 },
            seed: 37,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let spec = ScenarioSpec::new(Scenario::Diurnal);
        let x = build_scenario_schedule(&p, &cfg, &spec).unwrap();
        let y = build_scenario_schedule(&p, &cfg, &spec).unwrap();
        assert_eq!(x, y);
        assert!(x.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        let base = build_schedule(&p, &cfg).unwrap();
        assert_ne!(
            x.iter().map(|sr| sr.at_ns).collect::<Vec<_>>(),
            base.iter().map(|sr| sr.at_ns).collect::<Vec<_>>(),
            "diurnal must actually move the send times"
        );
    }

    #[test]
    fn run_scenario_survives_an_armed_worker_crash() {
        let mut spec = ScenarioSpec::new(Scenario::WorkerCrash);
        spec.crash_worker = 0;
        spec.crash_after_batches = Some(1);
        let inj = Arc::new(CrashInjector::new(&spec).expect("crash scenario"));
        assert!(
            CrashInjector::new(&ScenarioSpec::new(Scenario::Steady)).is_none(),
            "steady arms nothing"
        );
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            move |i| {
                let e: Box<dyn InferenceEngine> =
                    Box::new(MockEngine::new(16, 3, 10, 8));
                Ok(inj.arm(i, e))
            },
        )
        .unwrap();
        let cfg = LoadGenConfig {
            n_requests: 200,
            arrival: Arrival::ClosedLoop { concurrency: 16 },
            seed: 41,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let out =
            run_scenario(&c, &profile("kdd").unwrap(), &cfg, &spec).unwrap();
        assert_eq!(out.report.sent, 200);
        assert_eq!(out.report.completed, out.report.accepted - out.report.lost);
        assert!(out.post_crash_completed <= out.post_crash_sent);
        // the ledger must balance once the dead worker's guard has
        // booked its losses — poll briefly, then pin the invariants
        let t0 = Instant::now();
        loop {
            let snap = c.metrics.snapshot();
            if snap.failed > 0 && snap.ledger_ok() {
                assert_eq!(snap.live_workers(), 1);
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "ledger never balanced: req {} resp {} rej {} shed {} failed {}",
                snap.requests,
                snap.responses,
                snap.rejected,
                snap.shed,
                snap.failed
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(c.n_live(), 1);
        c.shutdown();
    }

    #[test]
    fn slow_worker_scenario_hedges_and_balances_the_ledger() {
        use crate::coordinator::batcher::BatcherConfig;
        use crate::coordinator::router::Policy;
        use crate::coordinator::tail::TailConfig;
        let mut spec = ScenarioSpec::new(Scenario::SlowWorker);
        spec.slow_worker = 0;
        spec.slow_after_batches = 1;
        spec.slow_delay = Duration::from_millis(10);
        spec.slow_jitter = Duration::from_millis(1);
        let inj = Arc::new(SlowInjector::new(&spec).expect("slow scenario"));
        assert!(
            SlowInjector::new(&ScenarioSpec::new(Scenario::Steady)).is_none(),
            "steady arms nothing"
        );
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                policy: Policy::LeastQueued,
                batcher: BatcherConfig {
                    max_batch: 1,
                    ..Default::default()
                },
                tail: Some(TailConfig {
                    hedge_after: Duration::from_millis(2),
                    hedge_budget: 1.0,
                    tick: Duration::from_millis(1),
                    ..Default::default()
                }),
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            move |i| {
                let e: Box<dyn InferenceEngine> =
                    Box::new(MockEngine::new(16, 3, 10, 8));
                Ok(inj.arm(i, e))
            },
        )
        .unwrap();
        let cfg = LoadGenConfig {
            n_requests: 80,
            arrival: Arrival::ClosedLoop { concurrency: 8 },
            seed: 43,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 0,
        };
        let rep = run(&c, &profile("kdd").unwrap(), &cfg).unwrap();
        assert_eq!(rep.sent, 80);
        assert_eq!(
            rep.completed + rep.expired + rep.lost,
            rep.accepted,
            "client accounting must close"
        );
        let snap = c.metrics.snapshot();
        // the gray worker serves every request 10ms late; with a 2ms
        // hedge trigger and a 1ms governor tick at least one aged entry
        // must have been hedged (5× timing margin against CI jitter)
        assert!(snap.hedges > 0, "no hedge fired against a 10ms straggler");
        assert!(
            snap.ledger_ok(),
            "ledger: req {} resp {} rej {} shed {} failed {} expired {}",
            snap.requests,
            snap.responses,
            snap.rejected,
            snap.shed,
            snap.failed,
            snap.expired
        );
        c.shutdown();
    }

    #[test]
    fn expired_deadlines_are_counted_not_lost() {
        use crate::coordinator::batcher::BatcherConfig;
        // every batch stalls 8ms; a 3ms deadline means queued requests
        // expire at dequeue and must come back as structured errors,
        // not vanish into `lost`
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            |_| {
                Ok(Box::new(SlowAfter::new(
                    Box::new(MockEngine::new(16, 3, 10, 8)),
                    0,
                    Duration::from_millis(8),
                    Duration::ZERO,
                    7,
                )))
            },
        )
        .unwrap();
        let cfg = LoadGenConfig {
            n_requests: 30,
            arrival: Arrival::ClosedLoop { concurrency: 8 },
            seed: 47,
            coverage: 1.0,
            oov_frac: 0.0,
            deadline_us: 3_000,
        };
        let rep = run(&c, &profile("kdd").unwrap(), &cfg).unwrap();
        assert_eq!(rep.sent, 30);
        assert!(rep.expired > 0, "queued requests must blow a 3ms deadline");
        assert_eq!(rep.lost, 0, "expired requests answer; they are not lost");
        assert_eq!(rep.completed + rep.expired + rep.rejected, rep.sent);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.expired, rep.expired as u64);
        assert!(
            snap.ledger_ok(),
            "ledger: req {} resp {} rej {} shed {} failed {} expired {}",
            snap.requests,
            snap.responses,
            snap.rejected,
            snap.shed,
            snap.failed,
            snap.expired
        );
        c.shutdown();
    }
}
