//! Deterministic load generation for the serving stack (S19).
//!
//! Two arrival processes over `util::rng` (both deterministic by seed
//! in *what* they send; wall-clock timing is inherently physical):
//!
//! * **open loop** — Poisson arrivals at a target rate (exponential
//!   inter-arrival gaps), the regime where queues actually grow and the
//!   latency/throughput knee appears;
//! * **closed loop** — a fixed number of outstanding requests, the
//!   regime that measures capacity.
//!
//! Request *content* comes from the procedural `data::Generator`
//! (record `k` of the dataset profile), and `coverage < 1.0` draws a
//! per-request subset of tables — the multi-tower traffic shape that
//! makes shard-affinity routing meaningful (a request touching every
//! table looks identical to every shard).

use super::server::{Admission, Coordinator, Request};
use crate::data::{Generator, Profile};
use crate::util::rng::{seed_from_name, Rng};
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Poisson arrivals at `rps` requests/second
    OpenLoop { rps: f64 },
    /// keep `concurrency` requests outstanding
    ClosedLoop { concurrency: usize },
}

#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    pub n_requests: usize,
    pub arrival: Arrival,
    /// seeds both the record stream and the table-subset draws
    pub seed: u64,
    /// fraction of tables each request touches (1.0 = all; the subset
    /// is drawn per request, at least one table)
    pub coverage: f64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            n_requests: 1000,
            arrival: Arrival::ClosedLoop { concurrency: 64 },
            seed: 7,
            coverage: 1.0,
        }
    }
}

/// What the run produced (latency/locality live in `Metrics`).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// responses received by the load generator
    pub completed: usize,
    /// accepted but never answered (shed by the worker or dropped by an
    /// engine failure) — always `accepted - completed`
    pub lost: usize,
}

/// Build request `k` of the deterministic stream. `rng` drives the
/// subset draw only, so record content stays pinned to `(profile, seed,
/// k)` regardless of coverage.
fn make_request(
    gen: &mut Generator,
    rng: &mut Rng,
    coverage: f64,
    k: usize,
    tx: &mpsc::Sender<super::server::Response>,
) -> Request {
    let (dense, ids_full) = gen.features(k);
    let nf = ids_full.len();
    if coverage >= 1.0 || nf == 0 {
        let ids = ids_full.iter().map(|&x| x as i32).collect();
        return Request::full(k as u64, dense, ids, tx.clone());
    }
    let m = ((nf as f64 * coverage).round() as usize).clamp(1, nf);
    let mut fields: Vec<u32> = (0..nf as u32).collect();
    rng.shuffle(&mut fields);
    fields.truncate(m);
    fields.sort_unstable();
    let ids = fields
        .iter()
        .map(|&f| ids_full[f as usize] as i32)
        .collect();
    Request::partial(k as u64, dense, fields, ids, tx.clone())
}

/// Drive `cfg.n_requests` through the coordinator; blocks until every
/// accepted request is either answered or shed, so the returned report
/// is an exact completed/lost split.
pub fn run(
    coord: &Coordinator,
    profile: &Profile,
    cfg: &LoadGenConfig,
) -> crate::Result<LoadReport> {
    let mut gen = Generator::new(profile.clone(), cfg.seed);
    let mut rng = Rng::new(seed_from_name(cfg.seed, "loadgen"));
    let (tx, rx) = mpsc::channel();
    let mut rep = LoadReport::default();

    match cfg.arrival {
        Arrival::OpenLoop { rps } => {
            crate::ensure!(rps > 0.0, "open-loop rps must be > 0");
            let t0 = Instant::now();
            let mut next_ns = 0f64;
            for k in 0..cfg.n_requests {
                // exponential gap: -ln(1-u)/λ  (u ∈ [0,1) keeps ln finite)
                next_ns += -(1.0 - rng.f64()).ln() / rps * 1e9;
                loop {
                    let now = t0.elapsed().as_nanos() as f64;
                    if now >= next_ns {
                        break;
                    }
                    let wait = next_ns - now;
                    if wait > 200_000.0 {
                        std::thread::sleep(Duration::from_nanos(
                            (wait - 100_000.0) as u64,
                        ));
                    } else {
                        std::hint::spin_loop();
                    }
                }
                let req = make_request(&mut gen, &mut rng, cfg.coverage, k, &tx);
                rep.sent += 1;
                match coord.submit(req)? {
                    Admission::Enqueued(_) => rep.accepted += 1,
                    Admission::Rejected => rep.rejected += 1,
                }
            }
            drop(tx);
            rep.completed = rx.iter().count();
            rep.lost = rep.accepted - rep.completed;
        }
        Arrival::ClosedLoop { concurrency } => {
            let window = concurrency.max(1);
            // `outstanding` tracks window occupancy. Shed/failed
            // requests never answer, so on a poll timeout we release
            // exactly as many slots as the coordinator's shed+failed
            // counters confirm were lost — a merely-slow batch (exec
            // time > the poll interval) keeps its slots and the loop
            // keeps waiting, so concurrency stays a true bound.
            // (Assumes this loadgen is the coordinator's only producer,
            // which is how serve-bench runs it.)
            let mut outstanding = 0usize;
            // baseline the ghost ledger so losses from a previous run()
            // on the same coordinator are not forgiven against THIS
            // run's window
            let start = coord.metrics.snapshot();
            let mut forgiven = start.shed + start.failed;
            while rep.sent < cfg.n_requests || outstanding > 0 {
                for _ in rx.try_iter() {
                    rep.completed += 1;
                    outstanding = outstanding.saturating_sub(1);
                }
                while rep.sent < cfg.n_requests && outstanding < window {
                    let k = rep.sent;
                    let req =
                        make_request(&mut gen, &mut rng, cfg.coverage, k, &tx);
                    rep.sent += 1;
                    match coord.submit(req)? {
                        Admission::Enqueued(_) => {
                            rep.accepted += 1;
                            outstanding += 1;
                        }
                        Admission::Rejected => rep.rejected += 1,
                    }
                }
                if outstanding == 0 {
                    continue; // whole window rejected; refill
                }
                match rx.recv_timeout(Duration::from_millis(300)) {
                    Ok(_) => {
                        rep.completed += 1;
                        outstanding -= 1;
                    }
                    Err(_) => {
                        let snap = coord.metrics.snapshot();
                        let ghosts = (snap.shed + snap.failed)
                            .saturating_sub(forgiven);
                        let release = (ghosts as usize).min(outstanding);
                        forgiven += release as u64;
                        outstanding -= release;
                    }
                }
            }
            drop(tx);
            // Every accepted request still holds a reply sender until a
            // worker answers or drops it, so this drain terminates and
            // catches any straggler that raced the ghost accounting.
            rep.completed += rx.iter().count();
            rep.lost = rep.accepted - rep.completed;
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::server::{Coordinator, CoordinatorConfig};
    use crate::data::profile;
    use crate::embeddings::EmbeddingStore;
    use std::sync::Arc;

    fn coord(workers: usize) -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                n_workers: workers,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            |_| Ok(Box::new(MockEngine::new(16, 3, 10, 8))),
        )
        .unwrap()
    }

    #[test]
    fn closed_loop_completes_everything() {
        let c = coord(2);
        let rep = run(
            &c,
            &profile("kdd").unwrap(),
            &LoadGenConfig {
                n_requests: 120,
                arrival: Arrival::ClosedLoop { concurrency: 16 },
                seed: 11,
                coverage: 1.0,
            },
        )
        .unwrap();
        assert_eq!(rep.sent, 120);
        assert_eq!(rep.accepted, 120);
        assert_eq!(rep.completed, 120);
        assert_eq!(rep.rejected + rep.lost, 0);
        c.shutdown();
    }

    #[test]
    fn open_loop_fast_rate_completes() {
        let c = coord(1);
        let rep = run(
            &c,
            &profile("kdd").unwrap(),
            &LoadGenConfig {
                n_requests: 80,
                arrival: Arrival::OpenLoop { rps: 1e6 },
                seed: 5,
                coverage: 0.5,
            },
        )
        .unwrap();
        assert_eq!(rep.sent, 80);
        assert_eq!(rep.completed, 80);
        c.shutdown();
    }

    #[test]
    fn subset_draw_is_deterministic_by_seed() {
        let p = profile("kdd").unwrap();
        let draw = |seed: u64| -> Vec<Vec<u32>> {
            let mut gen = Generator::new(p.clone(), seed);
            let mut rng = Rng::new(seed_from_name(seed, "loadgen"));
            let (tx, _rx) = mpsc::channel();
            (0..20)
                .map(|k| make_request(&mut gen, &mut rng, 0.4, k, &tx).fields)
                .collect()
        };
        assert_eq!(draw(9), draw(9));
        assert_ne!(draw(9), draw(10));
        for f in draw(9) {
            assert_eq!(f.len(), 4); // 0.4 × 10 fields
            assert!(f.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        }
    }

    #[test]
    fn partial_requests_round_trip() {
        let c = coord(2);
        let rep = run(
            &c,
            &profile("kdd").unwrap(),
            &LoadGenConfig {
                n_requests: 60,
                arrival: Arrival::ClosedLoop { concurrency: 8 },
                seed: 2,
                coverage: 0.3,
            },
        )
        .unwrap();
        assert_eq!(rep.completed, 60);
        c.shutdown();
    }
}
