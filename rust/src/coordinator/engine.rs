//! Inference-engine abstraction: the worker's compute backend.
//!
//! `PjrtEngine` executes the AOT model artifact; `PimEngine` executes
//! real crossbar math on `BatchedXbar` banks built from a genome
//! (`mapping::banks`, fully offline); `MockEngine` lets the
//! coordinator's scheduling/batching logic be tested hermetically (and
//! is also used to measure pure coordinator overhead in §Perf).

use crate::mapping::{build_pim_net_with, NetScratch, PimNet};
use crate::nas::Genome;
use crate::pim::{FaultCounts, XbarActivity, XbarOptions};
use crate::runtime::client::Runtime;

/// A batched CTR scorer: dense `[B×nd]` + gathered sparse `[B×Ns×d]` → `[B]`.
///
/// NOT `Send`: the PJRT client is `Rc`-internal, so each engine is
/// constructed inside its worker thread (see `Coordinator::start`).
pub trait InferenceEngine {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>>;

    /// [`InferenceEngine::infer_batch`] into a caller-owned buffer
    /// (cleared first). The serving worker calls this with one reused
    /// `probs` buffer per batch; engines that can score without
    /// allocating (`PimEngine`) override it, the default delegates.
    fn infer_batch_into(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        let probs = self.infer_batch(dense, sparse, batch)?;
        out.clear();
        out.extend_from_slice(&probs);
        Ok(())
    }

    /// The artifact's compiled batch size (inputs are padded to this).
    fn compiled_batch(&self) -> usize;
    fn n_dense(&self) -> usize;
    fn n_sparse(&self) -> usize;
    fn d_emb(&self) -> usize;

    /// Drain the device-fault counters accumulated since the last
    /// drain (S34: ABFT detections, spare-tile repairs, degraded rows).
    /// Engines without a device layer report nothing; the serving
    /// worker calls this once per served batch and feeds the metrics.
    fn take_fault_counts(&mut self) -> FaultCounts {
        FaultCounts::default()
    }
}

/// PJRT-backed engine for one (dataset, batch) model artifact.
pub struct PjrtEngine {
    runtime: Runtime,
    artifact: String,
    batch: usize,
    n_dense: usize,
    n_sparse: usize,
    d_emb: usize,
}

impl PjrtEngine {
    pub fn new(
        mut runtime: Runtime,
        dataset: &str,
        batch: usize,
        n_dense: usize,
        n_sparse: usize,
        d_emb: usize,
    ) -> crate::Result<PjrtEngine> {
        let artifact = Runtime::model_name(dataset, batch);
        runtime.ensure_compiled(&artifact)?;
        Ok(PjrtEngine {
            runtime,
            artifact,
            batch,
            n_dense: n_dense.max(1),
            n_sparse,
            d_emb,
        })
    }
}

impl InferenceEngine for PjrtEngine {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>> {
        crate::ensure!(batch <= self.batch, "batch {batch} > compiled {}", self.batch);
        // pad to the compiled batch
        let mut d = dense.to_vec();
        d.resize(self.batch * self.n_dense, 0.0);
        let mut s = sparse.to_vec();
        s.resize(self.batch * self.n_sparse * self.d_emb, 0.0);
        let probs = self.runtime.infer(
            &self.artifact,
            &d,
            [self.batch, self.n_dense],
            &s,
            [self.batch, self.n_sparse, self.d_emb],
        )?;
        Ok(probs[..batch].to_vec())
    }

    fn compiled_batch(&self) -> usize {
        self.batch
    }

    fn n_dense(&self) -> usize {
        self.n_dense
    }

    fn n_sparse(&self) -> usize {
        self.n_sparse
    }

    fn d_emb(&self) -> usize {
        self.d_emb
    }
}

/// Native PIM serving backend: scores requests by executing the
/// quantized bottom-MLP + mixed-precision interaction of a genome on
/// [`crate::pim::BatchedXbar`] banks ([`crate::mapping::PimNet`]) — the
/// batched bit-serial kernel on the actual request path, no artifacts
/// required. Fed by the worker's existing embedding gather: `sparse` is
/// the gathered `[B × Ns × d]` block, exactly as for `PjrtEngine`.
pub struct PimEngine {
    net: PimNet,
    scratch: NetScratch,
    batch: usize,
}

impl PimEngine {
    /// Build one engine (banks are programmed here — construction is the
    /// "crossbar programming" setup cost, so call it per worker thread,
    /// like `PjrtEngine` compilation).
    pub fn new(
        genome: &Genome,
        batch: usize,
        n_dense: usize,
        n_sparse: usize,
        d_emb: usize,
        seed: u64,
    ) -> crate::Result<PimEngine> {
        PimEngine::new_with(
            genome,
            batch,
            n_dense,
            n_sparse,
            d_emb,
            seed,
            &XbarOptions::default(),
        )
    }

    /// [`PimEngine::new`] with device fault-tolerance options (S34):
    /// spare-tile budget, ABFT gating, and seeded stuck-at injection,
    /// applied uniformly to every bank.
    #[allow(clippy::too_many_arguments)]
    pub fn new_with(
        genome: &Genome,
        batch: usize,
        n_dense: usize,
        n_sparse: usize,
        d_emb: usize,
        seed: u64,
        opts: &XbarOptions,
    ) -> crate::Result<PimEngine> {
        // no .max(1) clamp: a degenerate geometry should fail loudly at
        // construction (build_pim_net's ensure), not per-batch at serving
        let net = build_pim_net_with(genome, n_dense, n_sparse, d_emb, seed, opts)?;
        Ok(PimEngine {
            net,
            scratch: NetScratch::default(),
            batch: batch.max(1),
        })
    }

    /// Let every crossbar pass of this engine use up to `threads` worker
    /// threads (`XbarScratch::with_threads`). Scores are bit-identical
    /// at any setting — call at construction time, before serving.
    pub fn with_threads(mut self, threads: usize) -> PimEngine {
        self.scratch = NetScratch::with_threads(threads);
        self
    }

    /// Crossbar event counts accumulated by every batch served so far.
    pub fn activity(&self) -> XbarActivity {
        self.scratch.bank.xbar.activity
    }

    /// The bank stack — introspection for benches and tests (spare
    /// budget remaining, ground-truth corrupt tiles).
    pub fn net(&self) -> &PimNet {
        &self.net
    }
}

impl InferenceEngine for PimEngine {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(batch);
        self.infer_batch_into(dense, sparse, batch, &mut out)?;
        Ok(out)
    }

    /// The allocation-free scoring path: with a warmed `out` and the
    /// engine's persistent `NetScratch`, a served batch allocates
    /// nothing.
    fn infer_batch_into(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        crate::ensure!(batch <= self.batch, "batch {batch} > engine batch {}", self.batch);
        crate::ensure!(
            dense.len() >= batch * self.net.n_dense,
            "dense underfilled: {} < {}",
            dense.len(),
            batch * self.net.n_dense
        );
        crate::ensure!(
            sparse.len() >= batch * self.net.n_sparse * self.net.d_emb,
            "sparse underfilled: {} < {}",
            sparse.len(),
            batch * self.net.n_sparse * self.net.d_emb
        );
        let rows0 = self.scratch.bank.fault.corrupt_rows;
        self.net
            .forward_batch_into(dense, sparse, batch, out, &mut self.scratch);
        // Degraded-row accounting is per *response row*, not per bank:
        // if several unrepairable banks each booked this batch's rows,
        // clamp the delta so one batch never books more rows than it has.
        let fc = &mut self.scratch.bank.fault;
        fc.corrupt_rows = rows0 + (fc.corrupt_rows - rows0).min(batch as u64);
        // advance the device drift fuse by one served batch (the device
        // twin of CrashAfter/SlowAfter's batch counting)
        self.net.tick_drift();
        Ok(())
    }

    fn compiled_batch(&self) -> usize {
        self.batch
    }

    fn n_dense(&self) -> usize {
        self.net.n_dense
    }

    fn n_sparse(&self) -> usize {
        self.net.n_sparse
    }

    fn d_emb(&self) -> usize {
        self.net.d_emb
    }

    fn take_fault_counts(&mut self) -> FaultCounts {
        self.scratch.bank.fault.take()
    }
}

/// Deterministic stand-in engine: prob = sigmoid(mean(dense) + mean(sparse)).
pub struct MockEngine {
    pub batch: usize,
    pub n_dense: usize,
    pub n_sparse: usize,
    pub d_emb: usize,
    /// simulated per-batch compute time
    pub delay: std::time::Duration,
    /// optional gate: `infer_batch` spins until it reads `true` — tests
    /// use this to build queue backlog deterministically before
    /// releasing the worker (admission/overload scenarios)
    pub gate: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    pub calls: usize,
}

impl MockEngine {
    pub fn new(batch: usize, n_dense: usize, n_sparse: usize, d_emb: usize) -> Self {
        MockEngine {
            batch,
            n_dense: n_dense.max(1),
            n_sparse,
            d_emb,
            delay: std::time::Duration::ZERO,
            gate: None,
            calls: 0,
        }
    }
}

impl InferenceEngine for MockEngine {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>> {
        self.calls += 1;
        if let Some(g) = &self.gate {
            while !g.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(batch);
        for b in 0..batch {
            let dm: f32 = dense[b * self.n_dense..(b + 1) * self.n_dense]
                .iter()
                .sum::<f32>()
                / self.n_dense as f32;
            let stride = self.n_sparse * self.d_emb;
            let sm: f32 = sparse[b * stride..(b + 1) * stride].iter().sum::<f32>()
                / stride.max(1) as f32;
            out.push(1.0 / (1.0 + (-(dm + sm)).exp()));
        }
        Ok(out)
    }

    fn compiled_batch(&self) -> usize {
        self.batch
    }

    fn n_dense(&self) -> usize {
        self.n_dense
    }

    fn n_sparse(&self) -> usize {
        self.n_sparse
    }

    fn d_emb(&self) -> usize {
        self.d_emb
    }
}

/// Failure-injection wrapper: serves exactly like `inner` until its
/// trigger fires, then unwinds the worker thread — the same way a real
/// engine fault (device reset, OOM kill, watchdog abort) presents to
/// the coordinator: a panic mid-`infer`, not a polite `Err`. The
/// `worker-crash` scenario (`loadgen::CrashInjector`) and the failover
/// tests build on it.
///
/// The unwind uses [`std::panic::resume_unwind`] rather than `panic!`:
/// it raises the same unwinding the guards must survive, but skips the
/// global panic hook, so injected crashes do not spray backtraces over
/// test and bench output.
pub struct CrashAfter {
    inner: Box<dyn InferenceEngine>,
    /// crash when this many batches have been served (deterministic)
    after_batches: Option<usize>,
    /// crash at the first batch past this instant (wall-clock)
    deadline: Option<std::time::Instant>,
    batches: usize,
}

impl CrashAfter {
    /// Serve exactly `n` batches, then crash on the next one (`n = 0`
    /// crashes on the first call).
    pub fn after_batches(inner: Box<dyn InferenceEngine>, n: usize) -> CrashAfter {
        CrashAfter {
            inner,
            after_batches: Some(n),
            deadline: None,
            batches: 0,
        }
    }

    /// Serve normally until `deadline`, then crash on the next batch.
    pub fn at_deadline(
        inner: Box<dyn InferenceEngine>,
        deadline: std::time::Instant,
    ) -> CrashAfter {
        CrashAfter {
            inner,
            after_batches: None,
            deadline: Some(deadline),
            batches: 0,
        }
    }

    fn check_trigger(&self) {
        let tripped = self
            .after_batches
            .is_some_and(|n| self.batches >= n)
            || self.deadline.is_some_and(|d| std::time::Instant::now() >= d);
        if tripped {
            std::panic::resume_unwind(Box::new(
                "injected worker crash".to_string(),
            ));
        }
    }
}

impl InferenceEngine for CrashAfter {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>> {
        self.check_trigger();
        self.batches += 1;
        self.inner.infer_batch(dense, sparse, batch)
    }

    fn infer_batch_into(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        self.check_trigger();
        self.batches += 1;
        self.inner.infer_batch_into(dense, sparse, batch, out)
    }

    fn compiled_batch(&self) -> usize {
        self.inner.compiled_batch()
    }

    fn n_dense(&self) -> usize {
        self.inner.n_dense()
    }

    fn n_sparse(&self) -> usize {
        self.inner.n_sparse()
    }

    fn d_emb(&self) -> usize {
        self.inner.d_emb()
    }

    fn take_fault_counts(&mut self) -> FaultCounts {
        self.inner.take_fault_counts()
    }
}

/// Gray-failure injection wrapper: the slow twin of [`CrashAfter`].
/// Serves bit-identically to `inner` forever — same outputs, same
/// accessors — but once `after_batches` batches have been served, every
/// subsequent batch is delayed by `delay` plus a seeded jitter drawn
/// from `[0, jitter)`. The worker never dies and never errors; it just
/// straggles, which is exactly the failure the S33 tail-tolerance layer
/// (hedging, quarantine, brownout) must absorb. The `slow-worker`
/// scenario (`loadgen::SlowInjector`) builds on it.
pub struct SlowAfter {
    inner: Box<dyn InferenceEngine>,
    /// slow down once this many batches have been served
    after_batches: usize,
    delay: std::time::Duration,
    jitter: std::time::Duration,
    rng: crate::util::rng::Rng,
    batches: usize,
}

impl SlowAfter {
    /// Serve `n` batches at full speed, then add `delay` (+ jitter in
    /// `[0, jitter)`, drawn from `seed`) to every batch after.
    pub fn new(
        inner: Box<dyn InferenceEngine>,
        n: usize,
        delay: std::time::Duration,
        jitter: std::time::Duration,
        seed: u64,
    ) -> SlowAfter {
        SlowAfter {
            inner,
            after_batches: n,
            delay,
            jitter,
            rng: crate::util::rng::Rng::new(seed),
            batches: 0,
        }
    }

    fn straggle(&mut self) {
        if self.batches >= self.after_batches {
            let j = self.jitter.as_nanos() as u64;
            let extra = if j == 0 { 0 } else { self.rng.below(j) };
            std::thread::sleep(self.delay + std::time::Duration::from_nanos(extra));
        }
        self.batches += 1;
    }
}

impl InferenceEngine for SlowAfter {
    fn infer_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
    ) -> crate::Result<Vec<f32>> {
        self.straggle();
        self.inner.infer_batch(dense, sparse, batch)
    }

    fn infer_batch_into(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        self.straggle();
        self.inner.infer_batch_into(dense, sparse, batch, out)
    }

    fn compiled_batch(&self) -> usize {
        self.inner.compiled_batch()
    }

    fn n_dense(&self) -> usize {
        self.inner.n_dense()
    }

    fn n_sparse(&self) -> usize {
        self.inner.n_sparse()
    }

    fn d_emb(&self) -> usize {
        self.inner.d_emb()
    }

    fn take_fault_counts(&mut self) -> FaultCounts {
        self.inner.take_fault_counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;

    #[test]
    fn crash_after_serves_then_unwinds() {
        let inner = Box::new(MockEngine::new(8, 2, 3, 4));
        let mut e = CrashAfter::after_batches(inner, 2);
        let dense = vec![0.5f32; 2];
        let sparse = vec![0.1f32; 3 * 4];
        // two clean batches, bit-identical to the bare mock
        let mut bare = MockEngine::new(8, 2, 3, 4);
        let want = bare.infer_batch(&dense, &sparse, 1).unwrap();
        assert_eq!(e.infer_batch(&dense, &sparse, 1).unwrap(), want);
        assert_eq!(e.infer_batch(&dense, &sparse, 1).unwrap(), want);
        // the third unwinds
        let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || e.infer_batch(&dense, &sparse, 1),
        ));
        assert!(crashed.is_err(), "trigger must unwind, not return");
    }

    #[test]
    fn slow_after_straggles_but_stays_bit_identical() {
        let inner = Box::new(MockEngine::new(8, 2, 3, 4));
        let mut e = SlowAfter::new(
            inner,
            1,
            std::time::Duration::from_millis(5),
            std::time::Duration::ZERO,
            7,
        );
        let dense = vec![0.5f32; 2];
        let sparse = vec![0.1f32; 3 * 4];
        let mut bare = MockEngine::new(8, 2, 3, 4);
        let want = bare.infer_batch(&dense, &sparse, 1).unwrap();
        // batch 1: full speed, identical output
        let t = std::time::Instant::now();
        assert_eq!(e.infer_batch(&dense, &sparse, 1).unwrap(), want);
        assert!(t.elapsed() < std::time::Duration::from_millis(5));
        // batch 2: straggles, output STILL identical — gray, not wrong
        let t = std::time::Instant::now();
        assert_eq!(e.infer_batch(&dense, &sparse, 1).unwrap(), want);
        assert!(t.elapsed() >= std::time::Duration::from_millis(5));
        assert_eq!((e.n_dense(), e.n_sparse(), e.d_emb()), (2, 3, 4));
        assert_eq!(e.compiled_batch(), 8);
    }

    #[test]
    fn pim_engine_serves_valid_probabilities() {
        let g = autorac_best("criteo");
        let mut e = PimEngine::new(&g, 8, 13, 26, 16, 7).unwrap();
        assert_eq!(e.compiled_batch(), 8);
        assert_eq!((e.n_dense(), e.n_sparse(), e.d_emb()), (13, 26, 16));
        let b = 3;
        let dense: Vec<f32> = (0..b * 13).map(|i| (i as f32 * 0.13).sin()).collect();
        let sparse: Vec<f32> =
            (0..b * 26 * 16).map(|i| (i as f32 * 0.07).cos() * 0.05).collect();
        let p1 = e.infer_batch(&dense, &sparse, b).unwrap();
        assert_eq!(p1.len(), b);
        assert!(p1.iter().all(|p| (0.0..=1.0).contains(p)));
        // deterministic across calls, and crossbar activity accrues
        let p2 = e.infer_batch(&dense, &sparse, b).unwrap();
        assert!(p1.iter().zip(&p2).all(|(a, c)| a.to_bits() == c.to_bits()));
        assert!(e.activity().read_cycles > 0);
        assert!(e.activity().adc_conversions > 0);
        // oversized batch is refused
        assert!(e.infer_batch(&dense, &sparse, 9).is_err());
    }

    #[test]
    fn pim_engine_scores_do_not_depend_on_batching() {
        let g = autorac_best("kdd");
        let (nd, ns, d) = (11, 10, 8);
        let mut e = PimEngine::new(&g, 8, nd, ns, d, 3).unwrap();
        let b = 5;
        let dense: Vec<f32> = (0..b * nd).map(|i| (i as f32 * 0.31).sin()).collect();
        let sparse: Vec<f32> =
            (0..b * ns * d).map(|i| (i as f32 * 0.11).cos() * 0.05).collect();
        let batched = e.infer_batch(&dense, &sparse, b).unwrap();
        for j in 0..b {
            let one = e
                .infer_batch(
                    &dense[j * nd..(j + 1) * nd],
                    &sparse[j * ns * d..(j + 1) * ns * d],
                    1,
                )
                .unwrap();
            assert_eq!(one[0].to_bits(), batched[j].to_bits(), "row {j}");
        }
    }

    #[test]
    fn pim_engine_threads_and_into_buffer_do_not_change_scores() {
        let g = autorac_best("criteo");
        let mut e1 = PimEngine::new(&g, 8, 13, 26, 16, 7).unwrap();
        let mut e4 = PimEngine::new(&g, 8, 13, 26, 16, 7).unwrap().with_threads(4);
        let b = 4;
        let dense: Vec<f32> = (0..b * 13).map(|i| (i as f32 * 0.17).sin()).collect();
        let sparse: Vec<f32> =
            (0..b * 26 * 16).map(|i| (i as f32 * 0.05).cos() * 0.05).collect();
        let p1 = e1.infer_batch(&dense, &sparse, b).unwrap();
        // reused out-buffer across calls, threads=4
        let mut probs = vec![9.0f32; 99]; // stale garbage must be cleared
        e4.infer_batch_into(&dense, &sparse, b, &mut probs).unwrap();
        assert_eq!(probs.len(), b);
        assert!(p1.iter().zip(&probs).all(|(a, c)| a.to_bits() == c.to_bits()));
        e4.infer_batch_into(&dense, &sparse, b, &mut probs).unwrap();
        assert!(p1.iter().zip(&probs).all(|(a, c)| a.to_bits() == c.to_bits()));
        assert_eq!(e1.activity().read_cycles * 2, e4.activity().read_cycles);
    }

    #[test]
    fn pim_engine_drains_fault_counts_and_repairs() {
        let g = autorac_best("criteo");
        let opts = XbarOptions {
            spare_tiles: 2,
            ..XbarOptions::default()
        };
        let mut clean = PimEngine::new(&g, 8, 13, 26, 16, 7).unwrap();
        let mut e = PimEngine::new_with(&g, 8, 13, 26, 16, 7, &opts).unwrap();
        assert_eq!(e.take_fault_counts(), FaultCounts::default());
        let b = 3;
        let dense: Vec<f32> = (0..b * 13).map(|i| (i as f32 * 0.13).sin()).collect();
        let sparse: Vec<f32> =
            (0..b * 26 * 16).map(|i| (i as f32 * 0.07).cos() * 0.05).collect();
        let want = clean.infer_batch(&dense, &sparse, b).unwrap();
        // clean device: identical scores, nothing drained
        let p = e.infer_batch(&dense, &sparse, b).unwrap();
        assert!(want.iter().zip(&p).all(|(a, c)| a.to_bits() == c.to_bits()));
        assert_eq!(e.take_fault_counts(), FaultCounts::default());
        // corrupt a head cell: always excited (offset-binary inputs),
        // so the next batch detects, repairs, and re-serves exactly
        e.net.head.xbar.corrupt_bit(0, 0, 0, 0, 9);
        let p = e.infer_batch(&dense, &sparse, b).unwrap();
        assert!(want.iter().zip(&p).all(|(a, c)| a.to_bits() == c.to_bits()));
        let fc = e.take_fault_counts();
        assert!(fc.tiles_faulty > 0);
        assert_eq!(fc.tiles_repaired, 1);
        assert_eq!(fc.corrupt_rows, 0);
        // drain is a take: a second drain reports nothing
        assert_eq!(e.take_fault_counts(), FaultCounts::default());
        // and the wrapper forwards the drain
        let mut wrapped = CrashAfter::after_batches(
            Box::new(PimEngine::new_with(&g, 8, 13, 26, 16, 7, &opts).unwrap()),
            99,
        );
        wrapped.infer_batch(&dense, &sparse, b).unwrap();
        assert_eq!(wrapped.take_fault_counts(), FaultCounts::default());
    }

    #[test]
    fn mock_engine_is_deterministic_and_bounded() {
        let mut e = MockEngine::new(8, 2, 3, 4);
        let dense = vec![0.5f32; 2 * 2];
        let sparse = vec![0.1f32; 2 * 3 * 4];
        let a = e.infer_batch(&dense, &sparse, 2).unwrap();
        let b = e.infer_batch(&dense, &sparse, 2).unwrap();
        assert_eq!(a, b);
        assert!(a.iter().all(|p| (0.0..=1.0).contains(p)));
        assert_eq!(e.calls, 2);
    }
}
