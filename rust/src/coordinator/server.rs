//! The coordinator: leader that wires router → workers → batcher →
//! embedding gather → inference engine → responses, on std threads.
//!
//! Admission control: queues are bounded (`queue_cap` under every
//! policy) and overload is handled configurably — reject at the door
//! ([`AdmissionPolicy::RejectNew`]) or additionally shed stale
//! requests at dequeue time ([`AdmissionPolicy::ShedStale`]). Every
//! outcome is counted in [`Metrics`], so the books always balance:
//! `requests == responses + rejected + shed + failed`.
//!
//! Sharding: workers can serve from a [`ShardedStore`] (worker `i`
//! gathers from the perspective of shard `i % n_shards`, fetching
//! unowned tables cross-shard); the monolithic [`EmbeddingStore`] path
//! is unchanged.
//!
//! Caching (S29/S30): [`ServingStore::Cached`] layers an immutable
//! [`HotRowCache`] over the sharded store — workers consult it before
//! any shard, and every sharded/cached gather goes through each
//! worker's [`BatchGatherer`] so duplicate rows within a batch are
//! fetched once and scattered (RecNMP-style coalescing).

use super::batcher::{collect_batch, BatcherConfig};
use super::engine::InferenceEngine;
use super::metrics::Metrics;
use super::router::{Policy, RouteRejection, Router, WorkerSlot};
use super::tail::{FleetHealth, HedgeBudget, HedgeGate, HedgeTag, TailConfig};
use crate::embeddings::{
    BatchGatherer, EmbeddingStore, GatherStats, HotRowCache, ShardMap,
    ShardedStore,
};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One scoring request (features only; embedding gather happens on the
/// worker, next to the memory tiles). `fields[k]` is the table id of
/// `ids[k]` — a full request touches every table, a partial one (e.g. a
/// single-tower scorer) only a subset; untouched tables are zero-padded
/// at gather time.
pub struct Request {
    pub id: u64,
    pub dense: Vec<f32>,
    /// table ids touched, parallel to `ids` (strictly ascending)
    pub fields: Vec<u32>,
    pub ids: Vec<i32>,
    pub enqueued: Instant,
    /// end-to-end budget measured from `enqueued` (S33); `None` — the
    /// default — disables every deadline check for this request
    pub deadline: Option<Duration>,
    /// terminal-outcome claim shared with any hedge copy (S33);
    /// attached by `submit` when tail tolerance is configured
    pub tag: Option<HedgeTag>,
    pub reply: Sender<Response>,
}

impl Request {
    /// A request touching every table: `ids[j]` is the row of table `j`.
    pub fn full(id: u64, dense: Vec<f32>, ids: Vec<i32>, reply: Sender<Response>) -> Request {
        let fields = (0..ids.len() as u32).collect();
        Request {
            id,
            dense,
            fields,
            ids,
            enqueued: Instant::now(),
            deadline: None,
            tag: None,
            reply,
        }
    }

    /// A request touching only `fields` (ids parallel to fields).
    pub fn partial(
        id: u64,
        dense: Vec<f32>,
        fields: Vec<u32>,
        ids: Vec<i32>,
        reply: Sender<Response>,
    ) -> Request {
        debug_assert_eq!(fields.len(), ids.len());
        Request {
            id,
            dense,
            fields,
            ids,
            enqueued: Instant::now(),
            deadline: None,
            tag: None,
            reply,
        }
    }

    /// Attach an end-to-end deadline budget (builder style).
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Request {
        self.deadline = deadline;
        self
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prob: f32,
    pub e2e_ns: u64,
    /// structured error (`"deadline_exceeded"`); `None` for a served
    /// response — `prob` is meaningless when this is `Some`
    pub err: Option<&'static str>,
}

impl Response {
    /// A deadline-miss reply: the client paid for a deadline and gets
    /// told it was missed, rather than a silently closed channel.
    pub fn expired(id: u64, e2e_ns: u64) -> Response {
        Response {
            id,
            prob: 0.0,
            e2e_ns,
            err: Some("deadline_exceeded"),
        }
    }

    /// Whether this is a served (non-error) response.
    pub fn is_ok(&self) -> bool {
        self.err.is_none()
    }
}

/// What happens when queues are full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// reject a new request when the chosen worker's queue holds
    /// `queue_cap` requests (the caller sees `Admission::Rejected`)
    RejectNew,
    /// admit up to `queue_cap` (the bound still holds); the worker
    /// additionally sheds requests whose queue wait exceeded
    /// `shed_after` when it dequeues them (their reply channel closes
    /// without a response)
    ShedStale,
}

/// Outcome of [`Coordinator::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// enqueued on this worker's queue
    Enqueued(usize),
    /// turned away by admission control (counted in `metrics.rejected`)
    Rejected,
    /// turned away because no worker can plausibly meet the request's
    /// deadline budget (queue depth × EWMA service time exceeds it) —
    /// a `rejected` ledger leg with the `deadline_rejected` sub-cause,
    /// surfaced separately so the wire can answer `deadline_exceeded`
    DeadlineInfeasible,
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub n_workers: usize,
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// per-worker queue bound; `usize::MAX` = unbounded
    pub queue_cap: usize,
    pub admission: AdmissionPolicy,
    /// ShedStale: max tolerated queue wait before a request is dropped
    pub shed_after: Duration,
    /// Gray-failure tail tolerance (S33): deadline admission, hedged
    /// dispatch, breaker-aware routing, and brownout. `None` — the
    /// default — keeps the coordinator bit-identical to the pre-tail
    /// stack (per-request deadlines carried on the wire still expire
    /// at dequeue; everything else is off).
    pub tail: Option<TailConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_workers: 1,
            batcher: BatcherConfig::default(),
            policy: Policy::RoundRobin,
            queue_cap: usize::MAX,
            admission: AdmissionPolicy::RejectNew,
            shed_after: Duration::from_millis(50),
            tail: None,
        }
    }
}

/// The embedding memory the workers gather from.
#[derive(Clone)]
pub enum ServingStore {
    /// one monolithic store shared by every worker
    Shared(Arc<EmbeddingStore>),
    /// partitioned tables; worker `i` serves shard `i % n_shards`
    Sharded(Arc<ShardedStore>),
    /// sharded store fronted by an immutable hot-row cache every worker
    /// consults before touching any shard (the cache was warmed before
    /// serving started and never mutates here — lock-free reads)
    Cached(Arc<ShardedStore>, Arc<HotRowCache>),
}

/// Shared failover state for the sharded serving paths (S32): the live
/// ownership view every worker gathers through, plus the per-worker
/// liveness flags promotion is re-derived from. On worker death the
/// dying worker's guard calls [`ShardView::repromote`], which rebuilds
/// the view from the ORIGINAL map and the CURRENT liveness flags — a
/// pure function, so concurrent deaths compose in any order and the
/// last writer always publishes the correct cumulative view.
struct ShardView {
    /// the original placement (promotion always derives from this)
    base: ShardMap,
    /// the view workers currently gather through (swapped on death)
    view: RwLock<Arc<ShardMap>>,
    /// every worker's liveness flag, in worker order
    alive: Vec<Arc<AtomicBool>>,
}

impl ShardView {
    fn new(base: ShardMap, alive: Vec<Arc<AtomicBool>>) -> ShardView {
        let view = RwLock::new(Arc::new(base.clone()));
        ShardView { base, view, alive }
    }

    fn current(&self) -> Arc<ShardMap> {
        self.view.read().unwrap().clone()
    }

    /// Re-derive the view: a shard is dead only when EVERY worker
    /// serving it (worker `w` serves shard `w % n_shards`) is dead.
    fn repromote(&self) {
        let n_shards = self.base.n_shards;
        let mut shard_live = vec![false; n_shards];
        for (w, a) in self.alive.iter().enumerate() {
            if a.load(Ordering::Acquire) {
                shard_live[w % n_shards] = true;
            }
        }
        let dead: Vec<bool> = shard_live.iter().map(|&l| !l).collect();
        *self.view.write().unwrap() = Arc::new(self.base.promote(&dead));
    }
}

/// One logical request's entry in the governor's hedge registry (S33):
/// enough cloned content to re-enqueue a duplicate, the shared claim
/// gate, and the primary worker to hedge away from. Entries are pruned
/// lazily once their gate is claimed.
struct Pending {
    id: u64,
    dense: Vec<f32>,
    fields: Vec<u32>,
    ids: Vec<i32>,
    /// the ORIGINAL submit clock — the hedge copy inherits it so e2e
    /// latency and deadline expiry stay truthful for the logical request
    enqueued: Instant,
    deadline: Option<Duration>,
    reply: Sender<Response>,
    gate: Arc<HedgeGate>,
    /// where the primary copy went (the hedge must go elsewhere)
    worker: usize,
    hedged: bool,
}

impl Pending {
    /// Build the duplicate copy for hedged dispatch.
    fn hedge_request(&self) -> Request {
        Request {
            id: self.id,
            dense: self.dense.clone(),
            fields: self.fields.clone(),
            ids: self.ids.clone(),
            enqueued: self.enqueued,
            deadline: self.deadline,
            tag: Some(HedgeTag {
                gate: self.gate.clone(),
                is_hedge: true,
            }),
            reply: self.reply.clone(),
        }
    }
}

/// Live tail-tolerance state owned by the coordinator (S33).
struct TailState {
    pending: Arc<Mutex<VecDeque<Pending>>>,
    accepted: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    governor: Option<JoinHandle<()>>,
}

/// Everything the governor thread needs. It wakes every `cfg.tick`,
/// prunes claimed pending entries, hedges aged unclaimed ones onto the
/// healthiest other worker (budget permitting), and runs the brownout
/// pressure controller.
struct Governor {
    router: Arc<Router<Request>>,
    pending: Arc<Mutex<VecDeque<Pending>>>,
    metrics: Arc<Metrics>,
    budget: HedgeBudget,
    accepted: Arc<AtomicU64>,
    brownout: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
    cfg: TailConfig,
    queue_cap: usize,
}

fn governor_loop(g: Governor) {
    // brownout pressure window: diffs of (requests, expired+shed+
    // rejected), accumulated until enough traffic to judge
    let mut last = g.metrics.pressure_counts();
    let (mut win_req, mut win_bad) = (0u64, 0u64);
    while !g.stop.load(Ordering::Acquire) {
        std::thread::sleep(g.cfg.tick);
        // --- hedge scan ---
        let mut hedges: Vec<(usize, Request)> = Vec::new();
        {
            let mut q = g.pending.lock().unwrap();
            // prune settled requests from the front (their reply-sender
            // clones drop here, which is what lets client-side drains
            // that wait for all senders observe end-of-stream)
            while q.front().is_some_and(|p| p.gate.is_claimed()) {
                q.pop_front();
            }
            for p in q.iter_mut() {
                if p.gate.is_claimed() || p.hedged {
                    continue;
                }
                // submit order ≈ enqueue-time order: everything behind
                // the first young entry is younger still
                if p.enqueued.elapsed() < g.cfg.hedge_after {
                    break;
                }
                if !g.budget.try_take(g.accepted.load(Ordering::Relaxed)) {
                    break;
                }
                p.hedged = true;
                hedges.push((p.worker, p.hedge_request()));
            }
        }
        for (primary, req) in hedges {
            // NOT a ledger event: the hedge is a copy, not a request.
            // A failed placement is dropped on the floor — the primary
            // copy still owns the request's outcome.
            if g.router.route_hedge(primary, g.queue_cap, req).is_ok() {
                g.metrics.on_hedge();
            }
        }
        // --- brownout pressure controller ---
        let now = g.metrics.pressure_counts();
        win_req += now.0 - last.0;
        win_bad += now.1 - last.1;
        last = now;
        if win_req >= 16 {
            let pressure = win_bad as f64 / win_req as f64;
            let active = g.brownout.load(Ordering::Acquire);
            if !active && pressure >= g.cfg.brownout_enter {
                g.brownout.store(true, Ordering::Release);
                g.metrics.on_brownout_entry();
            } else if active && pressure <= g.cfg.brownout_exit {
                g.brownout.store(false, Ordering::Release);
            }
            (win_req, win_bad) = (0, 0);
        }
    }
    // the deque (and every remaining reply-sender clone) drops with the
    // governor's TailState owner, after workers have fully drained
}

pub struct Coordinator {
    router: Arc<Router<Request>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    queue_cap: usize,
    tail: Option<TailState>,
    health: Option<Arc<FleetHealth>>,
}

impl Coordinator {
    /// Start workers over one shared monolithic store (the original
    /// serving path); `make_engine(i)` runs INSIDE worker thread i to
    /// build its backend (the PJRT client is thread-local by design).
    pub fn start<F>(
        cfg: CoordinatorConfig,
        store: Arc<EmbeddingStore>,
        make_engine: F,
    ) -> crate::Result<Coordinator>
    where
        F: Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>
            + Send
            + Sync
            + 'static,
    {
        Coordinator::start_with(cfg, ServingStore::Shared(store), make_engine)
    }

    /// Start workers over any [`ServingStore`]. With a sharded store and
    /// `Policy::ShardAffinity`, the router scores workers by table
    /// ownership; otherwise the shard map only determines which tables
    /// each worker gathers locally.
    pub fn start_with<F>(
        cfg: CoordinatorConfig,
        store: ServingStore,
        make_engine: F,
    ) -> crate::Result<Coordinator>
    where
        F: Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>
            + Send
            + Sync
            + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let mut txs = Vec::new();
        let mut rxs: Vec<Receiver<Request>> = Vec::new();
        for _ in 0..cfg.n_workers {
            let (tx, rx) = mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let mut router = Router::new(txs, cfg.policy);
        // tail tolerance (S33): breaker states steer routing, workers
        // record service-time samples, and the brownout flag switches
        // gathers to cache/local-only under sustained pressure
        let health = cfg
            .tail
            .as_ref()
            .map(|tc| Arc::new(FleetHealth::new(cfg.n_workers, tc)));
        if let Some(h) = &health {
            router = router.with_health(h.clone());
        }
        let brownout = cfg.tail.as_ref().map(|_| Arc::new(AtomicBool::new(false)));
        match &store {
            ServingStore::Shared(_) => {}
            ServingStore::Sharded(s) => {
                router = router.with_shards(Arc::new(s.map.clone()));
            }
            ServingStore::Cached(s, c) => {
                router = router.with_shards(Arc::new(s.map.clone()));
                // warm-phase evictions are final — the serving-phase
                // cache is immutable — so book them once, up front
                metrics.on_cache_evictions(c.stats.evictions());
            }
        }
        let make_engine = Arc::new(make_engine);
        // every worker's liveness flag, registered for snapshots and
        // shared with the shard view so promotion can see the full set
        let all_alive: Vec<Arc<AtomicBool>> = (0..cfg.n_workers)
            .map(|i| router.slot_handle(i).alive_handle())
            .collect();
        for a in &all_alive {
            metrics.register_worker_alive(a.clone());
        }
        let shard_view = match &store {
            ServingStore::Shared(_) => None,
            ServingStore::Sharded(s) | ServingStore::Cached(s, _) => Some(
                Arc::new(ShardView::new(s.map.clone(), all_alive.clone())),
            ),
        };
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel();
        for (i, rx) in rxs.into_iter().enumerate() {
            let store = store.clone();
            let metrics = metrics.clone();
            let bcfg = cfg.batcher;
            let slot = router.slot_handle(i);
            metrics.register_worker_depth(slot.depth_handle());
            let make_engine = make_engine.clone();
            let ready = ready_tx.clone();
            let view = shard_view.clone();
            let shed_after = (cfg.admission == AdmissionPolicy::ShedStale)
                .then_some(cfg.shed_after);
            let health = health.clone();
            let brownout = brownout.clone();
            workers.push(std::thread::spawn(move || {
                match make_engine(i) {
                    Ok(engine) => {
                        let _ = ready.send(Ok(()));
                        // The guard owns the queue's end of life: on ANY
                        // exit — clean shutdown or panic — its Drop
                        // closes the slot, promotes the shard view,
                        // drains the queue, and books the leftovers as
                        // failed. Ledger conservation under crashes
                        // lives here.
                        let guard = WorkerGuard {
                            slot,
                            rx,
                            metrics: metrics.clone(),
                            view,
                            worker: i,
                        };
                        worker_loop(
                            &guard,
                            WorkerCtx {
                                engine,
                                store,
                                worker: i,
                                metrics,
                                bcfg,
                                shed_after,
                                health,
                                brownout,
                            },
                        );
                    }
                    Err(e) => {
                        // never served: close the slot so routing skips
                        // this worker while start_with unwinds
                        slot.close();
                        let _ = ready.send(Err(e));
                    }
                }
            }));
        }
        drop(ready_tx);
        let mut init_err = None;
        for r in ready_rx.iter().take(cfg.n_workers) {
            if let Err(e) = r {
                init_err = Some(e);
                break;
            }
        }
        if let Some(e) = init_err {
            // Unwind without leaking threads: the slots are shared with
            // the worker guards, so dropping the router alone no longer
            // closes any queue — close them all explicitly, then join
            // the workers that did spawn (their loops see end-of-stream
            // and exit through their guards).
            router.close_all();
            for w in workers {
                let _ = w.join();
            }
            return Err(crate::err!("worker engine init failed: {e:#}"));
        }
        metrics.reset_clock(); // engine compile time is not serving time
        let router = Arc::new(router);
        let tail = cfg.tail.as_ref().map(|tc| {
            let pending = Arc::new(Mutex::new(VecDeque::new()));
            let accepted = Arc::new(AtomicU64::new(0));
            let stop = Arc::new(AtomicBool::new(false));
            let gov = Governor {
                router: router.clone(),
                pending: pending.clone(),
                metrics: metrics.clone(),
                budget: HedgeBudget::new(tc.hedge_budget),
                accepted: accepted.clone(),
                brownout: brownout.clone().unwrap(),
                stop: stop.clone(),
                cfg: tc.clone(),
                queue_cap: cfg.queue_cap,
            };
            let governor = Some(std::thread::spawn(move || governor_loop(gov)));
            TailState {
                pending,
                accepted,
                stop,
                governor,
            }
        });
        Ok(Coordinator {
            router,
            workers,
            metrics,
            queue_cap: cfg.queue_cap,
            tail,
            health,
        })
    }

    /// Submit one request; an accepted request's reply arrives on
    /// `req.reply`, a rejected one never produces a response (its reply
    /// sender is dropped here).
    pub fn submit(&self, mut req: Request) -> crate::Result<Admission> {
        // `queue_cap` is a hard memory bound under BOTH policies —
        // ShedStale additionally trims stale requests at dequeue time,
        // it does not repeal the bound the operator configured.
        // Ledger discipline: `on_request` fires BEFORE routing (so no
        // snapshot can ever see a response outrun its request). A dead
        // worker is the router's problem — it re-picks among the
        // survivors — so `Closed` here means NO live worker remains;
        // that request is booked `failed` (an infrastructure loss, not
        // an admission decision — `rejected` stays an admission-control-
        // only signal), keeping
        // `requests == responses + rejected + shed + failed + expired`
        // exact.
        self.metrics.on_request();
        // deadline admission (S33): refuse a request no worker can
        // plausibly meet — cheaper for everyone than queueing work that
        // is doomed to expire at dequeue. Conservative on cold fleets:
        // no EWMA sample yet ⇒ admit.
        let pend = if let Some(t) = &self.tail {
            if let Some(d) = req.deadline {
                if let Some(eta) = self.router.eta_ns() {
                    let left = d.saturating_sub(req.enqueued.elapsed());
                    if Duration::from_nanos(eta) > left {
                        self.metrics.on_deadline_rejected();
                        return Ok(Admission::DeadlineInfeasible);
                    }
                }
            }
            // arm the hedge machinery: the gate is shared between the
            // primary copy (via the tag) and the governor's registry
            let gate = Arc::new(HedgeGate::new());
            req.tag = Some(HedgeTag {
                gate: gate.clone(),
                is_hedge: false,
            });
            Some((
                t,
                Pending {
                    id: req.id,
                    dense: req.dense.clone(),
                    fields: req.fields.clone(),
                    ids: req.ids.clone(),
                    enqueued: req.enqueued,
                    deadline: req.deadline,
                    reply: req.reply.clone(),
                    gate,
                    worker: 0,
                    hedged: false,
                },
            ))
        } else {
            None
        };
        match self
            .router
            .route_bounded_by(self.queue_cap, req, |r| r.fields.as_slice())
        {
            Ok(w) => {
                if let Some((t, mut p)) = pend {
                    p.worker = w;
                    t.accepted.fetch_add(1, Ordering::Relaxed);
                    t.pending.lock().unwrap().push_back(p);
                }
                Ok(Admission::Enqueued(w))
            }
            Err(RouteRejection::Overloaded(_req)) => {
                self.metrics.on_rejected();
                Ok(Admission::Rejected)
            }
            Err(RouteRejection::Closed(_req)) => {
                self.metrics.on_failed(1);
                crate::bail!("no live worker remains")
            }
        }
    }

    /// Fleet breaker states (tail tolerance only; `None` otherwise).
    pub fn health(&self) -> Option<&Arc<FleetHealth>> {
        self.health.as_ref()
    }

    /// Instantaneous queue depth of each worker.
    pub fn queue_depths(&self) -> Vec<usize> {
        (0..self.router.n_workers())
            .map(|i| self.router.depth(i))
            .collect()
    }

    /// Workers still accepting requests.
    pub fn n_live(&self) -> usize {
        self.router.n_alive()
    }

    /// Close intake and join workers (drains in-flight batches). The
    /// slots are shared with the worker guards, so the queues must be
    /// closed explicitly — dropping the router would not end them.
    pub fn shutdown(mut self) {
        // stop the governor FIRST: no new hedges land on queues that are
        // about to close, and the pending registry (holding reply-sender
        // clones) drops before clients could block on a drain
        if let Some(t) = &mut self.tail {
            t.stop.store(true, Ordering::Release);
            if let Some(g) = t.governor.take() {
                let _ = g.join();
            }
            t.pending.lock().unwrap().clear();
        }
        self.router.close_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Claim a request's terminal outcome. Returns `true` for exactly one
/// caller per logical request (the ledger writer); with no tag — tail
/// tolerance off — every caller wins, preserving the pre-tail behavior.
/// Losing hedge copies book the non-ledger `hedge_suppressed` counter.
fn claim_terminal(tag: &Option<HedgeTag>, metrics: &Metrics) -> bool {
    match tag {
        None => true,
        Some(t) => {
            let won = t.gate.claim();
            if !won {
                metrics.on_hedge_suppressed();
            }
            won
        }
    }
}

struct WorkerCtx {
    engine: Box<dyn InferenceEngine>,
    store: ServingStore,
    worker: usize,
    metrics: Arc<Metrics>,
    bcfg: BatcherConfig,
    /// Some(limit) ⇒ shed requests that waited longer than `limit`
    shed_after: Option<Duration>,
    /// tail tolerance (S33): per-worker service-time samples feed the
    /// fleet breaker; `None` when tail tolerance is off
    health: Option<Arc<FleetHealth>>,
    /// brownout flag (S33): when set, gathers skip cross-shard fetches
    brownout: Option<Arc<AtomicBool>>,
}

/// Sentinel owning one worker's queue end of life. Its `Drop` runs on
/// EVERY exit from `worker_loop` — clean shutdown or panic — and:
///
/// 1. closes the slot (alive flips, then the only sender is taken under
///    the send lock, so nothing can land on the queue afterwards);
/// 2. promotes the shard view, re-pointing survivors' cross-shard
///    gathers at live replicas of this worker's tables;
/// 3. drains the queue — every request still buffered will never be
///    served, so each is booked `failed` and its reply sender closes
///    (clients observe a closed channel, not a hang).
///
/// Step 1 before step 3 is what makes the drain complete: the slot held
/// the ONLY sender, so post-close the buffered set is final and the
/// ledger stays exact under any crash interleaving.
struct WorkerGuard {
    slot: Arc<WorkerSlot<Request>>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    view: Option<Arc<ShardView>>,
    worker: usize,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        self.slot.close();
        if let Some(v) = &self.view {
            v.repromote();
        }
        // Book the losses BEFORE dropping the reply senders: a client
        // draining its reply channel unblocks the moment the last
        // sender drops, and must find the ledger already balanced.
        // Claim-aware (S33): a drained hedge copy whose twin already
        // answered is NOT a loss — only claim winners book `failed`.
        let mut drained: Vec<Request> = Vec::new();
        while let Ok(r) = self.rx.try_recv() {
            drained.push(r);
        }
        if !drained.is_empty() {
            depth_release(&self.slot.depth_handle(), drained.len());
            let lost = drained
                .iter()
                .filter(|r| claim_terminal(&r.tag, &self.metrics))
                .count();
            if lost > 0 {
                self.metrics.on_failed(lost);
            }
        }
        // the Vec (and with it every queued reply sender, which closes
        // unanswered) drops at end of scope, after the books are square
        let drained = drained.len();
        if std::thread::panicking() {
            crate::error!(
                "worker {} died; {} queued request(s) booked failed",
                self.worker,
                drained
            );
        }
    }
}

/// Covers the batch between dequeue and outcome booking: if the worker
/// panics mid-flight (gather or engine), `Drop` books the batch as
/// failed. The normal paths zero `n` once the batch is booked through
/// `on_response`/`on_failed`, making this a no-op. Claim-aware (S33):
/// `gates` holds the batch's hedge gates (empty when tail tolerance is
/// off) so a panicking worker never books a loss for a request whose
/// twin copy already answered.
struct InflightGuard<'a> {
    metrics: &'a Metrics,
    n: usize,
    gates: Vec<Arc<HedgeGate>>,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        if self.n == 0 {
            return;
        }
        if self.gates.is_empty() {
            self.metrics.on_failed(self.n);
            return;
        }
        let mut lost = 0usize;
        for g in &self.gates {
            if g.claim() {
                lost += 1;
            } else {
                self.metrics.on_hedge_suppressed();
            }
        }
        if lost > 0 {
            self.metrics.on_failed(lost);
        }
    }
}

/// Saturating queue-depth decrement. The gauge is shared by concurrent
/// submitters (`Router::dispatch` increments, and transiently overshoots
/// then rolls back on rejection) and this worker; the old
/// `fetch_sub(n.min(depth.load()))` pattern is a check-then-act race —
/// two racing decrements (or a rollback landing between the load and the
/// sub) can drive the counter below the subtrahend and wrap it to
/// `usize::MAX`, after which `route_bounded` sees an eternally-full
/// queue and rejects everything. A `fetch_update` CAS loop re-reads the
/// current value on every attempt, so the subtraction saturates at 0
/// instead of underflowing, whatever interleaving happens.
pub(crate) fn depth_release(depth: &std::sync::atomic::AtomicUsize, n: usize) {
    let _ = depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
        Some(d.saturating_sub(n))
    });
}

fn worker_loop(guard: &WorkerGuard, ctx: WorkerCtx) {
    let WorkerCtx {
        mut engine,
        store,
        worker,
        metrics,
        bcfg,
        shed_after,
        health,
        brownout,
    } = ctx;
    let rx = &guard.rx;
    let depth = guard.slot.depth_handle();
    let shard = match &store {
        ServingStore::Shared(_) => 0,
        ServingStore::Sharded(s) | ServingStore::Cached(s, _) => {
            worker % s.map.n_shards
        }
    };
    // per-worker coalescing engine for the sharded/cached paths (its
    // arenas persist across batches — allocation-free after warmup)
    let mut gatherer = match &store {
        ServingStore::Shared(_) => None,
        ServingStore::Sharded(s) | ServingStore::Cached(s, _) => {
            Some(BatchGatherer::new(&s.cards))
        }
    };
    let nd = engine.n_dense();
    let (ns, d_emb) = (engine.n_sparse(), engine.d_emb());
    let cap = engine.compiled_batch().min(bcfg.max_batch);
    let bcfg = BatcherConfig {
        max_batch: cap,
        ..bcfg
    };
    // Persistent per-worker arenas sized to the compiled batch: the
    // gather/score hot path below allocates nothing per batch.
    let mut dense: Vec<f32> = Vec::with_capacity(cap * nd);
    let mut sparse: Vec<f32> = Vec::with_capacity(cap * ns * d_emb);
    let mut probs: Vec<f32> = Vec::with_capacity(cap);
    while let Some(mut batch) = collect_batch(rx, &bcfg) {
        depth_release(&depth, batch.len());
        // Deadline expiry (S33): a request whose end-to-end budget has
        // already elapsed is NEVER executed — the client gets a
        // structured `deadline_exceeded` reply and the ledger books
        // `expired`. The deadline rides the request itself, so this
        // works with tail tolerance off too; claim-aware so an expired
        // hedge copy whose twin already answered books nothing.
        {
            let mut expired = 0usize;
            batch.retain(|r| {
                let over =
                    r.deadline.is_some_and(|d| r.enqueued.elapsed() > d);
                if !over {
                    return true;
                }
                if claim_terminal(&r.tag, &metrics) {
                    expired += 1;
                    let e2e = r.enqueued.elapsed().as_nanos() as u64;
                    let _ = r.reply.send(Response::expired(r.id, e2e));
                }
                false
            });
            if expired > 0 {
                metrics.on_expired(expired);
            }
            if batch.is_empty() {
                continue;
            }
        }
        // Load shedding: a request that sat in the queue past its
        // budget is dropped here (its reply sender closes unanswered) —
        // under overload this keeps served latency bounded instead of
        // letting the queue wait grow without limit.
        if let Some(limit) = shed_after {
            let mut shed = 0usize;
            batch.retain(|r| {
                if r.enqueued.elapsed() <= limit {
                    return true;
                }
                if claim_terminal(&r.tag, &metrics) {
                    shed += 1;
                }
                false
            });
            if shed > 0 {
                metrics.on_shed(shed);
            }
            if batch.is_empty() {
                continue;
            }
        }
        // from here to the outcome booking, a panic loses the batch —
        // cover it so the crash books `failed` instead of leaking
        let mut inflight = InflightGuard {
            metrics: &metrics,
            n: batch.len(),
            gates: batch
                .iter()
                .filter_map(|r| r.tag.as_ref().map(|t| t.gate.clone()))
                .collect(),
        };
        let t_exec = Instant::now();
        let queue_ns = batch
            .iter()
            .map(|r| r.enqueued.elapsed().as_nanos() as u64)
            .max()
            .unwrap_or(0);
        // assemble inputs: dense [B×nd], gather sparse [B×Ns×d] — both
        // written in place into the persistent arenas (truncate/zero-pad
        // the dense row without the per-request clone the old path paid)
        dense.clear();
        sparse.clear();
        for r in &batch {
            let take = r.dense.len().min(nd);
            dense.extend_from_slice(&r.dense[..take]);
            dense.resize(dense.len() + (nd - take), 0.0);
        }
        // Brownout (S33): under sustained deadline pressure the
        // governor sets this flag and gathers skip cross-shard fetches
        // (remote-owned rows are zero-filled and counted `degraded`) —
        // a degraded answer now beats a perfect answer too late. The
        // monolithic path has no remote leg, so brownout is a no-op.
        let degrade = brownout
            .as_ref()
            .is_some_and(|b| b.load(Ordering::Acquire));
        // sparse side: the sharded/cached paths gather the WHOLE batch
        // through the coalescer (duplicate rows fetched once); the
        // monolithic path stays per-record
        let gs = match &store {
            ServingStore::Shared(s) => {
                let mut gs = GatherStats::default();
                for r in &batch {
                    gs.oob += s.gather_fields(&r.fields, &r.ids, &mut sparse);
                    gs.requested += r.fields.len();
                    gs.local += r.fields.len();
                }
                gs
            }
            // sharded paths gather through the CURRENT ownership view —
            // after a worker death this is the promoted map, so
            // cross-shard fetches target live replicas (bit-identical
            // rows; see `ShardMap::promote`)
            ServingStore::Sharded(s) => {
                let map = guard.view.as_ref().unwrap().current();
                gatherer.as_mut().unwrap().gather_batch_mode(
                    &map,
                    s,
                    None,
                    shard,
                    batch.iter().map(|r| (r.fields.as_slice(), r.ids.as_slice())),
                    &mut sparse,
                    degrade,
                )
            }
            ServingStore::Cached(s, c) => {
                let map = guard.view.as_ref().unwrap().current();
                gatherer.as_mut().unwrap().gather_batch_mode(
                    &map,
                    s,
                    Some(&**c),
                    shard,
                    batch.iter().map(|r| (r.fields.as_slice(), r.ids.as_slice())),
                    &mut sparse,
                    degrade,
                )
            }
        };
        metrics.on_gather(&gs);
        if gs.degraded > 0 {
            // batch-level attribution: the coalescer doesn't track
            // which request owned a skipped row, so every response in
            // a batch that zero-filled anything counts as degraded
            metrics.on_degraded(batch.len(), gs.degraded);
        }
        match engine.infer_batch_into(&dense, &sparse, batch.len(), &mut probs) {
            Ok(()) => {
                let exec_ns = t_exec.elapsed().as_nanos() as u64;
                metrics.on_batch(batch.len(), queue_ns, exec_ns);
                // drain the engine's device-fault ledger (S34): ABFT
                // detections, spare repairs, degraded rows — booked
                // off-ledger, the batch's responses still count below
                let fc = engine.take_fault_counts();
                if fc.any() {
                    metrics.on_device_faults(&fc);
                }
                // per-request service-time sample feeds the breaker —
                // this is where a gray (slow-but-correct) worker shows
                // up, batches later, as Probation/Quarantined
                if let Some(h) = &health {
                    h.record(worker, exec_ns / batch.len().max(1) as u64);
                }
                inflight.n = 0; // every outcome below books itself
                for (r, &p) in batch.into_iter().zip(&probs) {
                    // exactly-one-response: only the claim winner
                    // replies; a losing copy is silently discarded
                    if !claim_terminal(&r.tag, &metrics) {
                        continue;
                    }
                    if r.tag.as_ref().is_some_and(|t| t.is_hedge) {
                        metrics.on_hedge_won();
                    }
                    let e2e = r.enqueued.elapsed().as_nanos() as u64;
                    metrics.on_response(e2e);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        prob: p,
                        e2e_ns: e2e,
                        err: None,
                    });
                }
            }
            Err(e) => {
                crate::error!("worker inference failed: {e:#}");
                // drop the batch; claim winners book `failed`, losing
                // hedge copies book nothing (their twin owns the
                // outcome); senders observe a closed reply channel
                let lost = batch
                    .iter()
                    .filter(|r| claim_terminal(&r.tag, &metrics))
                    .count();
                if lost > 0 {
                    metrics.on_failed(lost);
                }
                inflight.n = 0; // booked as failed just above
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::data::profile;

    fn store() -> Arc<EmbeddingStore> {
        Arc::new(EmbeddingStore::random(
            &profile("criteo").unwrap(),
            16,
            3,
        ))
    }

    fn start(workers: usize) -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                n_workers: workers,
                ..Default::default()
            },
            store(),
            |_| Ok(Box::new(MockEngine::new(32, 13, 26, 16))),
        )
        .unwrap()
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let c = start(2);
        let (tx, rx) = mpsc::channel();
        let n = 200;
        for id in 0..n {
            c.submit(Request::full(id, vec![0.1; 13], vec![1; 26], tx.clone()))
                .unwrap();
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().take(n as usize).map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, n);
        assert!(snap.mean_batch >= 1.0);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.shed, 0);
        c.shutdown();
    }

    #[test]
    fn probabilities_are_valid() {
        let c = start(1);
        let (tx, rx) = mpsc::channel();
        let adm = c
            .submit(Request::full(1, vec![0.5; 13], (0..26).collect(), tx))
            .unwrap();
        assert!(matches!(adm, Admission::Enqueued(_)));
        let resp = rx.recv().unwrap();
        assert!((0.0..=1.0).contains(&resp.prob));
        assert!(resp.e2e_ns > 0);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let c = start(3);
        let (tx, rx) = mpsc::channel();
        for id in 0..50 {
            c.submit(Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone()))
                .unwrap();
        }
        drop(tx);
        c.shutdown();
        assert_eq!(rx.iter().count(), 50);
    }

    /// Engine that fails every other batch — exercises the error path.
    struct FlakyEngine {
        inner: MockEngine,
        calls: usize,
    }

    impl crate::coordinator::engine::InferenceEngine for FlakyEngine {
        fn infer_batch(
            &mut self,
            dense: &[f32],
            sparse: &[f32],
            batch: usize,
        ) -> crate::Result<Vec<f32>> {
            self.calls += 1;
            if self.calls % 2 == 0 {
                crate::bail!("injected engine failure");
            }
            self.inner.infer_batch(dense, sparse, batch)
        }

        fn compiled_batch(&self) -> usize {
            self.inner.compiled_batch()
        }
        fn n_dense(&self) -> usize {
            self.inner.n_dense()
        }
        fn n_sparse(&self) -> usize {
            self.inner.n_sparse()
        }
        fn d_emb(&self) -> usize {
            self.inner.d_emb()
        }
    }

    #[test]
    fn failure_injection_drops_batches_but_never_wedges() {
        crate::util::logger::set_level(crate::util::logger::Level::Error);
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    max_batch: 1, // one request per batch → every 2nd fails
                    max_wait: Duration::from_micros(10),
                },
                ..Default::default()
            },
            store(),
            |_| {
                Ok(Box::new(FlakyEngine {
                    inner: MockEngine::new(1, 13, 26, 16),
                    calls: 0,
                }))
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 40;
        for id in 0..n {
            c.submit(Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone()))
                .unwrap();
        }
        drop(tx);
        let ok: Vec<_> = rx.iter().collect();
        // exactly the odd-numbered calls succeed; the failed batches are
        // dropped (senders see a closed reply), and the worker survives
        assert_eq!(ok.len() as u64, n / 2, "{} responses", ok.len());
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.responses, n / 2);
        assert_eq!(snap.failed, n / 2, "failed batches must be counted");
        c.shutdown();
        crate::util::logger::set_level(crate::util::logger::Level::Info);
    }

    #[test]
    fn worker_crash_books_losses_and_reroutes() {
        use crate::coordinator::engine::CrashAfter;
        crate::util::logger::set_level(crate::util::logger::Level::Error);
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_micros(10),
                },
                ..Default::default()
            },
            store(),
            |i| {
                let e: Box<dyn InferenceEngine> =
                    Box::new(MockEngine::new(4, 13, 26, 16));
                Ok(if i == 0 {
                    // worker 0 serves one batch, then dies mid-infer
                    Box::new(CrashAfter::after_batches(e, 1))
                } else {
                    e
                })
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 300u64;
        for id in 0..n {
            c.submit(Request::full(id, vec![0.1; 13], vec![1; 26], tx.clone()))
                .expect("a live worker remains; submit must never error");
        }
        drop(tx);
        let got = rx.iter().count() as u64;
        // the dying worker's drain is asynchronous — poll until the
        // ledger balances, which implies the crash was fully booked
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let snap = c.metrics.snapshot();
            if snap.responses + snap.failed == n {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "ledger never balanced: {snap:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert!(snap.failed > 0, "crash losses must be booked failed");
        assert_eq!(snap.rejected, 0, "a crash is not an admission decision");
        assert_eq!(snap.responses, got);
        assert!(snap.ledger_ok(), "conservation across the crash: {snap:?}");
        assert_eq!(snap.live_workers(), 1);
        assert_eq!(c.n_live(), 1);
        c.shutdown();
        crate::util::logger::set_level(crate::util::logger::Level::Info);
    }

    #[test]
    fn init_error_unwinds_and_joins_spawned_workers() {
        // worker 2's engine fails to build: the error must surface AND
        // the two healthy workers must be joined. Without close_all on
        // the unwind path their queues (shared with the worker guards)
        // would never end and this test would hang forever on join.
        let r = Coordinator::start(
            CoordinatorConfig {
                n_workers: 3,
                ..Default::default()
            },
            store(),
            |i| {
                if i == 2 {
                    crate::bail!("injected init failure");
                }
                Ok(Box::new(MockEngine::new(32, 13, 26, 16)))
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn batching_engages_under_burst() {
        let c = start(1);
        let (tx, rx) = mpsc::channel();
        for id in 0..64 {
            c.submit(Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone()))
                .unwrap();
        }
        drop(tx);
        let _: Vec<_> = rx.iter().collect();
        let snap = c.metrics.snapshot();
        assert!(
            snap.mean_batch > 1.5,
            "burst should batch: mean {}",
            snap.mean_batch
        );
        c.shutdown();
    }

    #[test]
    fn reject_new_bounds_the_queue() {
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let gate2 = gate.clone();
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                queue_cap: 8,
                admission: AdmissionPolicy::RejectNew,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::ZERO,
                },
                ..Default::default()
            },
            store(),
            move |_| {
                let mut e = MockEngine::new(4, 13, 26, 16);
                e.gate = Some(gate2.clone());
                Ok(Box::new(e))
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 64u64;
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        for id in 0..n {
            match c
                .submit(Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone()))
                .unwrap()
            {
                Admission::Enqueued(_) => accepted += 1,
                Admission::Rejected => rejected += 1,
                Admission::DeadlineInfeasible => {
                    unreachable!("no deadline was set")
                }
            }
        }
        assert!(rejected > 0, "cap 8 must reject part of a 64-burst");
        gate.store(true, Ordering::Relaxed); // release the engine
        drop(tx);
        let got = rx.iter().count() as u64;
        assert_eq!(got, accepted, "every accepted request gets a response");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.rejected, rejected);
        assert_eq!(snap.responses + snap.rejected, n);
        c.shutdown();
    }

    #[test]
    fn depth_release_never_underflows_under_concurrent_updates() {
        // Regression for the racy `fetch_sub(n.min(load()))` pattern:
        // hammer one gauge with racing decrements whose total exceeds
        // the increments. An underflow wraps to ~usize::MAX, which the
        // bounded router would read as an eternally-full queue; the
        // saturating CAS loop must land at a small, sane value instead.
        use std::sync::atomic::AtomicUsize;
        let depth = Arc::new(AtomicUsize::new(0));
        let threads = 8;
        let rounds = 2000;
        let mut handles = Vec::new();
        for t in 0..threads {
            let d = depth.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..rounds {
                    if (t + i) % 3 == 0 {
                        d.fetch_add(1, Ordering::Relaxed);
                    } else {
                        // decrements outnumber increments 2:1
                        depth_release(&d, 1);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // never wrapped: the gauge is bounded by the total increments
        let v = depth.load(Ordering::Relaxed);
        assert!(v <= threads * rounds, "depth gauge wrapped: {v}");
        // and a direct over-subtraction saturates at zero
        depth.store(3, Ordering::Relaxed);
        depth_release(&depth, 10);
        assert_eq!(depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn deadline_expiry_replies_structured_and_books_expired() {
        // Deadline checks need no TailConfig: the budget rides the
        // request. Gate the engine so everything goes stale in-queue,
        // then release — expired requests get a structured reply
        // instead of a silently closed channel, and the extended
        // ledger (`… + expired`) stays exact.
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let gate2 = gate.clone();
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::ZERO,
                },
                ..Default::default()
            },
            store(),
            move |_| {
                let mut e = MockEngine::new(4, 13, 26, 16);
                e.gate = Some(gate2.clone());
                Ok(Box::new(e))
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 16u64;
        for id in 0..n {
            let r = Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone())
                .with_deadline(Some(Duration::from_millis(20)));
            assert!(matches!(c.submit(r).unwrap(), Admission::Enqueued(_)));
        }
        std::thread::sleep(Duration::from_millis(50));
        gate.store(true, Ordering::Relaxed);
        drop(tx);
        // every accepted request is answered: served or told "expired"
        let replies: Vec<Response> = rx.iter().collect();
        assert_eq!(replies.len() as u64, n, "one reply per request");
        let served = replies.iter().filter(|r| r.is_ok()).count() as u64;
        let expired = replies
            .iter()
            .filter(|r| r.err == Some("deadline_exceeded"))
            .count() as u64;
        assert_eq!(served + expired, n);
        assert!(expired > 0, "a 20ms budget must expire under a 50ms stall");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.responses, served);
        assert_eq!(snap.expired, expired);
        assert!(snap.ledger_ok(), "extended conservation: {snap:?}");
        c.shutdown();
    }

    #[test]
    fn hedged_dispatch_answers_exactly_once_under_a_gray_worker() {
        use crate::coordinator::engine::SlowAfter;
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                policy: Policy::LeastQueued,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(10),
                },
                tail: Some(TailConfig {
                    hedge_after: Duration::from_millis(2),
                    hedge_budget: 1.0, // hedge freely in this test
                    tick: Duration::from_millis(1),
                    ..TailConfig::default()
                }),
                ..Default::default()
            },
            store(),
            |i| {
                let e: Box<dyn InferenceEngine> =
                    Box::new(MockEngine::new(1, 13, 26, 16));
                Ok(if i == 0 {
                    // worker 0 is gray from the start: correct answers,
                    // 20ms late, every batch
                    Box::new(SlowAfter::new(
                        e,
                        0,
                        Duration::from_millis(20),
                        Duration::ZERO,
                        7,
                    ))
                } else {
                    e
                })
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 40u64;
        for id in 0..n {
            c.submit(Request::full(id, vec![0.1; 13], vec![1; 26], tx.clone()))
                .unwrap();
        }
        drop(tx);
        // exactly one response per logical request — sorted ids must be
        // 0..n with no duplicate and no hole, despite duplicate copies
        // racing on two workers
        let mut got: Vec<u64> = rx.iter().map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.responses, n);
        assert!(snap.hedges > 0, "a 20ms straggler must trigger hedges");
        assert!(snap.ledger_ok(), "hedging must not bend the ledger");
        c.shutdown();
    }

    #[test]
    fn shed_stale_drops_overdue_requests() {
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let gate2 = gate.clone();
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                admission: AdmissionPolicy::ShedStale,
                shed_after: Duration::from_millis(20),
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::ZERO,
                },
                ..Default::default()
            },
            store(),
            move |_| {
                let mut e = MockEngine::new(4, 13, 26, 16);
                e.gate = Some(gate2.clone());
                Ok(Box::new(e))
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 32u64;
        for id in 0..n {
            assert_eq!(
                c.submit(Request::full(id, vec![0.0; 13], vec![0; 26], tx.clone()))
                    .unwrap(),
                Admission::Enqueued(0),
                "ShedStale never rejects at the door"
            );
        }
        // let everything go stale, then release the engine
        std::thread::sleep(Duration::from_millis(40));
        gate.store(true, Ordering::Relaxed);
        drop(tx);
        let got = rx.iter().count() as u64;
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.rejected, 0);
        assert!(snap.shed > 0, "stale requests must be shed");
        assert_eq!(snap.responses, got);
        assert_eq!(snap.responses + snap.shed, n, "conservation");
        c.shutdown();
    }
}
