//! The coordinator: leader that wires router → workers → batcher →
//! embedding gather → inference engine → responses, on std threads.

use super::batcher::{collect_batch, BatcherConfig};
use super::engine::InferenceEngine;
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::embeddings::EmbeddingStore;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// One scoring request (features only; embedding gather happens on the
/// worker, next to the memory tiles).
pub struct Request {
    pub id: u64,
    pub dense: Vec<f32>,
    pub ids: Vec<i32>,
    pub enqueued: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prob: f32,
    pub e2e_ns: u64,
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub n_workers: usize,
    pub batcher: BatcherConfig,
    pub policy: Policy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_workers: 1,
            batcher: BatcherConfig::default(),
            policy: Policy::RoundRobin,
        }
    }
}

pub struct Coordinator {
    router: Router<Request>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start workers; `make_engine(i)` runs INSIDE worker thread i to
    /// build its backend (the PJRT client is thread-local by design),
    /// `store` is the shared embedding memory tile.
    pub fn start<F>(
        cfg: CoordinatorConfig,
        store: Arc<EmbeddingStore>,
        make_engine: F,
    ) -> crate::Result<Coordinator>
    where
        F: Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>
            + Send
            + Sync
            + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let mut txs = Vec::new();
        let mut rxs: Vec<Receiver<Request>> = Vec::new();
        for _ in 0..cfg.n_workers {
            let (tx, rx) = mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let router = Router::new(txs, cfg.policy);
        let make_engine = Arc::new(make_engine);
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel();
        for (i, rx) in rxs.into_iter().enumerate() {
            let store = store.clone();
            let metrics = metrics.clone();
            let bcfg = cfg.batcher;
            let depth = router.depth_handle(i);
            let make_engine = make_engine.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                match make_engine(i) {
                    Ok(engine) => {
                        let _ = ready.send(Ok(()));
                        worker_loop(rx, engine, store, metrics, bcfg, depth);
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                    }
                }
            }));
        }
        drop(ready_tx);
        for r in ready_rx.iter().take(cfg.n_workers) {
            r.map_err(|e| crate::err!("worker engine init failed: {e:#}"))?;
        }
        metrics.reset_clock(); // engine compile time is not serving time
        Ok(Coordinator {
            router,
            workers,
            metrics,
        })
    }

    /// Submit one request; the reply arrives on `reply`.
    pub fn submit(&self, req: Request) -> crate::Result<()> {
        self.metrics.on_request();
        self.router
            .route(req)
            .map(|_| ())
            .map_err(|_| crate::err!("all worker queues closed"))
    }

    /// Close intake and join workers (drains in-flight batches).
    pub fn shutdown(self) {
        drop(self.router);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Receiver<Request>,
    mut engine: Box<dyn InferenceEngine>,
    store: Arc<EmbeddingStore>,
    metrics: Arc<Metrics>,
    bcfg: BatcherConfig,
    depth: Arc<std::sync::atomic::AtomicUsize>,
) {
    let nd = engine.n_dense();
    let cap = engine.compiled_batch().min(bcfg.max_batch);
    let bcfg = BatcherConfig {
        max_batch: cap,
        ..bcfg
    };
    let mut dense = Vec::new();
    let mut sparse = Vec::new();
    while let Some(batch) = collect_batch(&rx, &bcfg) {
        depth.fetch_sub(batch.len().min(depth.load(Ordering::Relaxed)), Ordering::Relaxed);
        let t_exec = Instant::now();
        let queue_ns = batch
            .iter()
            .map(|r| r.enqueued.elapsed().as_nanos() as u64)
            .max()
            .unwrap_or(0);
        // assemble inputs: dense [B×nd], gather sparse [B×Ns×d]
        dense.clear();
        sparse.clear();
        for r in &batch {
            let mut row = r.dense.clone();
            row.resize(nd, 0.0);
            dense.extend_from_slice(&row);
            store.gather(&r.ids, 1, &mut sparse);
        }
        match engine.infer_batch(&dense, &sparse, batch.len()) {
            Ok(probs) => {
                let exec_ns = t_exec.elapsed().as_nanos() as u64;
                metrics.on_batch(batch.len(), queue_ns, exec_ns);
                for (r, p) in batch.into_iter().zip(probs) {
                    let e2e = r.enqueued.elapsed().as_nanos() as u64;
                    metrics.on_response(e2e);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        prob: p,
                        e2e_ns: e2e,
                    });
                }
            }
            Err(e) => {
                crate::error!("worker inference failed: {e:#}");
                // drop the batch; senders observe a closed reply channel
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::data::profile;

    fn store() -> Arc<EmbeddingStore> {
        Arc::new(EmbeddingStore::random(
            &profile("criteo").unwrap(),
            16,
            3,
        ))
    }

    fn start(workers: usize) -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                n_workers: workers,
                ..Default::default()
            },
            store(),
            |_| Ok(Box::new(MockEngine::new(32, 13, 26, 16))),
        )
        .unwrap()
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let c = start(2);
        let (tx, rx) = mpsc::channel();
        let n = 200;
        for id in 0..n {
            c.submit(Request {
                id,
                dense: vec![0.1; 13],
                ids: vec![1; 26],
                enqueued: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().take(n as usize).map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, n);
        assert!(snap.mean_batch >= 1.0);
        c.shutdown();
    }

    #[test]
    fn probabilities_are_valid() {
        let c = start(1);
        let (tx, rx) = mpsc::channel();
        c.submit(Request {
            id: 1,
            dense: vec![0.5; 13],
            ids: (0..26).collect(),
            enqueued: Instant::now(),
            reply: tx,
        })
        .unwrap();
        let resp = rx.recv().unwrap();
        assert!((0.0..=1.0).contains(&resp.prob));
        assert!(resp.e2e_ns > 0);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let c = start(3);
        let (tx, rx) = mpsc::channel();
        for id in 0..50 {
            c.submit(Request {
                id,
                dense: vec![0.0; 13],
                ids: vec![0; 26],
                enqueued: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
        }
        drop(tx);
        c.shutdown();
        assert_eq!(rx.iter().count(), 50);
    }

    /// Engine that fails every other batch — exercises the error path.
    struct FlakyEngine {
        inner: MockEngine,
        calls: usize,
    }

    impl crate::coordinator::engine::InferenceEngine for FlakyEngine {
        fn infer_batch(
            &mut self,
            dense: &[f32],
            sparse: &[f32],
            batch: usize,
        ) -> crate::Result<Vec<f32>> {
            self.calls += 1;
            if self.calls % 2 == 0 {
                crate::bail!("injected engine failure");
            }
            self.inner.infer_batch(dense, sparse, batch)
        }

        fn compiled_batch(&self) -> usize {
            self.inner.compiled_batch()
        }
        fn n_dense(&self) -> usize {
            self.inner.n_dense()
        }
        fn n_sparse(&self) -> usize {
            self.inner.n_sparse()
        }
        fn d_emb(&self) -> usize {
            self.inner.d_emb()
        }
    }

    #[test]
    fn failure_injection_drops_batches_but_never_wedges() {
        crate::util::logger::set_level(crate::util::logger::Level::Error);
        let c = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    max_batch: 1, // one request per batch → every 2nd fails
                    max_wait: Duration::from_micros(10),
                },
                ..Default::default()
            },
            store(),
            |_| {
                Ok(Box::new(FlakyEngine {
                    inner: MockEngine::new(1, 13, 26, 16),
                    calls: 0,
                }))
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 40;
        for id in 0..n {
            c.submit(Request {
                id,
                dense: vec![0.0; 13],
                ids: vec![0; 26],
                enqueued: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
        }
        drop(tx);
        let ok: Vec<_> = rx.iter().collect();
        // exactly the odd-numbered calls succeed; the failed batches are
        // dropped (senders see a closed reply), and the worker survives
        assert_eq!(ok.len() as u64, n / 2, "{} responses", ok.len());
        let snap = c.metrics.snapshot();
        assert_eq!(snap.requests, n);
        assert_eq!(snap.responses, n / 2);
        c.shutdown();
        crate::util::logger::set_level(crate::util::logger::Level::Info);
    }

    use crate::coordinator::batcher::BatcherConfig;
    use std::time::Duration;

    #[test]
    fn batching_engages_under_burst() {
        let c = start(1);
        let (tx, rx) = mpsc::channel();
        for id in 0..64 {
            c.submit(Request {
                id,
                dense: vec![0.0; 13],
                ids: vec![0; 26],
                enqueued: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
        }
        drop(tx);
        let _: Vec<_> = rx.iter().collect();
        let snap = c.metrics.snapshot();
        assert!(
            snap.mean_batch > 1.5,
            "burst should batch: mean {}",
            snap.mean_batch
        );
        c.shutdown();
    }
}
