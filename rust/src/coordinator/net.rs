//! Socket-native serving front end (S28): std-TCP, newline-delimited
//! JSON, zero dependencies.
//!
//! ```text
//!  client ──line──► reader thread ──Request──► coordinator queue ──► worker
//!                      │  (lazy parse, S27)                            │
//!  client ◄──line── reply pump ◄───────────── Response ◄──────────────┘
//! ```
//!
//! One accept loop fans connections out to a reader + reply-pump thread
//! pair. (Thread-per-connection rather than literal thread-per-core:
//! std has no readiness API, and the serving fleet here is a handful of
//! load-generator connections, not C10K — DESIGN.md §7.9 records the
//! deviation.) Framing is bounded by `max_frame`, reads are polled so
//! shutdown and idle eviction can never hang on a stalled peer, and
//! every malformed line is answered with a structured `{"error":…}` or
//! a clean close — never a panic: `rust/tests/wire_security.rs` pins
//! this byte-level contract.
//!
//! Conservation holds over sockets because the ledger lives below the
//! transport: `submit` books every admitted/rejected frame, workers
//! count a response *before* attempting the reply send, and a frame
//! that never parsed never becomes a request. A client disconnecting
//! mid-flight therefore costs nothing but a failed write on a closed
//! reply channel. Worker failure is equally invisible at this layer:
//! the router reroutes around a dead worker (DESIGN.md §7.11), its
//! queued requests are booked `failed` and their reply senders closed,
//! so the pump keeps draining and the connection stays up.

use super::server::{Admission, Coordinator, Request, Response};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::util::json::Json;
use crate::util::json_lazy::{self, ParsePath, WireRequest};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// max bytes in one request line (newline excluded); longer frames
    /// get a structured error and the connection is closed
    pub max_frame: usize,
    /// read-timeout granularity: how often a blocked reader rechecks
    /// the shutdown flag and the idle clock
    pub read_poll: Duration,
    /// a connection that carries no bytes for this long is evicted
    pub idle_timeout: Duration,
    /// connections beyond this are refused with an error line
    pub max_conns: usize,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            max_frame: 1 << 20,
            read_poll: Duration::from_millis(50),
            idle_timeout: Duration::from_secs(60),
            max_conns: 256,
        }
    }
}

/// Wire-level counters (the request/response ledger itself lives in
/// `Metrics`; these count frames and parse paths).
#[derive(Debug, Default)]
pub struct NetStats {
    pub conns_opened: AtomicU64,
    /// frames that parsed and reached `submit`
    pub frames_ok: AtomicU64,
    /// frames answered with a parse/shape error
    pub frames_bad: AtomicU64,
    /// frames decoded entirely by the lazy scanner
    pub lazy_frames: AtomicU64,
    /// frames that fell back to the tree parser
    pub tree_frames: AtomicU64,
    /// connections evicted by the idle clock (no bytes for
    /// `idle_timeout`) — distinct from client EOF and server stop
    pub conns_idle_closed: AtomicU64,
}

/// A running TCP front end over a [`Coordinator`].
pub struct NetServer {
    addr: SocketAddr,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    pub stats: Arc<NetStats>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting. Takes ownership of the coordinator; `shutdown`
    /// drains it.
    pub fn start(
        listen: &str,
        coord: Coordinator,
        cfg: NetServerConfig,
    ) -> crate::Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| crate::err!("binding {listen}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| crate::err!("local_addr: {e}"))?;
        let coord = Arc::new(coord);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let stats = Arc::new(NetStats::default());
        let n_open = Arc::new(AtomicUsize::new(0));

        let accept = {
            let coord = Arc::clone(&coord);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let stats = Arc::clone(&stats);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if n_open.load(Ordering::Relaxed) >= cfg.max_conns {
                        let mut s = stream;
                        let _ = s.write_all(
                            b"{\"error\":\"server at connection capacity\"}\n",
                        );
                        continue;
                    }
                    n_open.fetch_add(1, Ordering::Relaxed);
                    stats.conns_opened.fetch_add(1, Ordering::Relaxed);
                    let handle = {
                        let coord = Arc::clone(&coord);
                        let stop = Arc::clone(&stop);
                        let stats = Arc::clone(&stats);
                        let n_open = Arc::clone(&n_open);
                        let cfg = cfg.clone();
                        std::thread::spawn(move || {
                            handle_conn(stream, coord, stop, cfg, stats);
                            n_open.fetch_sub(1, Ordering::Relaxed);
                        })
                    };
                    let mut held = conns.lock().unwrap();
                    // reap finished handlers so the vec stays bounded
                    held.retain(|h| !h.is_finished());
                    held.push(handle);
                }
            })
        };

        Ok(NetServer {
            addr,
            coord,
            stop,
            accept: Some(accept),
            conns,
            stats,
        })
    }

    /// The bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the coordinator's serving ledger.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.coord.metrics.snapshot()
    }

    /// Stop accepting, close connections, then drain the coordinator.
    /// In-flight requests of still-open connections are answered before
    /// their reply pumps exit (workers stay live until the final
    /// coordinator shutdown below).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // every handler (the only other Arc holders) has exited
        if let Ok(coord) = Arc::try_unwrap(self.coord) {
            coord.shutdown();
        }
    }
}

// ---------------------------------------------------------------------------
// Connection handler
// ---------------------------------------------------------------------------

enum Frame {
    Line,
    Eof,
    TooLong,
    Stop,
    /// evicted by the idle clock (counted in `NetStats.conns_idle_closed`)
    Idle,
}

/// Accumulate one `\n`-terminated line into `buf` (newline excluded),
/// polling the stop flag and the idle clock on every read timeout.
/// On overflow the rest of the line is consumed but discarded.
fn read_frame(
    r: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    max_frame: usize,
    stop: &AtomicBool,
    idle: Duration,
) -> Frame {
    let mut last_data = Instant::now();
    let mut overflowed = false;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Frame::Stop;
        }
        let (advance, done) = {
            let avail = match r.fill_buf() {
                Ok([]) => return Frame::Eof,
                Ok(a) => a,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    if last_data.elapsed() > idle {
                        return Frame::Idle;
                    }
                    continue;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Frame::Eof,
            };
            match avail.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if buf.len() + pos > max_frame {
                        overflowed = true;
                    }
                    if !overflowed {
                        buf.extend_from_slice(&avail[..pos]);
                    }
                    (pos + 1, true)
                }
                None => {
                    if buf.len() + avail.len() > max_frame {
                        overflowed = true;
                    }
                    if !overflowed {
                        buf.extend_from_slice(avail);
                    }
                    (avail.len(), false)
                }
            }
        };
        r.consume(advance);
        last_data = Instant::now();
        if done {
            return if overflowed { Frame::TooLong } else { Frame::Line };
        }
    }
}

type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn send_error(out: &SharedWriter, id: Option<u64>, msg: &str) {
    let mut j = Json::obj();
    if let Some(id) = id {
        j.set("id", Json::Num(id as f64));
    }
    j.set("error", Json::Str(msg.to_string()));
    let mut line = j.to_string_compact();
    line.push('\n');
    if let Ok(mut w) = out.lock() {
        let _ = w.write_all(line.as_bytes()).and_then(|_| w.flush());
    }
}

fn response_line(r: &Response) -> String {
    let mut s = String::with_capacity(48);
    s.push_str("{\"id\":");
    s.push_str(&r.id.to_string());
    // a structured error (deadline miss) replaces the probability — the
    // client keyed on `"error"` treats it like any other error line,
    // but with the request id attached and the latency still measured
    if let Some(err) = r.err {
        s.push_str(",\"error\":\"");
        s.push_str(err);
        s.push('"');
    } else {
        s.push_str(",\"prob\":");
        json_lazy::write_f32(&mut s, r.prob);
    }
    s.push_str(",\"e2e_us\":");
    s.push_str(&(r.e2e_ns / 1000).to_string());
    s.push_str("}\n");
    s
}

fn handle_conn(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    cfg: NetServerConfig,
    stats: Arc<NetStats>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_poll));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let out: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));
    let (tx, rx) = mpsc::channel::<Response>();

    // The reply pump is the ONLY writer of success lines; the reader
    // thread writes error lines through the same mutex, so lines never
    // interleave mid-frame.
    let pump = {
        let out = Arc::clone(&out);
        std::thread::spawn(move || {
            for resp in rx {
                let line = response_line(&resp);
                let mut w = out.lock().unwrap();
                if w.write_all(line.as_bytes()).and_then(|_| w.flush()).is_err() {
                    // client gone: stop writing; remaining worker reply
                    // sends fall on the dropped receiver harmlessly
                    break;
                }
            }
        })
    };

    let mut r = BufReader::with_capacity(64 * 1024, reader_stream);
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        buf.clear();
        match read_frame(&mut r, &mut buf, cfg.max_frame, &stop, cfg.idle_timeout) {
            Frame::Eof | Frame::Stop => break,
            Frame::Idle => {
                stats.conns_idle_closed.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Frame::TooLong => {
                send_error(&out, None, "frame exceeds size limit");
                break;
            }
            Frame::Line => {}
        }
        let line: &[u8] = if buf.last() == Some(&b'\r') {
            &buf[..buf.len() - 1]
        } else {
            &buf
        };
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            send_error(&out, None, "empty frame");
            continue;
        }
        let (parsed, path) = json_lazy::parse_request_traced(line);
        match path {
            ParsePath::Lazy => {
                stats.lazy_frames.fetch_add(1, Ordering::Relaxed);
            }
            ParsePath::Tree => {
                stats.tree_frames.fetch_add(1, Ordering::Relaxed);
            }
        }
        let w: WireRequest = match parsed {
            Ok(w) => w,
            Err(e) => {
                stats.frames_bad.fetch_add(1, Ordering::Relaxed);
                send_error(&out, None, &e.to_string());
                continue;
            }
        };
        stats.frames_ok.fetch_add(1, Ordering::Relaxed);
        let id = w.id;
        // deadline propagation (S33): the wire budget rides the request
        // into admission, dequeue, and the reply — absent field ⇒ None
        // ⇒ every deadline check is skipped (bit-identical default)
        let deadline = w.deadline_us.map(Duration::from_micros);
        let req = Request::partial(w.id, w.dense, w.tables, w.ids, tx.clone())
            .with_deadline(deadline);
        match coord.submit(req) {
            Ok(Admission::Enqueued(_)) => {}
            Ok(Admission::Rejected) => send_error(&out, Some(id), "rejected"),
            // refused at admission: no worker can meet the budget — the
            // client hears the same structured error an in-queue expiry
            // produces, just earlier and cheaper
            Ok(Admission::DeadlineInfeasible) => {
                send_error(&out, Some(id), "deadline_exceeded")
            }
            // `submit` errs only when NO live worker remains (shutdown
            // or total fleet loss) — a single worker crash is rerouted
            // inside the coordinator and never surfaces here.
            Err(_) => {
                send_error(&out, Some(id), "no live worker");
                break;
            }
        }
    }
    // Drop our sender so the pump exits once every in-flight request
    // (each holding a clone) has been answered or dropped by a worker —
    // this IS the per-connection drain.
    drop(tx);
    let _ = pump.join();
    if let Ok(w) = out.lock() {
        let _ = w.get_ref().shutdown(Shutdown::Both);
    }
}

// ---------------------------------------------------------------------------
// Client side
// ---------------------------------------------------------------------------

/// One decoded response line.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    Ok { id: u64, prob: f32, e2e_us: u64 },
    Error { id: Option<u64>, msg: String },
}

/// Decode a response line (tree parse: the client is not the measured
/// system and response objects are three fields).
pub fn parse_response_line(line: &str) -> crate::Result<WireResponse> {
    let j = Json::parse(line.trim_end())
        .map_err(|e| crate::err!("bad response JSON: {e}"))?;
    if let Some(msg) = j.get("error").and_then(Json::as_str) {
        let id = j.get("id").and_then(Json::as_f64).map(|x| x as u64);
        return Ok(WireResponse::Error {
            id,
            msg: msg.to_string(),
        });
    }
    Ok(WireResponse::Ok {
        id: j.req_f64("id")? as u64,
        prob: j.req_f64("prob")? as f32,
        e2e_us: j.req_f64("e2e_us")? as u64,
    })
}

/// Blocking client over one connection.
pub struct NetClient {
    stream: TcpStream,
    r: BufReader<TcpStream>,
}

impl NetClient {
    pub fn connect(addr: &SocketAddr) -> crate::Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| crate::err!("connecting {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let read_half = stream
            .try_clone()
            .map_err(|e| crate::err!("cloning stream: {e}"))?;
        Ok(NetClient {
            stream,
            r: BufReader::new(read_half),
        })
    }

    /// Split into independently-owned send/receive halves (for the
    /// loadgen's sender/receiver thread pair).
    pub fn split(self) -> (NetClientTx, NetClientRx) {
        (
            NetClientTx {
                stream: self.stream,
            },
            NetClientRx { r: self.r },
        )
    }

    /// Convenience: send one request and block for one line.
    pub fn request(&mut self, req: &WireRequest) -> crate::Result<WireResponse> {
        self.send_line(&req.to_line())?;
        let mut line = String::new();
        let n = self.r.read_line(&mut line)?;
        crate::ensure!(n > 0, "server closed the connection");
        parse_response_line(&line)
    }

    pub fn send_line(&mut self, line: &str) -> crate::Result<()> {
        self.stream
            .write_all(line.as_bytes())
            .map_err(|e| crate::err!("send: {e}"))
    }

    /// Next response line; `None` on clean EOF.
    pub fn recv(&mut self) -> crate::Result<Option<WireResponse>> {
        let mut line = String::new();
        let n = self
            .r
            .read_line(&mut line)
            .map_err(|e| crate::err!("recv: {e}"))?;
        if n == 0 {
            return Ok(None);
        }
        parse_response_line(&line).map(Some)
    }
}

/// Send half of a split [`NetClient`].
pub struct NetClientTx {
    stream: TcpStream,
}

impl NetClientTx {
    pub fn send(&mut self, req: &WireRequest) -> crate::Result<()> {
        self.send_line(&req.to_line())
    }

    pub fn send_line(&mut self, line: &str) -> crate::Result<()> {
        self.stream
            .write_all(line.as_bytes())
            .map_err(|e| crate::err!("send: {e}"))
    }

    /// Half-close: tells the server no more requests are coming, so its
    /// reader sees EOF and the connection drains naturally.
    pub fn finish(&self) {
        let _ = self.stream.shutdown(Shutdown::Write);
    }
}

/// Receive half of a split [`NetClient`].
pub struct NetClientRx {
    r: BufReader<TcpStream>,
}

impl NetClientRx {
    /// Next response line; `None` on clean EOF.
    pub fn recv(&mut self) -> crate::Result<Option<WireResponse>> {
        let mut line = String::new();
        let n = self
            .r
            .read_line(&mut line)
            .map_err(|e| crate::err!("recv: {e}"))?;
        if n == 0 {
            return Ok(None);
        }
        parse_response_line(&line).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::profile;
    use crate::embeddings::EmbeddingStore;

    fn server() -> NetServer {
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_workers: 2,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            |_| Ok(Box::new(MockEngine::new(16, 3, 10, 8))),
        )
        .unwrap();
        NetServer::start("127.0.0.1:0", coord, NetServerConfig::default()).unwrap()
    }

    fn valid_request(id: u64) -> WireRequest {
        WireRequest {
            id,
            dense: vec![0.25; 3],
            tables: (0..10).collect(),
            ids: vec![1; 10],
            deadline_us: None,
        }
    }

    #[test]
    fn round_trip_over_loopback() {
        let srv = server();
        let mut c = NetClient::connect(&srv.local_addr()).unwrap();
        match c.request(&valid_request(42)).unwrap() {
            WireResponse::Ok { id, prob, .. } => {
                assert_eq!(id, 42);
                assert!((0.0..=1.0).contains(&prob));
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(srv.stats.frames_ok.load(Ordering::Relaxed), 1);
        assert_eq!(srv.stats.lazy_frames.load(Ordering::Relaxed), 1);
        let snap = srv.metrics();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.responses, 1);
        srv.shutdown();
    }

    #[test]
    fn malformed_line_gets_structured_error() {
        let srv = server();
        let mut c = NetClient::connect(&srv.local_addr()).unwrap();
        c.send_line("{not json}\n").unwrap();
        match c.recv().unwrap().unwrap() {
            WireResponse::Error { id, msg } => {
                assert_eq!(id, None);
                assert!(!msg.is_empty());
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(srv.stats.frames_bad.load(Ordering::Relaxed), 1);
        // the ledger never saw it
        assert_eq!(srv.metrics().requests, 0);
        srv.shutdown();
    }

    #[test]
    fn shutdown_with_idle_connection_does_not_hang() {
        let srv = server();
        let _idle = NetClient::connect(&srv.local_addr()).unwrap();
        let t0 = Instant::now();
        srv.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn response_line_is_parseable_and_compact() {
        let line = response_line(&Response {
            id: 9,
            prob: 0.625,
            e2e_ns: 12_345,
            err: None,
        });
        assert_eq!(line, "{\"id\":9,\"prob\":0.625,\"e2e_us\":12}\n");
        match parse_response_line(&line).unwrap() {
            WireResponse::Ok { id, prob, e2e_us } => {
                assert_eq!((id, e2e_us), (9, 12));
                assert_eq!(prob.to_bits(), 0.625f32.to_bits());
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn expired_response_line_is_a_structured_error() {
        let line = response_line(&Response::expired(7, 42_000));
        assert_eq!(
            line,
            "{\"id\":7,\"error\":\"deadline_exceeded\",\"e2e_us\":42}\n"
        );
        match parse_response_line(&line).unwrap() {
            WireResponse::Error { id, msg } => {
                assert_eq!(id, Some(7));
                assert_eq!(msg, "deadline_exceeded");
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn idle_connection_is_evicted_and_counted() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_workers: 1,
                ..Default::default()
            },
            Arc::new(EmbeddingStore::random(&profile("kdd").unwrap(), 8, 3)),
            |_| Ok(Box::new(MockEngine::new(16, 3, 10, 8))),
        )
        .unwrap();
        let srv = NetServer::start(
            "127.0.0.1:0",
            coord,
            NetServerConfig {
                idle_timeout: Duration::from_millis(80),
                read_poll: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = NetClient::connect(&srv.local_addr()).unwrap();
        // say nothing: the idle clock — not EOF, not shutdown — must
        // evict this connection and book it in conns_idle_closed
        let deadline = Instant::now() + Duration::from_secs(5);
        while srv.stats.conns_idle_closed.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "idle eviction never fired");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(srv.stats.conns_idle_closed.load(Ordering::Relaxed), 1);
        // the server closed the socket: the client reads EOF
        assert!(matches!(c.recv(), Ok(None) | Err(_)));
        srv.shutdown();
    }
}
