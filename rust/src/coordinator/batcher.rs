//! Dynamic batcher: collect requests until `max_batch` or `max_wait`,
//! whichever first — the classic latency/throughput knob of serving
//! systems. FIFO within a worker queue.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            // §Perf: immediate dispatch by default — batches form from
            // backlog while the engine is busy (vLLM-style continuous
            // batching), so an idle system pays zero batching latency.
            // Set max_wait > 0 to trade latency for fuller batches under
            // moderate open-loop load.
            max_wait: Duration::ZERO,
        }
    }
}

/// Block for the first request, greedily drain whatever is already
/// queued, and only then (optionally) wait out `max_wait` for stragglers.
/// Returns None when the channel closed and is empty (shutdown).
pub fn collect_batch<T>(rx: &Receiver<T>, cfg: &BatcherConfig) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    // free items: whatever the backlog already holds
    while batch.len() < cfg.max_batch {
        match rx.try_recv() {
            Ok(item) => batch.push(item),
            Err(_) => break,
        }
    }
    if cfg.max_wait.is_zero() || batch.len() >= cfg.max_batch {
        return Some(batch);
    }
    let deadline = Instant::now() + cfg.max_wait;
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let cfg = BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        let b = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn respects_deadline_with_sparse_arrivals() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let cfg = BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        let b = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn returns_none_on_closed_empty_channel() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(collect_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn drains_remaining_after_disconnect() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let cfg = BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let b = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(b, vec![7, 8]);
        assert!(collect_batch(&rx, &cfg).is_none());
    }

    #[test]
    fn property_never_exceeds_max_batch_and_preserves_order() {
        use crate::util::qcheck::qcheck;
        qcheck(50, |g| {
            let n = g.usize(1, 100);
            let max_batch = g.usize(1, 16);
            let (tx, rx) = mpsc::channel();
            for i in 0..n {
                tx.send(i).unwrap();
            }
            drop(tx);
            let cfg = BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(1),
            };
            let mut seen = Vec::new();
            while let Some(b) = collect_batch(&rx, &cfg) {
                crate::prop_assert!(b.len() <= max_batch, "batch too big");
                seen.extend(b);
            }
            crate::prop_assert_eq!(seen, (0..n).collect::<Vec<_>>());
            Ok(())
        });
    }
}
