//! Request router: spread incoming requests across worker queues.
//!
//! Policies: round-robin (default; uniform work), least-queued
//! (counter-based, for heterogeneous workers) and shard-affinity
//! (score each worker by the fraction of the request's table ids its
//! shard owns locally, falling back to least-queued on ties — keeps
//! embedding gathers next to the memory tiles that hold the tables).
//! Conservation — every accepted request lands on exactly one queue —
//! is property-tested, and queues are bounded: `route_bounded` rejects
//! a request when the chosen queue is at capacity (admission control).

use crate::embeddings::ShardMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastQueued,
    /// prefer the worker owning most of the request's tables; ties go
    /// to the shallowest queue (needs a `ShardMap`, else ≡ LeastQueued)
    ShardAffinity,
}

impl Policy {
    /// Parse a CLI spelling ("round-robin" | "least-queued" | "shard-affinity").
    pub fn parse(s: &str) -> crate::Result<Policy> {
        Ok(match s {
            "round-robin" | "rr" => Policy::RoundRobin,
            "least-queued" | "lq" => Policy::LeastQueued,
            "shard-affinity" | "affinity" => Policy::ShardAffinity,
            other => crate::bail!(
                "unknown policy `{other}` (round-robin|least-queued|shard-affinity)"
            ),
        })
    }
}

/// Why a request was not enqueued.
pub enum RouteRejection<T> {
    /// every worker queue is closed (shutdown) — request returned
    Closed(T),
    /// the chosen queue is at capacity — request returned (admission
    /// control; the caller decides whether to count it as rejected)
    Overloaded(T),
}

pub struct Router<T> {
    queues: Vec<Sender<T>>,
    depths: Vec<Arc<AtomicUsize>>,
    policy: Policy,
    next: AtomicUsize,
    /// table→shard ownership (ShardAffinity scoring); worker `i` serves
    /// shard `i % map.n_shards`
    shard_map: Option<Arc<ShardMap>>,
}

impl<T> Router<T> {
    pub fn new(queues: Vec<Sender<T>>, policy: Policy) -> Router<T> {
        let depths = (0..queues.len())
            .map(|_| Arc::new(AtomicUsize::new(0)))
            .collect();
        Router {
            queues,
            depths,
            policy,
            next: AtomicUsize::new(0),
            shard_map: None,
        }
    }

    /// Attach the shard map ShardAffinity scores against.
    pub fn with_shards(mut self, map: Arc<ShardMap>) -> Router<T> {
        self.shard_map = Some(map);
        self
    }

    pub fn n_workers(&self) -> usize {
        self.queues.len()
    }

    /// Depth handle for worker `i` — the worker decrements it when it
    /// takes a request off its queue.
    pub fn depth_handle(&self, i: usize) -> Arc<AtomicUsize> {
        self.depths[i].clone()
    }

    /// Current queue depth of worker `i`.
    pub fn depth(&self, i: usize) -> usize {
        self.depths[i].load(Ordering::Relaxed)
    }

    /// Pick a worker for a request touching `fields` (table ids; empty
    /// = unknown/all, which makes ShardAffinity a pure depth choice).
    fn pick(&self, fields: &[u32]) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.queues.len()
            }
            Policy::LeastQueued => self.least_queued(),
            Policy::ShardAffinity => match &self.shard_map {
                None => self.least_queued(),
                Some(map) => {
                    let mut best = 0usize;
                    let mut best_frac = -1.0f64;
                    let mut best_depth = usize::MAX;
                    for w in 0..self.queues.len() {
                        let frac =
                            map.local_fraction(w % map.n_shards, fields);
                        let depth = self.depths[w].load(Ordering::Relaxed);
                        // higher locality wins; exact ties go to the
                        // shallower queue, then the lower worker id
                        if frac > best_frac + 1e-12
                            || ((frac - best_frac).abs() <= 1e-12
                                && depth < best_depth)
                        {
                            best = w;
                            best_frac = frac;
                            best_depth = depth;
                        }
                    }
                    best
                }
            },
        }
    }

    fn least_queued(&self) -> usize {
        self.depths
            .iter()
            .enumerate()
            .min_by_key(|(_, d)| d.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Route one request; returns the chosen worker or Err(req) if every
    /// queue is closed.
    pub fn route(&self, req: T) -> Result<usize, T> {
        match self.route_bounded(&[], usize::MAX, req) {
            Ok(w) => Ok(w),
            Err(RouteRejection::Closed(r)) | Err(RouteRejection::Overloaded(r)) => {
                Err(r)
            }
        }
    }

    /// Route a request touching `fields`, with a per-worker queue bound:
    /// if the chosen worker's queue already holds `cap` requests the
    /// request is rejected (returned in `Overloaded`).
    pub fn route_bounded(
        &self,
        fields: &[u32],
        cap: usize,
        req: T,
    ) -> Result<usize, RouteRejection<T>> {
        let w = self.pick(fields);
        self.dispatch(w, cap, req)
    }

    /// Like [`Router::route_bounded`] but reads the field list out of
    /// the request itself, so callers holding an owned request don't
    /// have to clone the slice to satisfy the borrow checker.
    pub fn route_bounded_by<F>(
        &self,
        cap: usize,
        req: T,
        fields_of: F,
    ) -> Result<usize, RouteRejection<T>>
    where
        F: FnOnce(&T) -> &[u32],
    {
        let w = self.pick(fields_of(&req));
        self.dispatch(w, cap, req)
    }

    /// Enqueue on worker `w` iff a slot is free. The slot is reserved
    /// with an atomic increment BEFORE the send (rolled back on
    /// rejection/closure), so `cap` is a hard bound even with many
    /// concurrent submitters — a check-then-send would let N racing
    /// producers each observe `cap - 1` and all enqueue.
    fn dispatch(&self, w: usize, cap: usize, req: T) -> Result<usize, RouteRejection<T>> {
        if self.depths[w].fetch_add(1, Ordering::Relaxed) >= cap {
            self.depths[w].fetch_sub(1, Ordering::Relaxed);
            return Err(RouteRejection::Overloaded(req));
        }
        match self.queues[w].send(req) {
            Ok(()) => Ok(w),
            Err(e) => {
                self.depths[w].fetch_sub(1, Ordering::Relaxed);
                Err(RouteRejection::Closed(e.0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embeddings::ShardPolicy;
    use std::sync::mpsc;

    #[test]
    fn round_robin_spreads_evenly() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..4).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::RoundRobin);
        for i in 0..20 {
            r.route(i).unwrap();
        }
        for rx in &rxs {
            assert_eq!(rx.try_iter().count(), 5);
        }
    }

    #[test]
    fn least_queued_prefers_empty_worker() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..2).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::LeastQueued);
        // fill worker queues unevenly by routing, then drain worker 1
        for i in 0..6 {
            r.route(i).unwrap();
        }
        // drain worker 1's queue and decrement its depth handle
        let d1 = r.depth_handle(1);
        while rxs[1].try_recv().is_ok() {
            d1.fetch_sub(1, Ordering::Relaxed);
        }
        let w = r.route(99).unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn property_conservation() {
        use crate::util::qcheck::qcheck;
        qcheck(30, |g| {
            let workers = g.usize(1, 6);
            let n = g.usize(0, 80);
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..workers).map(|_| mpsc::channel()).unzip();
            let r = Router::new(txs, Policy::RoundRobin);
            for i in 0..n {
                crate::prop_assert!(r.route(i).is_ok());
            }
            let total: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();
            crate::prop_assert_eq!(total, n);
            Ok(())
        });
    }

    #[test]
    fn closed_queues_return_request() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(rx);
        let r = Router::new(vec![tx], Policy::RoundRobin);
        assert_eq!(r.route(5).unwrap_err(), 5);
    }

    #[test]
    fn bounded_route_rejects_at_capacity() {
        let (tx, rx) = mpsc::channel::<u32>();
        let r = Router::new(vec![tx], Policy::RoundRobin);
        assert!(r.route_bounded(&[], 2, 1).is_ok());
        assert!(r.route_bounded(&[], 2, 2).is_ok());
        match r.route_bounded(&[], 2, 3) {
            Err(RouteRejection::Overloaded(req)) => assert_eq!(req, 3),
            _ => panic!("expected Overloaded"),
        }
        assert_eq!(rx.try_iter().count(), 2);
    }

    #[test]
    fn shard_affinity_prefers_local_owner() {
        // 4 tables on 2 shards round-robin: shard 0 owns {0,2}, 1 owns {1,3}
        let map = Arc::new(ShardMap::build(
            &[10, 10, 10, 10],
            1.2,
            2,
            ShardPolicy::RoundRobinTables,
        ));
        let (txs, _rxs): (Vec<_>, Vec<_>) =
            (0..2).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::ShardAffinity).with_shards(map);
        assert_eq!(r.route_bounded(&[0, 2], usize::MAX, 1u32).unwrap(), 0);
        assert_eq!(r.route_bounded(&[1, 3], usize::MAX, 2u32).unwrap(), 1);
        // mixed request: tie (0.5 each) → least-queued → worker 0 has
        // depth 1, worker 1 has depth 1 → lower id after depth tie…
        // drain nothing; both depth 1 → worker 0
        assert_eq!(r.route_bounded(&[0, 1], usize::MAX, 3u32).unwrap(), 0);
    }

    #[test]
    fn shard_affinity_without_map_is_least_queued() {
        let (txs, _rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::ShardAffinity);
        let w = r.route_bounded(&[1, 2], usize::MAX, 7u32).unwrap();
        assert_eq!(w, 0); // all empty → first worker
    }
}
