//! Request router: spread incoming requests across worker queues.
//!
//! Policies: round-robin (default; uniform work), least-queued
//! (counter-based, for heterogeneous workers) and shard-affinity
//! (score each worker by the fraction of the request's table ids its
//! shard owns locally, falling back to least-queued on ties — keeps
//! embedding gathers next to the memory tiles that hold the tables).
//! Conservation — every accepted request lands on exactly one queue —
//! is property-tested, and queues are bounded: `route_bounded` rejects
//! a request when the chosen queue is at capacity (admission control).
//!
//! Failure model (S31): each worker's sender lives in a [`WorkerSlot`]
//! shared with that worker's lifecycle guard. A send that finds the
//! queue closed marks the worker dead and the route loop re-picks among
//! the remaining live workers — a single dead worker never bubbles a
//! false "all queues closed" error out of `Coordinator::submit`, and
//! every picking policy skips non-alive workers (a dead worker's frozen
//! depth gauge would otherwise make it look attractively idle forever).

use super::tail::{BreakerState, FleetHealth};
use crate::embeddings::ShardMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastQueued,
    /// prefer the worker owning most of the request's tables; ties go
    /// to the shallowest queue (needs a `ShardMap`, else ≡ LeastQueued)
    ShardAffinity,
}

impl Policy {
    /// Parse a CLI spelling ("round-robin" | "least-queued" | "shard-affinity").
    pub fn parse(s: &str) -> crate::Result<Policy> {
        Ok(match s {
            "round-robin" | "rr" => Policy::RoundRobin,
            "least-queued" | "lq" => Policy::LeastQueued,
            "shard-affinity" | "affinity" => Policy::ShardAffinity,
            other => crate::bail!(
                "unknown policy `{other}` (round-robin|least-queued|shard-affinity)"
            ),
        })
    }
}

/// Why a request was not enqueued.
pub enum RouteRejection<T> {
    /// no live worker remains (all dead or shut down) — request returned
    Closed(T),
    /// the chosen queue is at capacity — request returned (admission
    /// control; the caller decides whether to count it as rejected)
    Overloaded(T),
}

/// One worker's routing endpoint: its queue sender, depth gauge, and
/// liveness flag, shared between the router (which sends) and the
/// worker's lifecycle guard (which closes on death or shutdown).
///
/// The sender lives behind a mutex and every send happens UNDER that
/// lock; [`WorkerSlot::close`] takes the sender under the same lock.
/// Because the slot holds the ONLY sender for the queue, after `close`
/// returns no request can ever land on it again — the dying worker's
/// drain of its receiver is therefore complete and deterministic, with
/// no check-then-send window for a racing submitter to lose a request
/// into (the ledger-conservation property under crashes hinges on this).
pub struct WorkerSlot<T> {
    tx: Mutex<Option<Sender<T>>>,
    depth: Arc<AtomicUsize>,
    alive: Arc<AtomicBool>,
}

impl<T> WorkerSlot<T> {
    fn new(tx: Sender<T>) -> WorkerSlot<T> {
        WorkerSlot {
            tx: Mutex::new(Some(tx)),
            depth: Arc::new(AtomicUsize::new(0)),
            alive: Arc::new(AtomicBool::new(true)),
        }
    }

    /// Whether this worker still accepts requests.
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Mark the worker dead and close its queue (idempotent). The alive
    /// flag flips first so pickers stop choosing this worker, then the
    /// sender is taken under the send lock — the barrier after which the
    /// queue's contents are final.
    pub fn close(&self) {
        self.alive.store(false, Ordering::Release);
        drop(self.tx.lock().unwrap().take());
    }

    /// The queue-depth gauge (the worker decrements it at dequeue).
    pub fn depth_handle(&self) -> Arc<AtomicUsize> {
        self.depth.clone()
    }

    /// The liveness flag, for metrics registration.
    pub fn alive_handle(&self) -> Arc<AtomicBool> {
        self.alive.clone()
    }
}

pub struct Router<T> {
    slots: Vec<Arc<WorkerSlot<T>>>,
    policy: Policy,
    next: AtomicUsize,
    /// table→shard ownership (ShardAffinity scoring); worker `i` serves
    /// shard `i % map.n_shards`
    shard_map: Option<Arc<ShardMap>>,
    /// breaker states + probe tickets (S33). `None` — the default —
    /// keeps every pick bit-identical to the health-blind router.
    health: Option<Arc<FleetHealth>>,
}

impl<T> Router<T> {
    pub fn new(queues: Vec<Sender<T>>, policy: Policy) -> Router<T> {
        let slots = queues
            .into_iter()
            .map(|tx| Arc::new(WorkerSlot::new(tx)))
            .collect();
        Router {
            slots,
            policy,
            next: AtomicUsize::new(0),
            shard_map: None,
            health: None,
        }
    }

    /// Attach the shard map ShardAffinity scores against.
    pub fn with_shards(mut self, map: Arc<ShardMap>) -> Router<T> {
        self.shard_map = Some(map);
        self
    }

    /// Attach fleet-health breakers (S33): `LeastQueued` and
    /// `ShardAffinity` then rank probation workers after healthy ones
    /// and route nothing to a quarantined worker except trickle probes.
    pub fn with_health(mut self, health: Arc<FleetHealth>) -> Router<T> {
        self.health = Some(health);
        self
    }

    pub fn n_workers(&self) -> usize {
        self.slots.len()
    }

    /// Workers still accepting requests.
    pub fn n_alive(&self) -> usize {
        self.slots.iter().filter(|s| s.is_alive()).count()
    }

    /// Worker `i`'s slot — the coordinator hands a clone to that
    /// worker's lifecycle guard so death closes the queue atomically.
    pub fn slot_handle(&self, i: usize) -> Arc<WorkerSlot<T>> {
        self.slots[i].clone()
    }

    /// Depth handle for worker `i` — the worker decrements it when it
    /// takes a request off its queue.
    pub fn depth_handle(&self, i: usize) -> Arc<AtomicUsize> {
        self.slots[i].depth_handle()
    }

    /// Current queue depth of worker `i`.
    pub fn depth(&self, i: usize) -> usize {
        self.slots[i].depth.load(Ordering::Relaxed)
    }

    /// Close every slot (coordinator shutdown / init-failure unwind).
    /// Since slots are shared with worker guards, dropping the router
    /// alone no longer closes any queue — shutdown MUST call this or
    /// the workers never see end-of-stream.
    pub fn close_all(&self) {
        for s in &self.slots {
            s.close();
        }
    }

    /// Worker `w`'s breaker rank: 0 healthy, 1 probation, 2
    /// quarantined. Always 0 without attached health, so the health-
    /// blind orderings below collapse to the original depth-only ones.
    fn rank(&self, w: usize) -> u8 {
        self.health.as_ref().map_or(0, |h| h.rank(w))
    }

    /// Trickle probe (S33): while a quarantined-but-alive worker
    /// exists, every `probe_interval`-th pick is diverted to one
    /// (rotating) so it sees just enough traffic to prove recovery —
    /// `FleetHealth::record` promotes it to probation on the first
    /// fast sample.
    fn probe_pick(&self, h: &FleetHealth) -> Option<usize> {
        let quarantined = || {
            (0..self.slots.len()).filter(|&w| {
                self.slots[w].is_alive()
                    && h.state(w) == BreakerState::Quarantined
            })
        };
        let n = quarantined().count();
        if n == 0 {
            return None;
        }
        let t = h.probe_ticket();
        let every = h.probe_interval();
        if t % every == 0 {
            quarantined().nth(((t / every) % n as u64) as usize)
        } else {
            None
        }
    }

    /// Pick a live worker for a request touching `fields` (table ids;
    /// empty = unknown/all, which makes ShardAffinity a pure depth
    /// choice). `None` when no live worker remains. With health
    /// attached, quarantined workers get no normal traffic (probes
    /// only) and probation workers rank after healthy ones — unless
    /// every live worker is quarantined, in which case traffic flows
    /// anyway (degraded beats dead).
    fn pick(&self, fields: &[u32]) -> Option<usize> {
        if let Some(h) = &self.health {
            if let Some(w) = self.probe_pick(h) {
                return Some(w);
            }
        }
        match self.policy {
            Policy::RoundRobin => {
                let n = self.slots.len();
                let start = self.next.fetch_add(1, Ordering::Relaxed);
                (0..n)
                    .map(|i| (start + i) % n)
                    .find(|&w| self.slots[w].is_alive())
            }
            Policy::LeastQueued => self.least_queued(),
            Policy::ShardAffinity => match &self.shard_map {
                None => self.least_queued(),
                Some(map) => {
                    let mut best = None;
                    let mut best_rank = u8::MAX;
                    let mut best_frac = -1.0f64;
                    let mut best_depth = usize::MAX;
                    for w in 0..self.slots.len() {
                        if !self.slots[w].is_alive() || self.rank(w) >= 2 {
                            continue;
                        }
                        let rank = self.rank(w);
                        let frac =
                            map.local_fraction(w % map.n_shards, fields);
                        let depth = self.slots[w].depth.load(Ordering::Relaxed);
                        // breaker rank dominates, then higher locality;
                        // exact ties go to the shallower queue, then
                        // the lower worker id
                        if rank < best_rank
                            || (rank == best_rank
                                && (frac > best_frac + 1e-12
                                    || ((frac - best_frac).abs() <= 1e-12
                                        && depth < best_depth)))
                        {
                            best = Some(w);
                            best_rank = rank;
                            best_frac = frac;
                            best_depth = depth;
                        }
                    }
                    best.or_else(|| self.any_alive())
                }
            },
        }
    }

    fn least_queued(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, s)| s.is_alive() && self.rank(*i) < 2)
            .min_by_key(|(i, s)| {
                (self.rank(*i), s.depth.load(Ordering::Relaxed), *i)
            })
            .map(|(i, _)| i)
            .or_else(|| self.any_alive())
    }

    /// Rank-blind fallback: the shallowest live queue, quarantined or
    /// not. Reached only when every live worker is quarantined.
    fn any_alive(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_alive())
            .min_by_key(|(i, s)| (s.depth.load(Ordering::Relaxed), *i))
            .map(|(i, _)| i)
    }

    /// Route one request; returns the chosen worker or Err(req) if no
    /// live worker remains.
    pub fn route(&self, req: T) -> Result<usize, T> {
        match self.route_bounded(&[], usize::MAX, req) {
            Ok(w) => Ok(w),
            Err(RouteRejection::Closed(r)) | Err(RouteRejection::Overloaded(r)) => {
                Err(r)
            }
        }
    }

    /// Route a request touching `fields`, with a per-worker queue bound:
    /// if the chosen worker's queue already holds `cap` requests the
    /// request is rejected (returned in `Overloaded`). A closed queue is
    /// NOT a rejection: the worker is marked dead and the request
    /// re-picks among the survivors, erroring only when none remain.
    pub fn route_bounded(
        &self,
        fields: &[u32],
        cap: usize,
        mut req: T,
    ) -> Result<usize, RouteRejection<T>> {
        loop {
            let Some(w) = self.pick(fields) else {
                return Err(RouteRejection::Closed(req));
            };
            match self.dispatch(w, cap, req) {
                Err(RouteRejection::Closed(r)) => {
                    // the picked worker died between the alive check and
                    // the send — mark it and retry with the survivors
                    // (each iteration retires one worker, so this
                    // terminates after at most n_workers re-picks)
                    self.slots[w].close();
                    req = r;
                }
                other => return other,
            }
        }
    }

    /// Like [`Router::route_bounded`] but reads the field list out of
    /// the request itself, so callers holding an owned request don't
    /// have to clone the slice to satisfy the borrow checker. `Fn` (not
    /// `FnOnce`): the reroute loop re-reads the fields on every re-pick.
    pub fn route_bounded_by<F>(
        &self,
        cap: usize,
        mut req: T,
        fields_of: F,
    ) -> Result<usize, RouteRejection<T>>
    where
        F: Fn(&T) -> &[u32],
    {
        loop {
            let Some(w) = self.pick(fields_of(&req)) else {
                return Err(RouteRejection::Closed(req));
            };
            match self.dispatch(w, cap, req) {
                Err(RouteRejection::Closed(r)) => {
                    self.slots[w].close();
                    req = r;
                }
                other => return other,
            }
        }
    }

    /// Cheapest feasible completion estimate (S33 deadline admission):
    /// `min` over alive, non-quarantined workers of `(depth + 1) ×`
    /// that worker's service-time EWMA, in ns. `None` without attached
    /// health or before any worker has a sample — nothing to judge
    /// against, so admission stays open.
    pub fn eta_ns(&self) -> Option<u64> {
        let h = self.health.as_ref()?;
        let mut best: Option<u64> = None;
        for (w, s) in self.slots.iter().enumerate() {
            if !s.is_alive() || h.state(w) == BreakerState::Quarantined {
                continue;
            }
            let Some(e) = h.ewma_ns(w) else { continue };
            let eta = (s.depth.load(Ordering::Relaxed) as u64 + 1)
                .saturating_mul(e as u64);
            best = Some(best.map_or(eta, |b| b.min(eta)));
        }
        best
    }

    /// One-shot hedge dispatch (S33): enqueue `req` on the best-ranked
    /// live worker other than `exclude` (breaker rank, then depth, then
    /// id). No re-pick loop and no ledger entry on failure — a hedge
    /// that cannot be placed simply never existed; the primary copy
    /// still answers. Returns the request on any failure so the caller
    /// can drop it deliberately.
    pub fn route_hedge(
        &self,
        exclude: usize,
        cap: usize,
        req: T,
    ) -> Result<usize, T> {
        let pick = self
            .slots
            .iter()
            .enumerate()
            .filter(|(i, s)| {
                *i != exclude && s.is_alive() && self.rank(*i) < 2
            })
            .min_by_key(|(i, s)| {
                (self.rank(*i), s.depth.load(Ordering::Relaxed), *i)
            })
            .map(|(i, _)| i);
        let Some(w) = pick else { return Err(req) };
        match self.dispatch(w, cap, req) {
            Ok(w) => Ok(w),
            Err(RouteRejection::Closed(r))
            | Err(RouteRejection::Overloaded(r)) => Err(r),
        }
    }

    /// Enqueue on worker `w` iff its slot is open and a queue slot is
    /// free. The depth slot is reserved with an atomic increment BEFORE
    /// the send (rolled back on rejection/closure), so `cap` is a hard
    /// bound even with many concurrent submitters — a check-then-send
    /// would let N racing producers each observe `cap - 1` and all
    /// enqueue. The send itself happens under the slot's sender lock,
    /// serializing against [`WorkerSlot::close`] (see the slot docs).
    fn dispatch(&self, w: usize, cap: usize, req: T) -> Result<usize, RouteRejection<T>> {
        let slot = &self.slots[w];
        let guard = slot.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else {
            return Err(RouteRejection::Closed(req));
        };
        if slot.depth.fetch_add(1, Ordering::Relaxed) >= cap {
            slot.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(RouteRejection::Overloaded(req));
        }
        match tx.send(req) {
            Ok(()) => Ok(w),
            Err(e) => {
                slot.depth.fetch_sub(1, Ordering::Relaxed);
                Err(RouteRejection::Closed(e.0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embeddings::ShardPolicy;
    use std::sync::mpsc;

    #[test]
    fn round_robin_spreads_evenly() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..4).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::RoundRobin);
        for i in 0..20 {
            r.route(i).unwrap();
        }
        for rx in &rxs {
            assert_eq!(rx.try_iter().count(), 5);
        }
    }

    #[test]
    fn least_queued_prefers_empty_worker() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..2).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::LeastQueued);
        // fill worker queues unevenly by routing, then drain worker 1
        for i in 0..6 {
            r.route(i).unwrap();
        }
        // drain worker 1's queue and decrement its depth handle
        let d1 = r.depth_handle(1);
        while rxs[1].try_recv().is_ok() {
            d1.fetch_sub(1, Ordering::Relaxed);
        }
        let w = r.route(99).unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn property_conservation() {
        use crate::util::qcheck::qcheck;
        qcheck(30, |g| {
            let workers = g.usize(1, 6);
            let n = g.usize(0, 80);
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..workers).map(|_| mpsc::channel()).unzip();
            let r = Router::new(txs, Policy::RoundRobin);
            for i in 0..n {
                crate::prop_assert!(r.route(i).is_ok());
            }
            let total: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();
            crate::prop_assert_eq!(total, n);
            Ok(())
        });
    }

    #[test]
    fn closed_queues_return_request() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(rx);
        let r = Router::new(vec![tx], Policy::RoundRobin);
        assert_eq!(r.route(5).unwrap_err(), 5);
        // the failed send marked the only worker dead
        assert_eq!(r.n_alive(), 0);
    }

    #[test]
    fn closed_queue_reroutes_to_live_workers() {
        // worker 1's receiver dies; every request must still land on a
        // live worker, with no error surfaced and nothing lost
        let (txs, mut rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|_| mpsc::channel::<u32>()).unzip();
        drop(rxs.remove(1)); // rxs now holds workers 0 and 2
        let r = Router::new(txs, Policy::RoundRobin);
        for i in 0..30 {
            let w = r.route(i).unwrap();
            assert_ne!(w, 1, "request {i} routed to the dead worker");
        }
        assert_eq!(r.n_alive(), 2);
        let total: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();
        assert_eq!(total, 30, "reroute must conserve requests");
    }

    #[test]
    fn dead_worker_receives_zero_new_routes() {
        // A killed worker's depth gauge freezes at 0 — without the alive
        // check, LeastQueued and ShardAffinity would keep picking it
        // forever. Pin: zero new routes land on a closed slot.
        let map = Arc::new(ShardMap::build(
            &[10, 10, 10, 10],
            1.2,
            2,
            ShardPolicy::RoundRobinTables,
        ));
        for policy in [Policy::RoundRobin, Policy::LeastQueued, Policy::ShardAffinity] {
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..2).map(|_| mpsc::channel::<u32>()).unzip();
            let r = match policy {
                Policy::ShardAffinity => {
                    Router::new(txs, policy).with_shards(map.clone())
                }
                _ => Router::new(txs, policy),
            };
            r.slot_handle(0).close();
            assert_eq!(r.n_alive(), 1);
            for i in 0..20 {
                // shard 0 owns tables {0,2}: under affinity these
                // requests WANT dead worker 0, and must not get it
                assert_eq!(r.route_bounded(&[0, 2], usize::MAX, i).unwrap(), 1);
            }
            assert_eq!(rxs[0].try_iter().count(), 0, "{policy:?}");
            assert_eq!(rxs[1].try_iter().count(), 20, "{policy:?}");
        }
    }

    #[test]
    fn close_all_ends_every_queue() {
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|_| mpsc::channel::<u32>()).unzip();
        let r = Router::new(txs, Policy::RoundRobin);
        r.route(1).unwrap();
        r.close_all();
        assert_eq!(r.n_alive(), 0);
        assert!(r.route(2).is_err());
        // queued work is still readable, then the channel reports closed
        assert_eq!(rxs.iter().map(|rx| rx.try_iter().count()).sum::<usize>(), 1);
        for rx in &rxs {
            assert!(matches!(
                rx.try_recv(),
                Err(mpsc::TryRecvError::Disconnected)
            ));
        }
    }

    #[test]
    fn bounded_route_rejects_at_capacity() {
        let (tx, rx) = mpsc::channel::<u32>();
        let r = Router::new(vec![tx], Policy::RoundRobin);
        assert!(r.route_bounded(&[], 2, 1).is_ok());
        assert!(r.route_bounded(&[], 2, 2).is_ok());
        match r.route_bounded(&[], 2, 3) {
            Err(RouteRejection::Overloaded(req)) => assert_eq!(req, 3),
            _ => panic!("expected Overloaded"),
        }
        // overload is admission control, not death
        assert_eq!(r.n_alive(), 1);
        assert_eq!(rx.try_iter().count(), 2);
    }

    #[test]
    fn shard_affinity_prefers_local_owner() {
        // 4 tables on 2 shards round-robin: shard 0 owns {0,2}, 1 owns {1,3}
        let map = Arc::new(ShardMap::build(
            &[10, 10, 10, 10],
            1.2,
            2,
            ShardPolicy::RoundRobinTables,
        ));
        let (txs, _rxs): (Vec<_>, Vec<_>) =
            (0..2).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::ShardAffinity).with_shards(map);
        assert_eq!(r.route_bounded(&[0, 2], usize::MAX, 1u32).unwrap(), 0);
        assert_eq!(r.route_bounded(&[1, 3], usize::MAX, 2u32).unwrap(), 1);
        // mixed request: tie (0.5 each) → least-queued → worker 0 has
        // depth 1, worker 1 has depth 1 → lower id after depth tie…
        // drain nothing; both depth 1 → worker 0
        assert_eq!(r.route_bounded(&[0, 1], usize::MAX, 3u32).unwrap(), 0);
    }

    fn health(workers: usize, probe_interval: u64) -> Arc<FleetHealth> {
        use crate::coordinator::tail::TailConfig;
        Arc::new(FleetHealth::new(
            workers,
            &TailConfig {
                strikes: 1,
                probe_interval,
                ..TailConfig::default()
            },
        ))
    }

    /// Drive worker `w` into quarantine: one fast peer sample as the
    /// baseline, then two slow strikes (strikes = 1 per demotion).
    fn quarantine(h: &FleetHealth, w: usize, peer: usize) {
        h.record(peer, 1_000_000);
        h.record(w, 100_000_000);
        h.record(w, 100_000_000);
        assert_eq!(h.state(w), BreakerState::Quarantined);
    }

    #[test]
    fn quarantined_worker_gets_zero_normal_routes() {
        // probe_interval = u64::MAX: the probe path never fires, so a
        // quarantined worker must see literally zero traffic — even
        // when affinity scoring WANTS it — until a probe succeeds.
        let map = Arc::new(ShardMap::build(
            &[10, 10, 10, 10],
            1.2,
            2,
            ShardPolicy::RoundRobinTables,
        ));
        for policy in [Policy::LeastQueued, Policy::ShardAffinity] {
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..2).map(|_| mpsc::channel::<u32>()).unzip();
            let h = health(2, u64::MAX);
            let r = match policy {
                Policy::ShardAffinity => {
                    Router::new(txs, policy).with_shards(map.clone())
                }
                _ => Router::new(txs, policy),
            }
            .with_health(h.clone());
            quarantine(&h, 0, 1);
            for i in 0..20 {
                // shard 0 owns tables {0,2}: affinity wants worker 0
                assert_eq!(r.route_bounded(&[0, 2], usize::MAX, i).unwrap(), 1);
            }
            assert_eq!(rxs[0].try_iter().count(), 0, "{policy:?}");
            assert_eq!(rxs[1].try_iter().count(), 20, "{policy:?}");
        }
    }

    #[test]
    fn trickle_probe_reaches_the_quarantined_worker() {
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..2).map(|_| mpsc::channel::<u32>()).unzip();
        let h = health(2, 4);
        let r = Router::new(txs, Policy::LeastQueued).with_health(h.clone());
        quarantine(&h, 0, 1);
        for i in 0..8 {
            r.route_bounded(&[], usize::MAX, i).unwrap();
        }
        // tickets 0..8 with interval 4 → exactly tickets 0 and 4 probe
        assert_eq!(rxs[0].try_iter().count(), 2, "trickle probes");
        assert_eq!(rxs[1].try_iter().count(), 6);
        // a fast probe sample lifts quarantine; normal ranking resumes
        h.record(0, 1_000_000);
        assert_eq!(h.state(0), BreakerState::Probation);
    }

    #[test]
    fn all_quarantined_still_serves() {
        // degraded beats dead: with every live worker quarantined the
        // fallback routes anyway instead of surfacing Closed
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..2).map(|_| mpsc::channel::<u32>()).unzip();
        let h = health(2, u64::MAX);
        let r = Router::new(txs, Policy::LeastQueued).with_health(h.clone());
        // quarantine BOTH: w0 seeds the baseline, w1 strikes out
        // against it, then w0 strikes out against w1's inflated EWMA
        h.record(0, 1_000_000);
        h.record(1, 100_000_000);
        h.record(1, 100_000_000);
        h.record(0, 1_000_000_000);
        h.record(0, 1_000_000_000);
        assert_eq!(h.state(0), BreakerState::Quarantined);
        assert_eq!(h.state(1), BreakerState::Quarantined);
        for i in 0..6 {
            r.route_bounded(&[], usize::MAX, i).unwrap();
        }
        let total: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn route_hedge_avoids_excluded_and_quarantined() {
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|_| mpsc::channel::<u32>()).unzip();
        let h = health(3, u64::MAX);
        let r = Router::new(txs, Policy::LeastQueued).with_health(h.clone());
        quarantine(&h, 2, 0);
        // exclude the primary (0); worker 2 is quarantined → worker 1
        assert_eq!(r.route_hedge(0, usize::MAX, 7).unwrap(), 1);
        assert_eq!(rxs[1].try_iter().count(), 1);
        // no eligible peer: worker 1 dead, 2 quarantined → Err, and the
        // request comes back to be dropped deliberately
        r.slot_handle(1).close();
        assert_eq!(r.route_hedge(0, usize::MAX, 8).unwrap_err(), 8);
        assert_eq!(rxs[0].try_iter().count(), 0);
        assert_eq!(rxs[2].try_iter().count(), 0);
    }

    #[test]
    fn eta_estimates_from_health_ewma() {
        let (txs, _rxs): (Vec<_>, Vec<_>) =
            (0..2).map(|_| mpsc::channel::<u32>()).unzip();
        let h = health(2, u64::MAX);
        let r = Router::new(txs, Policy::LeastQueued).with_health(h.clone());
        assert_eq!(r.eta_ns(), None, "no samples yet → admission open");
        h.record(0, 2_000_000);
        h.record(1, 1_000_000);
        // empty queues: min (depth 0 + 1) × ewma = 1ms (worker 1)
        assert_eq!(r.eta_ns(), Some(1_000_000));
        // routing 3 least-queued: w0, w1, w0 → depths (2, 1), so the
        // min eta is worker 1's (1+1) × 1ms = 2ms (w0: (2+1) × 2ms)
        r.route_bounded(&[], usize::MAX, 1).unwrap();
        r.route_bounded(&[], usize::MAX, 2).unwrap();
        r.route_bounded(&[], usize::MAX, 3).unwrap();
        assert_eq!(r.eta_ns(), Some(2_000_000));
    }

    #[test]
    fn shard_affinity_without_map_is_least_queued() {
        let (txs, _rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::ShardAffinity);
        let w = r.route_bounded(&[1, 2], usize::MAX, 7u32).unwrap();
        assert_eq!(w, 0); // all empty → first worker
    }
}
