//! Request router: spread incoming requests across worker queues.
//!
//! Policies: round-robin (default; uniform work) and least-queued
//! (counter-based, for heterogeneous workers). Conservation — every
//! accepted request lands on exactly one queue — is property-tested.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastQueued,
}

pub struct Router<T> {
    queues: Vec<Sender<T>>,
    depths: Vec<Arc<AtomicUsize>>,
    policy: Policy,
    next: AtomicUsize,
}

impl<T> Router<T> {
    pub fn new(queues: Vec<Sender<T>>, policy: Policy) -> Router<T> {
        let depths = (0..queues.len())
            .map(|_| Arc::new(AtomicUsize::new(0)))
            .collect();
        Router {
            queues,
            depths,
            policy,
            next: AtomicUsize::new(0),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.queues.len()
    }

    /// Depth handle for worker `i` — the worker decrements it when it
    /// takes a request off its queue.
    pub fn depth_handle(&self, i: usize) -> Arc<AtomicUsize> {
        self.depths[i].clone()
    }

    /// Route one request; returns the chosen worker or Err(req) if every
    /// queue is closed.
    pub fn route(&self, req: T) -> Result<usize, T> {
        let w = match self.policy {
            Policy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.queues.len()
            }
            Policy::LeastQueued => self
                .depths
                .iter()
                .enumerate()
                .min_by_key(|(_, d)| d.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
        };
        match self.queues[w].send(req) {
            Ok(()) => {
                self.depths[w].fetch_add(1, Ordering::Relaxed);
                Ok(w)
            }
            Err(e) => Err(e.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn round_robin_spreads_evenly() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..4).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::RoundRobin);
        for i in 0..20 {
            r.route(i).unwrap();
        }
        for rx in &rxs {
            assert_eq!(rx.try_iter().count(), 5);
        }
    }

    #[test]
    fn least_queued_prefers_empty_worker() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..2).map(|_| mpsc::channel()).unzip();
        let r = Router::new(txs, Policy::LeastQueued);
        // fill worker queues unevenly by routing, then drain worker 1
        for i in 0..6 {
            r.route(i).unwrap();
        }
        // drain worker 1's queue and decrement its depth handle
        let d1 = r.depth_handle(1);
        while rxs[1].try_recv().is_ok() {
            d1.fetch_sub(1, Ordering::Relaxed);
        }
        let w = r.route(99).unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn property_conservation() {
        use crate::util::qcheck::qcheck;
        qcheck(30, |g| {
            let workers = g.usize(1, 6);
            let n = g.usize(0, 80);
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..workers).map(|_| mpsc::channel()).unzip();
            let r = Router::new(txs, Policy::RoundRobin);
            for i in 0..n {
                crate::prop_assert!(r.route(i).is_ok());
            }
            let total: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();
            crate::prop_assert_eq!(total, n);
            Ok(())
        });
    }

    #[test]
    fn closed_queues_return_request() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(rx);
        let r = Router::new(vec![tx], Policy::RoundRobin);
        assert_eq!(r.route(5).unwrap_err(), 5);
    }
}
