//! Serving coordinator (S15) — the L3 request path.
//!
//! Architecture (vLLM-router-like, scaled to this paper's serving job):
//!
//! ```text
//!  load gen ──► router ──► worker queue ──► dynamic batcher
//!   (S19)        │  admission │ (bounded)       │ shed-stale
//!                ▼            ▼                 ▼
//!             metrics ◄── responses ◄── embedding gather ─► PJRT exec
//!                              (local shard + cross-shard fetches)
//! ```
//!
//! Workers are std threads (tokio is unavailable offline — DESIGN.md §8);
//! each worker owns a PJRT `Runtime` (or any `InferenceEngine` in tests)
//! and either a shared `EmbeddingStore` handle or its slice of a
//! `ShardedStore` (S18), so Python is never on this path. Queues are
//! bounded with reject/shed admission control, and `loadgen` drives the
//! whole stack deterministically for `autorac serve-bench`.

pub mod batcher;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod net;
pub mod router;
pub mod server;
pub mod tail;

pub use batcher::{BatcherConfig, collect_batch};
pub use engine::{
    CrashAfter, InferenceEngine, MockEngine, PimEngine, PjrtEngine, SlowAfter,
};
pub use loadgen::{
    run_scenario, Arrival, CrashInjector, LoadGenConfig, LoadReport, Scenario,
    ScenarioOutcome, ScenarioSpec, ScheduledRequest, SlowInjector, WireStats,
};
pub use metrics::{Metrics, MetricsSnapshot};
pub use net::{NetClient, NetServer, NetServerConfig, WireResponse};
pub use router::{Policy, Router, WorkerSlot};
pub use server::{
    Admission, AdmissionPolicy, Coordinator, CoordinatorConfig, Request,
    Response, ServingStore,
};
pub use tail::{
    BreakerState, FleetHealth, HedgeBudget, HedgeGate, HedgeTag, TailConfig,
};
