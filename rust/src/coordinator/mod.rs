//! Serving coordinator (S15) — the L3 request path.
//!
//! Architecture (vLLM-router-like, scaled to this paper's serving job):
//!
//! ```text
//!  load gen ──► router ──► worker queue ──► dynamic batcher
//!                 │                              │
//!                 ▼                              ▼
//!              metrics ◄── responses ◄── embedding gather ─► PJRT exec
//! ```
//!
//! Workers are std threads (tokio is unavailable offline — DESIGN.md §8);
//! each worker owns a PJRT `Runtime` (or any `InferenceEngine` in tests)
//! and an `EmbeddingStore` handle, so Python is never on this path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, collect_batch};
pub use engine::{InferenceEngine, MockEngine, PjrtEngine};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::Router;
pub use server::{Coordinator, CoordinatorConfig, Request, Response};
