//! Model-quality metrics (S16): LogLoss and AUC, used by the rust-side
//! evaluation of the served model (Table 2 verification) — mirrors
//! `python/compile/model.py::{logloss, auc}`.

/// Binary cross-entropy of probabilities against {0,1} labels.
pub fn logloss(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    assert!(!probs.is_empty());
    let eps = 1e-7f64;
    let mut acc = 0f64;
    for (&p, &y) in probs.iter().zip(labels) {
        let p = (p as f64).clamp(eps, 1.0 - eps);
        let y = y as f64;
        acc -= y * p.ln() + (1.0 - y) * (1.0 - p).ln();
    }
    acc / probs.len() as f64
}

/// Rank-based AUC (Mann–Whitney), with midrank tie handling.
pub fn auc(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let n = probs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| probs[a].partial_cmp(&probs[b]).unwrap());
    let mut ranks = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && probs[order[j + 1]] == probs[order[i]] {
            j += 1;
        }
        let midrank = 0.5 * (i + j) as f64 + 1.0;
        for &k in &order[i..=j] {
            ranks[k] = midrank;
        }
        i = j + 1;
    }
    let n_pos: f64 = labels.iter().map(|&y| y as f64).sum();
    let n_neg = n as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return 0.5;
    }
    let rank_sum: f64 = ranks
        .iter()
        .zip(labels)
        .filter(|(_, &y)| y > 0.5)
        .map(|(r, _)| r)
        .sum();
    (rank_sum - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_has_auc_one() {
        let probs = [0.1, 0.2, 0.8, 0.9];
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert!((auc(&probs, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_ties_have_auc_half() {
        let probs = [0.5; 6];
        let labels = [1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert!((auc(&probs, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reversed_ranking_has_auc_zero() {
        let probs = [0.9, 0.8, 0.1];
        let labels = [0.0, 0.0, 1.0];
        assert!(auc(&probs, &labels) < 1e-12);
    }

    #[test]
    fn degenerate_labels_return_half() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn logloss_matches_closed_form() {
        let probs = [0.8f32, 0.2];
        let labels = [1.0f32, 0.0];
        let want = -((0.8f64).ln() + (0.8f64).ln()) / 2.0;
        // f32 literals carry ~1e-8 representation error into the f64 math
        assert!((logloss(&probs, &labels) - want).abs() < 1e-7);
    }

    #[test]
    fn logloss_clamps_extremes() {
        let l = logloss(&[0.0, 1.0], &[1.0, 0.0]);
        assert!(l.is_finite() && l > 10.0);
    }

    #[test]
    fn property_auc_is_order_invariant_under_monotone_transform() {
        use crate::util::qcheck::qcheck;
        qcheck(50, |g| {
            let n = g.usize(4, 64);
            let probs = g.vec_f32(n, 0.01, 0.99);
            let labels: Vec<f32> =
                (0..n).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let a1 = auc(&probs, &labels);
            let squashed: Vec<f32> = probs.iter().map(|p| p * p).collect();
            let a2 = auc(&squashed, &labels);
            crate::prop_assert!((a1 - a2).abs() < 1e-9, "{a1} vs {a2}");
            Ok(())
        });
    }
}
