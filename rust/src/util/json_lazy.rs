//! Lazy wire-request extraction (S27): hot-field scanning over raw
//! bytes, with the `util::json` tree parser as the authoritative
//! fallback.
//!
//! The serving front end (`coordinator::net`, S28) speaks one request
//! line per inference:
//!
//! ```text
//! {"id":7,"dense":[0.5,-1.25],"tables":[0,3,9],"ids":[12,44,7]}\n
//! ```
//!
//! Building a full [`Json`] tree for that line allocates a `String` per
//! key, a boxed `Json::Num` per element, and a `Vec` per container —
//! then throws it all away after four field lookups. [`lazy_scan`]
//! instead cursor-walks the bytes once and parses the four hot fields
//! (`id`, `dense`, `tables`, `ids`) straight into their final typed
//! buffers, skipping any cold field (session blobs, AB labels, user
//! agents…) without materialising it.
//!
//! **Invariant — lazy never disagrees with the tree.** The scanner
//! returns [`Scan::Fallback`] the moment it sees anything it is not
//! trivially sure about: a non-ASCII byte anywhere, a `\` escape in any
//! string, a hot field with a surprising type, nesting past
//! [`json::MAX_DEPTH`], any grammar it does not recognise. Fallback
//! re-parses the same bytes through [`Json::parse`], so the lazy path
//! can only ever accept a *subset* of what the tree accepts, and on
//! that subset it produces bit-identical values by construction: both
//! paths scan the same number extent, call the same `str::parse::<f64>`,
//! and convert through the same narrowing helpers. Duplicate keys keep
//! the tree's first-occurrence-wins semantics ([`Json::get`] returns
//! the first match). The differential qcheck suite
//! (`rust/tests/json_lazy_prop.rs`) pins all of this.

use super::json::{self, Json};

/// Hard caps applied by [`WireRequest::validate`] on BOTH parse paths.
/// These are request-shape sanity bounds (anti-DoS hygiene), not panic
/// guards — the embedding gather paths already clamp hostile row ids.
pub const MAX_WIRE_FIELDS: usize = 4096;
/// Cap on `dense` length (see [`MAX_WIRE_FIELDS`]).
pub const MAX_WIRE_DENSE: usize = 4096;

/// A decoded serving request, transport-level view.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub dense: Vec<f32>,
    /// table indices, strictly ascending (same contract as
    /// `coordinator::server::Request::fields`)
    pub tables: Vec<u32>,
    /// one embedding row id per entry of `tables`
    pub ids: Vec<i32>,
    /// optional end-to-end deadline budget in microseconds (S33);
    /// absent on the wire ⇒ `None` ⇒ every deadline check downstream is
    /// skipped. Present-but-invalid (null, string, negative, fractional)
    /// is a parse error on both paths.
    pub deadline_us: Option<u64>,
}

/// Which parser produced a result — surfaced so tests and server
/// counters can pin the lazy hit-rate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParsePath {
    Lazy,
    Tree,
}

/// Outcome of one lazy pass over the bytes.
pub enum Scan {
    Done(WireRequest),
    /// the scanner was not sure; re-parse through the tree. The reason
    /// is for diagnostics only — the tree path is authoritative for
    /// both acceptance and the error message.
    Fallback(&'static str),
}

// ---------------------------------------------------------------------------
// Shared narrowing helpers — the ONE definition both paths go through,
// so a lazy-accepted number can never convert differently than the
// tree-accepted same number.
// ---------------------------------------------------------------------------

#[inline]
fn f64_to_u64(x: f64) -> Option<u64> {
    // `as` saturates, which is fine: a request id only needs identity
    (x >= 0.0 && x.fract() == 0.0).then(|| x as u64)
}

#[inline]
fn f64_to_u32(x: f64) -> Option<u32> {
    (x >= 0.0 && x <= u32::MAX as f64 && x.fract() == 0.0).then(|| x as u32)
}

#[inline]
fn f64_to_i32(x: f64) -> Option<i32> {
    (x >= i32::MIN as f64 && x <= i32::MAX as f64 && x.fract() == 0.0)
        .then(|| x as i32)
}

impl WireRequest {
    /// Decode from an already-built tree (the fallback path).
    pub fn from_json(j: &Json) -> crate::Result<WireRequest> {
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .and_then(f64_to_u64)
            .ok_or_else(|| crate::err!("missing/invalid number field `id`"))?;
        let dense: Vec<f32> = j
            .req_arr("dense")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<_>>()
            .ok_or_else(|| crate::err!("non-number in `dense`"))?;
        let tables: Vec<u32> = j
            .req_arr("tables")?
            .iter()
            .map(|v| v.as_f64().and_then(f64_to_u32))
            .collect::<Option<_>>()
            .ok_or_else(|| crate::err!("non-u32 in `tables`"))?;
        let ids: Vec<i32> = j
            .req_arr("ids")?
            .iter()
            .map(|v| v.as_f64().and_then(f64_to_i32))
            .collect::<Option<_>>()
            .ok_or_else(|| crate::err!("non-i32 in `ids`"))?;
        let deadline_us = match j.get("deadline_us") {
            None => None,
            Some(v) => Some(
                v.as_f64().and_then(f64_to_u64).ok_or_else(|| {
                    crate::err!("missing/invalid number field `deadline_us`")
                })?,
            ),
        };
        Ok(WireRequest { id, dense, tables, ids, deadline_us })
    }

    /// Shape sanity, applied after BOTH parse paths.
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(
            self.tables.len() == self.ids.len(),
            "`tables` ({}) and `ids` ({}) lengths differ",
            self.tables.len(),
            self.ids.len()
        );
        crate::ensure!(
            self.tables.len() <= MAX_WIRE_FIELDS,
            "too many sparse fields ({} > {MAX_WIRE_FIELDS})",
            self.tables.len()
        );
        crate::ensure!(
            self.dense.len() <= MAX_WIRE_DENSE,
            "too many dense features ({} > {MAX_WIRE_DENSE})",
            self.dense.len()
        );
        crate::ensure!(
            self.tables.windows(2).all(|w| w[0] < w[1]),
            "`tables` must be strictly ascending"
        );
        Ok(())
    }

    /// Encode as one request line (trailing `\n` included). Floats use
    /// Rust's shortest round-trip formatting; an f32 printed this way,
    /// parsed back as f64 and narrowed, recovers the original bits —
    /// pinned by the encoder round-trip qcheck.
    pub fn to_line(&self) -> String {
        let mut s = String::with_capacity(
            40 + 12 * (self.dense.len() + self.tables.len() + self.ids.len()),
        );
        s.push_str("{\"id\":");
        s.push_str(&self.id.to_string());
        s.push_str(",\"dense\":[");
        for (i, &x) in self.dense.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            write_f32(&mut s, x);
        }
        s.push_str("],\"tables\":[");
        for (i, &t) in self.tables.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&t.to_string());
        }
        s.push_str("],\"ids\":[");
        for (i, &v) in self.ids.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&v.to_string());
        }
        s.push(']');
        // emitted only when set: a deadline-free request line is
        // byte-identical to the pre-deadline wire format
        if let Some(d) = self.deadline_us {
            s.push_str(",\"deadline_us\":");
            s.push_str(&d.to_string());
        }
        s.push_str("}\n");
        s
    }
}

/// Append an f32 as a JSON number in shortest round-trip form (shared
/// with the response encoder in `coordinator::net`). JSON has no
/// NaN/Inf; mirror `json::write_num` and emit `null`, which both decode
/// paths then reject as a non-number (fail-loud beats a silently
/// corrupted feature).
pub fn write_f32(out: &mut String, x: f32) {
    if x.is_finite() {
        // -0.0 must take the Display branch ("-0") to round-trip its bits
        if x.fract() == 0.0 && x.abs() < 1.0e7 && !(x == 0.0 && x.is_sign_negative()) {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        out.push_str("null");
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Parse one request line, lazy-first. See [`parse_request_traced`].
pub fn parse_request(bytes: &[u8]) -> crate::Result<WireRequest> {
    parse_request_traced(bytes).0
}

/// Parse one request line and report which path produced the result.
pub fn parse_request_traced(bytes: &[u8]) -> (crate::Result<WireRequest>, ParsePath) {
    match lazy_scan(bytes) {
        Scan::Done(req) => {
            let res = req.validate().map(|()| req);
            (res, ParsePath::Lazy)
        }
        Scan::Fallback(_) => (parse_request_tree(bytes), ParsePath::Tree),
    }
}

/// The authoritative tree path (public so benches can time it head-to-
/// head against the lazy path on identical bytes).
pub fn parse_request_tree(bytes: &[u8]) -> crate::Result<WireRequest> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| crate::err!("request is not valid UTF-8"))?;
    let j = Json::parse(text).map_err(|e| crate::err!("bad request JSON: {e}"))?;
    let req = WireRequest::from_json(&j)?;
    req.validate()?;
    Ok(req)
}

// ---------------------------------------------------------------------------
// The scanner
// ---------------------------------------------------------------------------

/// One pass over the bytes. Never errors and never panics: anything
/// suspicious is a [`Scan::Fallback`].
pub fn lazy_scan(bytes: &[u8]) -> Scan {
    let mut c = Cursor { b: bytes, i: 0 };
    macro_rules! fall {
        ($why:expr) => {
            return Scan::Fallback($why)
        };
    }
    c.skip_ws();
    if c.peek() != Some(b'{') {
        fall!("top level is not an object");
    }
    c.i += 1;

    let mut id: Option<u64> = None;
    let mut dense: Option<Vec<f32>> = None;
    let mut tables: Option<Vec<u32>> = None;
    let mut ids: Option<Vec<i32>> = None;
    // optional hot field: captured when present (the tree path would
    // see it, so skipping it as cold would make the paths disagree),
    // but never required for Scan::Done
    let mut deadline_us: Option<u64> = None;

    c.skip_ws();
    if c.peek() == Some(b'}') {
        c.i += 1;
    } else {
        loop {
            c.skip_ws();
            let (ks, ke) = match c.raw_string() {
                Ok(span) => span,
                Err(why) => fall!(why),
            };
            c.skip_ws();
            if c.peek() != Some(b':') {
                fall!("expected `:` after key");
            }
            c.i += 1;
            c.skip_ws();
            // First occurrence wins (Json::get semantics); later
            // duplicates are skipped like any cold field.
            let outcome = match &bytes[ks..ke] {
                b"id" if id.is_none() => match c.number() {
                    Ok(x) => match f64_to_u64(x) {
                        Some(v) => {
                            id = Some(v);
                            Ok(())
                        }
                        None => Err("`id` is not a u64"),
                    },
                    Err(why) => Err(why),
                },
                b"dense" if dense.is_none() => {
                    match c.number_array(|x| Some(x as f32)) {
                        Ok(v) => {
                            dense = Some(v);
                            Ok(())
                        }
                        Err(why) => Err(why),
                    }
                }
                b"tables" if tables.is_none() => match c.number_array(f64_to_u32) {
                    Ok(v) => {
                        tables = Some(v);
                        Ok(())
                    }
                    Err(why) => Err(why),
                },
                b"ids" if ids.is_none() => match c.number_array(f64_to_i32) {
                    Ok(v) => {
                        ids = Some(v);
                        Ok(())
                    }
                    Err(why) => Err(why),
                },
                b"deadline_us" if deadline_us.is_none() => match c.number() {
                    Ok(x) => match f64_to_u64(x) {
                        Some(v) => {
                            deadline_us = Some(v);
                            Ok(())
                        }
                        None => Err("`deadline_us` is not a u64"),
                    },
                    Err(why) => Err(why),
                },
                _ => c.skip_value(0),
            };
            if let Err(why) = outcome {
                fall!(why);
            }
            c.skip_ws();
            match c.peek() {
                Some(b',') => c.i += 1,
                Some(b'}') => {
                    c.i += 1;
                    break;
                }
                _ => fall!("expected `,` or `}`"),
            }
        }
    }
    c.skip_ws();
    if c.i != bytes.len() {
        fall!("trailing bytes after object");
    }
    match (id, dense, tables, ids) {
        (Some(id), Some(dense), Some(tables), Some(ids)) => {
            Scan::Done(WireRequest { id, dense, tables, ids, deadline_us })
        }
        // missing hot field: let the tree path own the error message
        _ => Scan::Fallback("missing hot field"),
    }
}

type ScanResult<T> = Result<T, &'static str>;

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    #[inline]
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    #[inline]
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    /// A `"…"` span with NO escapes, NO control bytes, NO non-ASCII —
    /// the only strings the lazy path trusts itself with. Returns the
    /// byte span between the quotes.
    fn raw_string(&mut self) -> ScanResult<(usize, usize)> {
        if self.peek() != Some(b'"') {
            return Err("expected a string");
        }
        self.i += 1;
        let start = self.i;
        loop {
            match self.peek() {
                None => return Err("unterminated string"),
                Some(b'"') => {
                    let end = self.i;
                    self.i += 1;
                    return Ok((start, end));
                }
                Some(b'\\') => return Err("escape in string"),
                Some(c) if c < 0x20 || c >= 0x80 => {
                    return Err("non-ASCII or control byte in string")
                }
                Some(_) => self.i += 1,
            }
        }
    }

    /// Scan a number with EXACTLY the tree parser's extent grammar and
    /// the same `str::parse::<f64>` — bit-identical by construction.
    fn number(&mut self) -> ScanResult<f64> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if self.i == start {
            return Err("expected a number");
        }
        // the extent is ASCII by construction of the scan above
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or("invalid number")
    }

    /// `[n, n, …]` of numbers straight into a typed vec.
    fn number_array<T>(&mut self, narrow: impl Fn(f64) -> Option<T>) -> ScanResult<Vec<T>> {
        if self.peek() != Some(b'[') {
            return Err("expected an array");
        }
        self.i += 1;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            self.skip_ws();
            let x = self.number()?;
            out.push(narrow(x).ok_or("element out of range for target type")?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(out);
                }
                _ => return Err("expected `,` or `]`"),
            }
        }
    }

    /// Skip one cold JSON value without materialising it. Mirrors the
    /// tree parser's grammar (same literals, same number extents, same
    /// [`json::MAX_DEPTH`]) but with the stricter lazy string rule, so
    /// it accepts a strict subset of what the tree accepts.
    fn skip_value(&mut self, depth: usize) -> ScanResult<()> {
        match self.peek() {
            Some(b'{') => self.skip_object(depth + 1),
            Some(b'[') => self.skip_array(depth + 1),
            Some(b'"') => self.raw_string().map(|_| ()),
            Some(b't') => self.skip_lit(b"true"),
            Some(b'f') => self.skip_lit(b"false"),
            Some(b'n') => self.skip_lit(b"null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(|_| ()),
            _ => Err("expected a JSON value"),
        }
    }

    fn skip_lit(&mut self, lit: &[u8]) -> ScanResult<()> {
        if self.b[self.i..].starts_with(lit) {
            self.i += lit.len();
            Ok(())
        } else {
            Err("bad literal")
        }
    }

    fn skip_object(&mut self, depth: usize) -> ScanResult<()> {
        if depth > json::MAX_DEPTH {
            return Err("nesting exceeds depth limit");
        }
        self.i += 1; // past `{`
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.raw_string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err("expected `:`");
            }
            self.i += 1;
            self.skip_ws();
            self.skip_value(depth)?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err("expected `,` or `}`"),
            }
        }
    }

    fn skip_array(&mut self, depth: usize) -> ScanResult<()> {
        if depth > json::MAX_DEPTH {
            return Err("nesting exceeds depth limit");
        }
        self.i += 1; // past `[`
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.skip_value(depth)?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err("expected `,` or `]`"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> WireRequest {
        WireRequest {
            id: 7,
            dense: vec![0.5, -1.25, 3.0],
            tables: vec![0, 3, 9],
            ids: vec![12, -4, 7],
            deadline_us: None,
        }
    }

    #[test]
    fn happy_path_stays_lazy_and_round_trips() {
        let line = req().to_line();
        let (got, path) = parse_request_traced(line.trim_end().as_bytes());
        assert_eq!(path, ParsePath::Lazy);
        assert_eq!(got.unwrap(), req());
    }

    #[test]
    fn cold_fields_are_skipped_lazily() {
        let line = concat!(
            r#"{"ctx":{"sess":"abc","ab":["x","y"],"n":null,"ok":true},"#,
            r#""id":7,"dense":[0.5,-1.25,3],"tables":[0,3,9],"ids":[12,-4,7],"#,
            r#""extra":[1,[2,[3]]]}"#
        );
        let (got, path) = parse_request_traced(line.as_bytes());
        assert_eq!(path, ParsePath::Lazy);
        assert_eq!(got.unwrap(), req());
    }

    #[test]
    fn escapes_and_unicode_fall_back_but_agree() {
        for line in [
            r#"{"id":1,"dense":[1],"tables":[0],"ids":[2],"note":"a\nb"}"#,
            "{\"id\":1,\"dense\":[1],\"tables\":[0],\"ids\":[2],\"note\":\"caf\u{e9}\"}",
        ] {
            let (got, path) = parse_request_traced(line.as_bytes());
            assert_eq!(path, ParsePath::Tree, "{line}");
            let tree = parse_request_tree(line.as_bytes()).unwrap();
            assert_eq!(got.unwrap(), tree);
        }
    }

    #[test]
    fn duplicate_keys_keep_first_occurrence() {
        let line = r#"{"id":1,"id":999,"dense":[1],"dense":"junk","tables":[0],"ids":[2]}"#;
        let (got, path) = parse_request_traced(line.as_bytes());
        assert_eq!(path, ParsePath::Lazy);
        let got = got.unwrap();
        assert_eq!(got.id, 1);
        assert_eq!(got.dense, vec![1.0]);
        // and the tree agrees
        assert_eq!(got, parse_request_tree(line.as_bytes()).unwrap());
    }

    #[test]
    fn validation_rejects_shape_violations_on_both_paths() {
        // mismatched lengths
        let line = r#"{"id":1,"dense":[1],"tables":[0,1],"ids":[2]}"#;
        assert!(parse_request(line.as_bytes()).is_err());
        assert!(parse_request_tree(line.as_bytes()).is_err());
        // unsorted tables
        let line = r#"{"id":1,"dense":[1],"tables":[3,0],"ids":[2,2]}"#;
        assert!(parse_request(line.as_bytes()).is_err());
        assert!(parse_request_tree(line.as_bytes()).is_err());
    }

    #[test]
    fn hostile_inputs_error_without_panicking() {
        for bad in [
            &b""[..],
            b"{",
            b"garbage",
            b"{\"id\":}",
            b"\xff\xfe\x00",
            b"[1,2,3]",
            b"{\"id\":1,\"dense\":[1],\"tables\":[0],\"ids\":[2]} trailing",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?}");
        }
        // deep nesting in a cold field: falls back, tree rejects at cap
        let deep = format!(
            r#"{{"id":1,"dense":[1],"tables":[0],"ids":[2],"x":{}1{}}}"#,
            "[".repeat(json::MAX_DEPTH + 4),
            "]".repeat(json::MAX_DEPTH + 4)
        );
        assert!(parse_request(deep.as_bytes()).is_err());
    }

    #[test]
    fn deadline_rides_the_wire_only_when_set() {
        // absent ⇒ the line is byte-identical to the pre-deadline format
        let line = req().to_line();
        assert!(!line.contains("deadline_us"));
        let mut r = req();
        r.deadline_us = Some(2_500);
        let line = r.to_line();
        assert!(line.contains(",\"deadline_us\":2500}"));
        let (got, path) = parse_request_traced(line.trim_end().as_bytes());
        assert_eq!(path, ParsePath::Lazy, "deadline must stay on the lazy path");
        assert_eq!(got.unwrap(), r);
        assert_eq!(parse_request_tree(line.trim_end().as_bytes()).unwrap(), r);
        // present-but-invalid is an error on BOTH paths, not a silent None
        for bad in [
            r#"{"id":1,"dense":[1],"tables":[0],"ids":[2],"deadline_us":null}"#,
            r#"{"id":1,"dense":[1],"tables":[0],"ids":[2],"deadline_us":-5}"#,
            r#"{"id":1,"dense":[1],"tables":[0],"ids":[2],"deadline_us":1.5}"#,
        ] {
            assert!(parse_request(bad.as_bytes()).is_err(), "{bad}");
            assert!(parse_request_tree(bad.as_bytes()).is_err(), "{bad}");
        }
    }

    #[test]
    fn nonfinite_floats_encode_as_null_and_are_rejected() {
        let mut r = req();
        r.dense[0] = f32::NAN;
        let line = r.to_line();
        assert!(line.contains("null"));
        assert!(parse_request(line.trim_end().as_bytes()).is_err());
    }
}
