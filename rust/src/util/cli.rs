//! Tiny argv parser (clap is unavailable offline — DESIGN.md §8).
//!
//! Grammar: `autorac <subcommand> [positional]... [--flag] [--key value]...`
//! Values may be given as `--key=value` or `--key value`; a `--key`
//! followed by a non-dash token always binds greedily, so positionals must
//! precede options. Unknown keys are collected and reported by `finish()`
//! so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut subcommand = None;
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();

        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    kv.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    kv.insert(body.to_string(), it.next().unwrap());
                } else {
                    flags.push(body.to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args {
            subcommand,
            kv,
            flags,
            positional,
            consumed: std::cell::RefCell::new(Vec::new()),
        }
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| crate::err!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> crate::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| crate::err!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| crate::err!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on any `--key value` / `--flag` that no handler consumed.
    pub fn finish(&self) -> crate::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            crate::bail!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_kv_flags_positional() {
        let a = args("search input.txt --seed 42 --out=x.json --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("search"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["input.txt".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = args("simulate");
        assert_eq!(a.usize_or("batch", 8).unwrap(), 8);
        assert_eq!(a.f64_or("alpha", 1.5).unwrap(), 1.5);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = args("x --n abc");
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn unknown_options_fail_finish() {
        let a = args("x --unknown 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args("x --quiet");
        assert!(a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn no_subcommand_when_leading_dash() {
        let a = args("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}
