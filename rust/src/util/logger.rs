//! Leveled stderr logger with a global verbosity switch.
//!
//! Intentionally tiny: the coordinator's hot path records metrics through
//! `coordinator::metrics`, not the logger, so this only needs to be
//! convenient, not fast.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match VERBOSITY.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
