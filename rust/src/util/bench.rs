//! Micro-benchmark harness (criterion is unavailable offline — DESIGN.md
//! §8). Used by the `benches/*.rs` binaries (declared `harness = false`)
//! and by the §Perf iteration loop.
//!
//! Methodology: warm up for a fixed wall-clock slice, auto-calibrate the
//! per-sample iteration count so a sample lasts ≳1 ms, then collect N
//! samples and report mean/median/p95 with a simple MAD-based outlier
//! count. Results can be appended to a JSON log for before/after diffs.

use super::json::Json;
use super::stats::Quantiles;
use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("median_ns", Json::Num(self.median_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("throughput_per_s", Json::Num(self.throughput_per_s)),
        ])
    }
}

pub struct Bencher {
    warmup: Duration,
    samples: usize,
    min_sample_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        // Honor a quick mode so `cargo bench` in CI stays fast:
        // AUTORAC_BENCH_FAST=1 shrinks warmup/samples.
        let fast = std::env::var("AUTORAC_BENCH_FAST").ok().as_deref() == Some("1");
        Bencher {
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            samples: if fast { 10 } else { 30 },
            min_sample_time: Duration::from_millis(if fast { 1 } else { 4 }),
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> Bencher {
        self.samples = n;
        self
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let warm_start = Instant::now();
        let mut calls: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            calls += 1;
        }
        let per_call = self.warmup.as_nanos() as f64 / calls.max(1) as f64;
        let iters = ((self.min_sample_time.as_nanos() as f64 / per_call.max(1.0)).ceil()
            as u64)
            .max(1);

        let mut q = Quantiles::new();
        let mut min_ns = f64::INFINITY;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
            q.push(ns);
            min_ns = min_ns.min(ns);
        }
        let mean_ns = {
            // recompute from retained samples
            let mut s = 0.0;
            for i in 0..q.len() {
                s += q.quantile(i as f64 / (q.len().max(2) - 1) as f64);
            }
            s / q.len() as f64
        };
        let median = q.median();
        let result = BenchResult {
            name: name.to_string(),
            samples: self.samples,
            iters_per_sample: iters,
            mean_ns,
            median_ns: median,
            p95_ns: q.quantile(0.95),
            min_ns,
            throughput_per_s: 1e9 / median.max(1e-9),
        };
        println!(
            "{:<48} {:>12} /iter   p95 {:>12}   {:>14}/s",
            name,
            fmt_ns(result.median_ns),
            fmt_ns(result.p95_ns),
            fmt_count(result.throughput_per_s)
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Benchmark with a per-iteration setup (excluded from timing by
    /// amortization: setup runs once per sample, f runs `iters` times).
    pub fn bench_with<S, T, F>(&mut self, name: &str, mut setup: S, mut f: F) -> &BenchResult
    where
        S: FnMut() -> T,
        F: FnMut(&mut T),
    {
        let mut state = setup();
        self.bench(name, move || f(black_box(&mut state)))
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append results to artifacts/bench_log.json for before/after diffs.
    pub fn write_log(&self, tag: &str) -> crate::Result<()> {
        let path = std::path::Path::new("artifacts/bench_log.json");
        let mut log = if path.exists() {
            Json::read_file(path)?
        } else {
            Json::Arr(vec![])
        };
        if let Json::Arr(entries) = &mut log {
            for r in &self.results {
                let mut j = r.to_json();
                j.set("tag", Json::Str(tag.to_string()));
                entries.push(j);
            }
        }
        log.write_file(path)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("AUTORAC_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(5);
        let mut acc = 0u64;
        let r = b
            .bench("noop_add", || {
                acc = acc.wrapping_add(bb(1));
            })
            .clone();
        assert!(r.median_ns > 0.0);
        assert!(r.median_ns < 1e6, "a wrapping add should be fast");
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_count(2_000_000.0), "2.00M");
    }
}
