//! Error substrate (S3 in DESIGN.md): the crate-wide `Error`/`Result`
//! pair plus `err!`/`bail!`/`ensure!` macros and a `Context` extension
//! trait — a zero-dependency stand-in for the `anyhow` crate, which is
//! unavailable in the offline build environment (DESIGN.md §8,
//! docs/adr/001-offline-zero-deps.md).
//!
//! Semantics mirror anyhow where it matters to this codebase:
//!
//! * `Error` is a cheap, `Send + Sync` message chain (outermost context
//!   first, root cause last);
//! * `.context("…")` / `.with_context(|| …)` wrap any error — or an
//!   `Option` — with a higher-level frame;
//! * `Display` prints the full chain joined by `": "` (both `{}` and the
//!   anyhow-style alternate `{:#}` — this crate always wants the chain);
//! * `?` converts from the std error types the codebase actually
//!   produces (`io::Error`, `fmt::Error`, UTF-8 and number parses, and
//!   the internal `JsonError` / `XlaError`).

use std::fmt;

/// Crate-wide result alias (replaces the one the anyhow crate provided).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error message. Frames are ordered outermost-first;
/// the last frame is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message — usually reached through the
    /// [`crate::err!`] macro (anyhow's `anyhow!` analogue).
    pub fn msg(message: impl Into<String>) -> Error {
        Error {
            chain: vec![message.into()],
        }
    }

    /// Wrap with an outer context frame (consuming builder form).
    pub fn wrap(mut self, context: impl Into<String>) -> Error {
        self.chain.insert(0, context.into());
        self
    }

    /// The outermost (most recent) context frame.
    pub fn outermost(&self) -> &str {
        &self.chain[0]
    }

    /// The innermost frame — the original failure.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }

    /// All frames, outermost first (like iterating anyhow's `Chain`).
    pub fn frames(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and `{:#}` both print the full chain: every consumer in
        // this crate wants the whole story (anyhow prints only the
        // outermost frame for `{}`, which loses the root cause).
        f.write_str(&self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<…>` reports errors via Debug; make that
        // path human-readable instead of dumping the struct.
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

// ---------------------------------------------------------------------------
// `?` conversions for the error types this codebase produces.
// A blanket `impl<E: std::error::Error> From<E>` would collide with the
// reflexive `From<Error>`, so the sources are listed explicitly.
// ---------------------------------------------------------------------------

macro_rules! impl_from_error {
    ($($ty:ty),* $(,)?) => {
        $(impl From<$ty> for Error {
            fn from(e: $ty) -> Error {
                Error::msg(e.to_string())
            }
        })*
    };
}

impl_from_error!(
    std::io::Error,
    std::fmt::Error,
    std::string::FromUtf8Error,
    std::str::Utf8Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::num::TryFromIntError,
    super::json::JsonError,
);

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

// ---------------------------------------------------------------------------
// Context extension trait (analogue of anyhow's `Context`).
// ---------------------------------------------------------------------------

/// Attach context to a failing `Result` or an empty `Option`.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;

    /// Wrap the error with a lazily-built message (free on success).
    fn with_context<F, S>(self, f: F) -> Result<T>
    where
        F: FnOnce() -> S,
        S: Into<String>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg))
    }

    fn with_context<F, S>(self, f: F) -> Result<T>
    where
        F: FnOnce() -> S,
        S: Into<String>,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<F, S>(self, f: F) -> Result<T>
    where
        F: FnOnce() -> S,
        S: Into<String>,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

// ---------------------------------------------------------------------------
// Macros (exported at the crate root: `crate::err!` / `autorac::err!`).
// ---------------------------------------------------------------------------

/// Build an [`Error`](crate::util::error::Error) from a format string —
/// the analogue of anyhow's `anyhow!`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`](crate::util::error::Error) —
/// the analogue of anyhow's `bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds — the
/// analogue of anyhow's `ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_display() {
        let e = Error::msg("root failure");
        assert_eq!(e.to_string(), "root failure");
        assert_eq!(e.root_cause(), "root failure");
        assert_eq!(e.outermost(), "root failure");
        let e = crate::err!("bad value {} in {}", 42, "field");
        assert_eq!(e.to_string(), "bad value 42 in field");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<()> = Err(Error::msg("disk on fire"));
        let e = r
            .context("reading meta.json")
            .context("opening artifact registry")
            .unwrap_err();
        assert_eq!(
            e.to_string(),
            "opening artifact registry: reading meta.json: disk on fire"
        );
        assert_eq!(e.root_cause(), "disk on fire");
        assert_eq!(e.outermost(), "opening artifact registry");
        assert_eq!(e.frames().count(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32> = Ok(7);
        let v = ok
            .with_context(|| {
                called = true;
                "never built"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called, "context closure must not run on success");

        let err: Result<u32> = Err(Error::msg("boom"));
        let e = err.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: boom");
    }

    #[test]
    fn option_context() {
        let some: Option<u8> = Some(1);
        assert_eq!(some.context("missing").unwrap(), 1);
        let none: Option<u8> = None;
        let e = none.with_context(|| "key `x` absent").unwrap_err();
        assert_eq!(e.to_string(), "key `x` absent");
    }

    #[test]
    fn display_alternate_matches_plain() {
        let e = Error::msg("inner").wrap("outer");
        assert_eq!(format!("{e}"), "outer: inner");
        assert_eq!(format!("{e:#}"), "outer: inner");
        // Debug is the human-readable chain too (main() exit path).
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_io_error() {
        fn open_missing() -> Result<String> {
            let text = std::fs::read_to_string("/definitely/not/a/real/path")?;
            Ok(text)
        }
        let e = open_missing().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn question_mark_converts_fmt_error() {
        fn render() -> Result<String> {
            use std::fmt::Write;
            let mut s = String::new();
            write!(s, "{}", 1)?;
            Ok(s)
        }
        assert_eq!(render().unwrap(), "1");

        // And the explicit From path:
        let e: Error = std::fmt::Error.into();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn question_mark_converts_parse_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>().context("expected an integer")?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        let e = parse("xyz").unwrap_err();
        assert_eq!(e.outermost(), "expected an integer");
    }

    #[test]
    fn bail_and_ensure() {
        fn guarded(n: usize) -> Result<usize> {
            crate::ensure!(n > 0, "n must be positive, got {n}");
            if n > 100 {
                crate::bail!("n too large: {n}");
            }
            crate::ensure!(n != 13);
            Ok(n)
        }
        assert_eq!(guarded(5).unwrap(), 5);
        assert_eq!(
            guarded(0).unwrap_err().to_string(),
            "n must be positive, got 0"
        );
        assert_eq!(guarded(200).unwrap_err().to_string(), "n too large: 200");
        assert!(guarded(13)
            .unwrap_err()
            .to_string()
            .contains("n != 13"));
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
