//! Foundation substrates (S1–S5 in DESIGN.md): everything the offline
//! environment forced us to build instead of pulling from crates.io.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod json_lazy;
pub mod logger;
pub mod qcheck;
pub mod rng;
pub mod stats;
