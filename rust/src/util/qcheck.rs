//! Mini property-based testing framework (proptest is unavailable offline
//! — DESIGN.md §8). Deterministic: each case is derived from a base seed,
//! and failures report the seed + a greedily-shrunk input description so
//! they can be replayed with `QCHECK_SEED`.
//!
//! Usage:
//! ```ignore
//! qcheck(200, |g| {
//!     let n = g.usize(1, 64);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     prop_assert!(xs.len() == n);
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
    trace: Vec<String>,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("usize({lo},{hi})={v}"));
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(format!("u64({lo},{hi})={v}"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.f64() * (hi - lo);
        self.trace.push(format!("f64({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        self.trace.push(format!("choose[{i}/{}]", xs.len()));
        &xs[i]
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| lo + self.rng.f64() * (hi - lo)).collect()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + self.rng.f32() * (hi - lo)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.rng.range(lo, hi)).collect()
    }

    /// Raw access for generators that need more control.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed and
/// generated-value trace on the first failure.
pub fn qcheck<F: FnMut(&mut Gen) -> PropResult>(cases: usize, mut prop: F) {
    let base_seed: u64 = std::env::var("QCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
            trace: Vec::new(),
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (case {case}, replay with QCHECK_SEED={seed}):\n  {msg}\n  \
                 inputs: [{}]",
                g.trace.join(", ")
            );
        }
    }
}

/// assert-style helpers that return Err instead of panicking, so qcheck
/// can attach seed/trace context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{}: {} != {} ({:?} vs {:?})",
                format!($($fmt)+),
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

/// Default base seed ("AUTORAC" on a phone keypad, more or less).
const DEFAULT_SEED: u64 = 0x2886_7722_u64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qcheck_passes_trivial_property() {
        qcheck(100, |g| {
            let n = g.usize(0, 100);
            prop_assert!(n <= 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn qcheck_reports_failures() {
        qcheck(50, |g| {
            let n = g.usize(0, 100);
            prop_assert!(n < 90, "n was {n}");
            Ok(())
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut first = Vec::new();
        qcheck(5, |g| {
            first.push(g.u64(0, u64::MAX - 1));
            Ok(())
        });
        let mut second = Vec::new();
        qcheck(5, |g| {
            second.push(g.u64(0, u64::MAX - 1));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn vec_bounds_hold() {
        qcheck(50, |g| {
            let n = g.usize(0, 32);
            let v = g.vec_f64(n, -2.0, 3.0);
            prop_assert_eq!(v.len(), n);
            prop_assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
            Ok(())
        });
    }
}
