//! Deterministic PRNG shared across the workspace (and, algorithm-for-
//! algorithm, with `python/compile/prng.py` so the synthetic datasets
//! generated on either side of the build boundary are bit-identical).
//!
//! Core generator: **xoshiro256\*\*** seeded through **splitmix64** — the
//! canonical construction from Blackman & Vigna. We avoid the `rand`
//! crate because the build environment is offline (see DESIGN.md §8).

/// splitmix64 step; used for seeding and as a one-shot hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash a byte string to a u64 seed (FNV-1a folded through splitmix64).
/// Used to derive stable per-name substream seeds.
pub fn seed_from_name(root: u64, name: &str) -> u64 {
    let mut s = root ^ fnv1a(0xcbf2_9ce4_8422_2325, name.as_bytes());
    splitmix64(&mut s)
}

/// Allocation-free variant of `seed_from_name(root, &format!("{prefix}{index}"))`
/// for the per-record hot path — produces IDENTICAL seeds (pinned by a
/// unit test) without building the string.
pub fn seed_from_indexed(root: u64, prefix: &str, index: usize) -> u64 {
    let h = fnv1a(0xcbf2_9ce4_8422_2325, prefix.as_bytes());
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    let mut v = index;
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    let mut s = root ^ fnv1a(h, &buf[i..]);
    splitmix64(&mut s)
}

#[inline]
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent substream for a named component.
    pub fn substream(&self, name: &str) -> Rng {
        Rng::new(seed_from_name(self.state_key(), name))
    }

    /// Stable key identifying this generator's current state (used as the
    /// root for named derived streams; mirrors python's `s[0]^s[2]`).
    pub fn state_key(&self) -> u64 {
        self.s[0] ^ self.s[2]
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value is *not* kept
    /// so the stream is position-independent and easy to mirror in python).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(α) sampler over [0, n) via precomputed CDF — models the skewed
/// embedding-access distributions that the paper's access-aware placement
/// exploits (hot rows reordered across banks).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vector — MUST match python/compile/prng.py::test vector.
    /// If either side changes, the cross-language dataset parity breaks.
    #[test]
    fn golden_xoshiro_stream() {
        let mut r = Rng::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // Independently computed from a python reference implementation of
        // splitmix64-seeded xoshiro256** (mirrored in python/compile/prng.py).
        let want = vec![
            1546998764402558742,
            6990951692964543102,
            12544586762248559009,
            17057574109182124193,
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(123);
        let n = 10u64;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(n) as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10_000; allow 10% slop
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn substreams_are_decorrelated() {
        let root = Rng::new(5);
        let mut a = root.substream("alpha");
        let mut b = root.substream("beta");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn substream_is_stable() {
        let root = Rng::new(5);
        let mut a1 = root.substream("alpha");
        let mut a2 = root.substream("alpha");
        for _ in 0..16 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(1);
        let mut head = 0usize;
        let n = 50_000;
        for _ in 0..n {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // top-1% of ids should hold a large share of the mass
        assert!(head as f64 / n as f64 > 0.3, "head share {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }
}

#[cfg(test)]
mod indexed_tests {
    use super::*;

    #[test]
    fn seed_from_indexed_matches_format_version() {
        for root in [0u64, 42, u64::MAX] {
            for idx in [0usize, 7, 99, 12345, usize::MAX / 2] {
                assert_eq!(
                    seed_from_indexed(root, "rec/", idx),
                    seed_from_name(root, &format!("rec/{idx}")),
                    "root={root} idx={idx}"
                );
            }
        }
    }
}
