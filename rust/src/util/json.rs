//! Minimal but complete JSON parser/serializer.
//!
//! serde/serde_json are unavailable in the offline build environment
//! (DESIGN.md §8), and AutoRAC's interchange needs are modest: config
//! files, architecture genomes, calibration tables, and report output.
//! Object key order is preserved (insertion order) so emitted genomes and
//! reports are stable and diffable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Maximum container nesting the parser accepts. The descent is
/// recursive, so without a cap a hostile `[[[[…` line would overflow
/// the stack instead of returning an error; 512 levels is far beyond
/// any genome/config/report this crate emits. `util::json_lazy` skips
/// cold values with the same bound so both paths agree on what is
/// "too deep".
pub const MAX_DEPTH: usize = 512;

impl Json {
    // ---------- constructors ----------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str<S: AsRef<str>>(xs: &[S]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.as_ref().to_string())).collect())
    }

    /// Insert/overwrite a key on an object (panics on non-object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path access: `j.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| crate::err!("missing/invalid number field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::err!("missing/invalid integer field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| crate::err!("missing/invalid string field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> crate::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| crate::err!("missing/invalid array field `{key}`"))
    }

    /// Vec<f64> out of an array field.
    pub fn req_f64s(&self, key: &str) -> crate::Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| crate::err!("non-number in `{key}`")))
            .collect()
    }

    /// Convert to a sorted map (for order-insensitive comparisons).
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    // ---------- serialization ----------
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---------- parsing ----------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn read_file(path: &std::path::Path) -> crate::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| crate::err!("parsing {}: {e}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string_pretty())
            .map_err(|e| crate::err!("writing {}: {e}", path.display()))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; clamp to null (callers should avoid this).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // shortest round-trip via Rust's float formatting
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting exceeds depth limit"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        self.pos += 1; // past 'u'
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parses_nested() {
        let text = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-2}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert!((v.req_f64("d").unwrap() + 0.025).abs() < 1e-12);
    }

    #[test]
    fn preserves_key_order() {
        let text = r#"{"z": 1, "a": 2, "m": 3}"#;
        let v = Json::parse(text).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn pretty_roundtrips() {
        let v = Json::from_pairs(vec![
            ("name", Json::Str("autorac".into())),
            ("dims", Json::arr_usize(&[16, 32, 64])),
            ("nested", Json::from_pairs(vec![("p", Json::Num(0.5))])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::obj()),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"abc", "{} x"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn set_overwrites_and_appends() {
        let mut v = Json::obj();
        v.set("a", Json::Num(1.0));
        v.set("b", Json::Num(2.0));
        v.set("a", Json::Num(3.0));
        assert_eq!(v.req_f64("a").unwrap(), 3.0);
        assert_eq!(v.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn integers_are_printed_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.25).to_string_compact(), "0.25");
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // hostile depth: an unclosed tower of arrays 100k deep must
        // return a parse error, not blow the recursion stack
        let hostile = "[".repeat(100_000);
        let e = Json::parse(&hostile).unwrap_err();
        assert!(e.msg.contains("depth"), "{e}");

        // a *closed* tower just past the cap errors too
        let n = MAX_DEPTH + 1;
        let closed = format!("{}{}", "[".repeat(n), "]".repeat(n));
        assert!(Json::parse(&closed).is_err());

        // comfortably inside the cap still parses
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("autorac_json_test");
        let path = dir.join("x.json");
        let v = Json::from_pairs(vec![("k", Json::arr_f64(&[1.0, 2.5]))]);
        v.write_file(&path).unwrap();
        let v2 = Json::read_file(&path).unwrap();
        assert_eq!(v, v2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
