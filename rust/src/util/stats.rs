//! Streaming statistics and histogram helpers used by the coordinator's
//! metrics, the simulator reports, and the micro-bench harness.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Running {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantiles over a retained sample (fine for bench/report sizes).
#[derive(Clone, Debug, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn new() -> Quantiles {
        Quantiles {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated quantile, q in [0,1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(!self.xs.is_empty(), "quantile of empty sample");
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = pos - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Fixed-bucket log-scale latency histogram (ns → ~s), constant memory,
/// used on the serving hot path where retaining samples is too costly.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// bucket i covers [2^i, 2^{i+1}) nanoseconds
    buckets: [u64; 48],
    count: u64,
    sum_ns: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: [0; 48],
            count: 0,
            sum_ns: 0,
        }
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(47);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Upper-bound estimate of the q-quantile (bucket upper edge).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }
}

/// Geometric mean (used for the paper-style "up to N×" summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut q = Quantiles::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            q.push(x);
        }
        assert_eq!(q.quantile(0.0), 1.0);
        assert_eq!(q.quantile(1.0), 4.0);
        assert!((q.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_quantile_bounds() {
        let mut h = LogHistogram::new();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.quantile_ns(0.5);
        assert!(p50 >= 200 && p50 <= 512, "p50 {p50}");
        let p100 = h.quantile_ns(1.0);
        assert!(p100 >= 100_000, "p100 {p100}");
    }

    #[test]
    fn log_histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record_ns(100);
        b.record_ns(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
