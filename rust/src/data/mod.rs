//! Synthetic CTR data system (S6): procedural datasets shared
//! bit-for-bit with the python build path. See profile.rs for the
//! substitution rationale (real Criteo/Avazu/KDD are offline-unavailable).

pub mod batch;
pub mod gen;
pub mod profile;

pub use batch::{make_batch, make_request_batch, Batch, Splits};
pub use gen::{dataset_key, Generator, Record, TruthModel};
pub use profile::{profile, Profile, ALL_PROFILES, DEFAULT_SEED, LATENT_K};
