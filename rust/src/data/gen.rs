//! Procedural CTR record generator — rust mirror of datagen.py.
//!
//! Every record is a pure function of `(profile, seed, index)`; see the
//! draw-order contract in datagen.py's module docstring:
//!   1. `n_dense` normals (dense features, stored as f32)
//!   2. one zipf sample per sparse field
//!   3. one normal (label noise ε)
//!   4. one f64 (label bernoulli draw)

use super::profile::{Profile, DEFAULT_SEED, LATENT_K};
use crate::util::rng::{seed_from_indexed, seed_from_name, Rng, Zipf};
use std::collections::HashMap;

/// Root key for one dataset = substream state of the global seed
/// (mirrors datagen.dataset_key).
pub fn dataset_key(seed: u64, name: &str) -> u64 {
    let root = Rng::new(seed);
    let ds = root.substream(&format!("data/{name}"));
    // python reads s[0]^s[2] of the substream
    ds.state_key()
}

/// Ground-truth click-model parameters (lazily materialized embeddings).
pub struct TruthModel {
    pub profile: Profile,
    key: u64,
    w_dense: Vec<f64>,
    u: Vec<Vec<f64>>,
    pairs: Vec<(usize, usize)>,
    pub bias: f64,
    emb_cache: HashMap<(usize, usize), Vec<f64>>,
}

impl TruthModel {
    pub fn new(profile: Profile, seed: u64) -> TruthModel {
        let key = dataset_key(seed, profile.name);
        let mut r = Rng::new(seed_from_name(key, "densew"));
        let w_dense: Vec<f64> = (0..profile.n_dense).map(|_| r.normal()).collect();
        let mut u: Vec<Vec<f64>> = Vec::with_capacity(profile.n_sparse());
        let root_k = (LATENT_K as f64).sqrt();
        for j in 0..profile.n_sparse() {
            let mut rj = Rng::new(seed_from_name(key, &format!("fieldw/{j}")));
            u.push((0..LATENT_K).map(|_| rj.normal() / root_k).collect());
        }
        let pairs = profile.pairs();
        // Bias with probit-style variance correction (mirrors datagen.py).
        let mut var = profile.noise * profile.noise;
        var += profile.gamma_dense.powi(2)
            * w_dense.iter().map(|w| w * w).sum::<f64>();
        for uj in &u {
            var += profile.gamma_field.powi(2)
                * uj.iter().map(|x| x * x).sum::<f64>()
                / LATENT_K as f64;
        }
        var += profile.gamma_pair.powi(2) * pairs.len() as f64 / LATENT_K as f64;
        let target = (profile.base_ctr / (1.0 - profile.base_ctr)).ln();
        let bias = target * (1.0 + std::f64::consts::PI * var / 8.0).sqrt();
        TruthModel {
            profile,
            key,
            w_dense,
            u,
            pairs,
            bias,
            emb_cache: HashMap::new(),
        }
    }

    /// Truth embedding for (field j, category c) — random access, cached.
    pub fn emb(&mut self, j: usize, c: usize) -> &[f64] {
        let key = self.key;
        self.emb_cache.entry((j, c)).or_insert_with(|| {
            let mut r = Rng::new(seed_from_name(key, &format!("emb/{j}/{c}")));
            let root_k = (LATENT_K as f64).sqrt();
            (0..LATENT_K).map(|_| r.normal() / root_k).collect()
        })
    }

    /// True logit for one record's features.
    ///
    /// §Perf: two-phase — fill the embedding cache first (mutable), then
    /// compute dots from immutable borrows. The original one-pass version
    /// cloned every embedding to satisfy the borrow checker (~2 allocs
    /// per field per record on the serving-eval path).
    pub fn logit(&mut self, dense: &[f32], ids: &[usize], eps: f64) -> f64 {
        for j in 0..self.profile.n_sparse() {
            self.emb(j, ids[j]);
        }
        let p = &self.profile;
        let mut z = self.bias;
        for t in 0..p.n_dense {
            z += p.gamma_dense * self.w_dense[t] * dense[t] as f64;
        }
        for (j, uj) in self.u.iter().enumerate() {
            let e = &self.emb_cache[&(j, ids[j])];
            let dot: f64 = uj.iter().zip(e).map(|(a, b)| a * b).sum();
            z += p.gamma_field * dot;
        }
        for &(j, l) in &self.pairs {
            let ej = &self.emb_cache[&(j, ids[j])];
            let el = &self.emb_cache[&(l, ids[l])];
            let dot: f64 = ej.iter().zip(el).map(|(a, b)| a * b).sum();
            z += p.gamma_pair * dot;
        }
        z + p.noise * eps
    }
}

/// One generated record.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub index: usize,
    pub dense: Vec<f32>,
    pub ids: Vec<usize>,
    pub label: bool,
}

/// Procedural generator (random access by index).
pub struct Generator {
    pub truth: TruthModel,
    key: u64,
    zipfs: Vec<Zipf>,
}

impl Generator {
    pub fn new(profile: Profile, seed: u64) -> Generator {
        let key = dataset_key(seed, profile.name);
        let zipfs = profile
            .cards
            .iter()
            .map(|&c| Zipf::new(c, profile.zipf_alpha))
            .collect();
        Generator {
            truth: TruthModel::new(profile, seed),
            key,
            zipfs,
        }
    }

    pub fn with_default_seed(profile: Profile) -> Generator {
        Generator::new(profile, DEFAULT_SEED)
    }

    pub fn profile(&self) -> &Profile {
        &self.truth.profile
    }

    /// Generate record `index` (bit-identical with datagen.Generator.record).
    pub fn record(&mut self, index: usize) -> Record {
        let (n_dense, n_sparse) =
            (self.truth.profile.n_dense, self.truth.profile.n_sparse());
        let mut r = Rng::new(seed_from_indexed(self.key, "rec/", index));
        let dense: Vec<f32> = (0..n_dense).map(|_| r.normal() as f32).collect();
        let ids: Vec<usize> = (0..n_sparse)
            .map(|j| self.zipfs[j].sample(&mut r))
            .collect();
        let eps = r.normal();
        let z = self.truth.logit(&dense, &ids, eps);
        let label = r.f64() < 1.0 / (1.0 + (-z).exp());
        Record {
            index,
            dense,
            ids,
            label,
        }
    }

    /// Features only (serving path — skips the label computation's truth
    /// embedding lookups for speed). Draw order is identical; the label
    /// draws are simply not consumed, which is safe because each record
    /// has its own substream.
    pub fn features(&mut self, index: usize) -> (Vec<f32>, Vec<usize>) {
        let (n_dense, n_sparse) =
            (self.truth.profile.n_dense, self.truth.profile.n_sparse());
        let mut r = Rng::new(seed_from_indexed(self.key, "rec/", index));
        let dense: Vec<f32> = (0..n_dense).map(|_| r.normal() as f32).collect();
        let ids: Vec<usize> = (0..n_sparse)
            .map(|j| self.zipfs[j].sample(&mut r))
            .collect();
        (dense, ids)
    }

    /// Generate a contiguous block of records.
    pub fn block(&mut self, start: usize, count: usize) -> Vec<Record> {
        (start..start + count).map(|i| self.record(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile::profile;

    #[test]
    fn records_are_deterministic_and_random_access() {
        let p = profile("criteo").unwrap();
        let mut g1 = Generator::with_default_seed(p.clone());
        let mut g2 = Generator::with_default_seed(p);
        let a = g1.record(12345);
        // access out of order on the second generator
        let _ = g2.record(7);
        let b = g2.record(12345);
        assert_eq!(a, b);
    }

    #[test]
    fn features_match_record_features() {
        let p = profile("avazu").unwrap();
        let mut g = Generator::with_default_seed(p);
        let rec = g.record(99);
        let (dense, ids) = g.features(99);
        assert_eq!(rec.dense, dense);
        assert_eq!(rec.ids, ids);
    }

    #[test]
    fn ids_respect_cardinalities() {
        let p = profile("kdd").unwrap();
        let cards = p.cards.clone();
        let mut g = Generator::with_default_seed(p);
        for rec in g.block(0, 500) {
            for (j, &id) in rec.ids.iter().enumerate() {
                assert!(id < cards[j], "field {j} id {id} >= {}", cards[j]);
            }
        }
    }

    #[test]
    fn ctr_is_near_profile_target() {
        for name in ["criteo", "avazu", "kdd"] {
            let p = profile(name).unwrap();
            let target = p.base_ctr;
            let mut g = Generator::with_default_seed(p);
            let n = 3000;
            let clicks = g.block(0, n).iter().filter(|r| r.label).count();
            let ctr = clicks as f64 / n as f64;
            // probit correction is approximate; allow a generous band
            assert!(
                ctr > target * 0.5 && ctr < target * 2.2,
                "{name}: ctr {ctr} vs target {target}"
            );
        }
    }

    #[test]
    fn different_seeds_differ() {
        let p = profile("criteo").unwrap();
        let mut g1 = Generator::new(p.clone(), 1);
        let mut g2 = Generator::new(p, 2);
        assert_ne!(g1.record(0), g2.record(0));
    }

    #[test]
    fn zipf_head_is_hot() {
        let p = profile("criteo").unwrap();
        let mut g = Generator::with_default_seed(p);
        let recs = g.block(0, 2000);
        let head = recs.iter().filter(|r| r.ids[0] < 5).count();
        assert!(head as f64 / 2000.0 > 0.4, "head share {head}");
    }
}
