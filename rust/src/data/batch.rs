//! Batched feature matrices for the serving / eval / training paths.
//!
//! The coordinator and benches consume contiguous row-major buffers that
//! can be handed to PJRT literals without copying per element.

use super::gen::Generator;

/// A dense batch of records, row-major, ready for the runtime.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    /// [batch × max(n_dense,1)] — zero padded when the profile has no
    /// dense features (matches the model artifact's input contract).
    pub dense: Vec<f32>,
    pub n_dense: usize,
    /// [batch × n_sparse] feature ids.
    pub ids: Vec<i32>,
    pub n_sparse: usize,
    /// labels (present for eval/training batches)
    pub labels: Vec<f32>,
    /// original record indices
    pub indices: Vec<usize>,
}

impl Batch {
    pub fn dense_stride(&self) -> usize {
        self.n_dense.max(1)
    }

    pub fn dense_row(&self, i: usize) -> &[f32] {
        let s = self.dense_stride();
        &self.dense[i * s..(i + 1) * s]
    }

    pub fn ids_row(&self, i: usize) -> &[i32] {
        &self.ids[i * self.n_sparse..(i + 1) * self.n_sparse]
    }
}

/// Materialize records [start, start+count) as a batch (with labels).
pub fn make_batch(gen: &mut Generator, start: usize, count: usize) -> Batch {
    let n_dense = gen.profile().n_dense;
    let n_sparse = gen.profile().n_sparse();
    let stride = n_dense.max(1);
    let mut dense = vec![0f32; count * stride];
    let mut ids = Vec::with_capacity(count * n_sparse);
    let mut labels = Vec::with_capacity(count);
    let mut indices = Vec::with_capacity(count);
    for i in 0..count {
        let rec = gen.record(start + i);
        dense[i * stride..i * stride + n_dense].copy_from_slice(&rec.dense);
        ids.extend(rec.ids.iter().map(|&x| x as i32));
        labels.push(if rec.label { 1.0 } else { 0.0 });
        indices.push(start + i);
    }
    Batch {
        batch: count,
        dense,
        n_dense,
        ids,
        n_sparse,
        labels,
        indices,
    }
}

/// Features-only batch (serving path: labels are unknown at request time).
pub fn make_request_batch(gen: &mut Generator, start: usize, count: usize) -> Batch {
    let n_dense = gen.profile().n_dense;
    let n_sparse = gen.profile().n_sparse();
    let stride = n_dense.max(1);
    let mut dense = vec![0f32; count * stride];
    let mut ids = Vec::with_capacity(count * n_sparse);
    let mut indices = Vec::with_capacity(count);
    for i in 0..count {
        let (d, s) = gen.features(start + i);
        dense[i * stride..i * stride + n_dense].copy_from_slice(&d);
        ids.extend(s.iter().map(|&x| x as i32));
        indices.push(start + i);
    }
    Batch {
        batch: count,
        dense,
        n_dense,
        ids,
        n_sparse,
        labels: Vec::new(),
        indices,
    }
}

/// Split layout shared with python (train 80k / val 10k / test 10k by
/// default; python env AUTORAC_*_N overrides only affect the build-time
/// calibration, not the serving-side contract).
#[derive(Clone, Copy, Debug)]
pub struct Splits {
    pub train: usize,
    pub val: usize,
    pub test: usize,
}

impl Default for Splits {
    fn default() -> Self {
        Splits {
            train: 80_000,
            val: 10_000,
            test: 10_000,
        }
    }
}

impl Splits {
    pub fn offset(&self, split: &str) -> usize {
        match split {
            "train" => 0,
            "val" => self.train,
            "test" => self.train + self.val,
            _ => panic!("unknown split {split}"),
        }
    }

    pub fn len(&self, split: &str) -> usize {
        match split {
            "train" => self.train,
            "val" => self.val,
            "test" => self.test,
            _ => panic!("unknown split {split}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profile::profile;

    #[test]
    fn batch_layout_is_row_major() {
        let p = profile("criteo").unwrap();
        let mut g = Generator::with_default_seed(p);
        let b = make_batch(&mut g, 0, 4);
        assert_eq!(b.batch, 4);
        assert_eq!(b.dense.len(), 4 * 13);
        assert_eq!(b.ids.len(), 4 * 26);
        assert_eq!(b.labels.len(), 4);
        let rec = g.record(2);
        assert_eq!(b.dense_row(2), rec.dense.as_slice());
        assert_eq!(
            b.ids_row(2),
            rec.ids.iter().map(|&x| x as i32).collect::<Vec<_>>().as_slice()
        );
    }

    #[test]
    fn avazu_dense_is_padded_to_one() {
        let p = profile("avazu").unwrap();
        let mut g = Generator::with_default_seed(p);
        let b = make_batch(&mut g, 0, 3);
        assert_eq!(b.n_dense, 0);
        assert_eq!(b.dense_stride(), 1);
        assert!(b.dense.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn request_batch_matches_labeled_batch_features() {
        let p = profile("kdd").unwrap();
        let mut g = Generator::with_default_seed(p);
        let a = make_batch(&mut g, 10, 5);
        let b = make_request_batch(&mut g, 10, 5);
        assert_eq!(a.dense, b.dense);
        assert_eq!(a.ids, b.ids);
        assert!(b.labels.is_empty());
    }

    #[test]
    fn splits_layout() {
        let s = Splits::default();
        assert_eq!(s.offset("test"), 90_000);
        assert_eq!(s.len("val"), 10_000);
    }
}
