//! Dataset profiles — rust mirror of `python/compile/datagen.py::PROFILES`.
//!
//! Each profile models one public CTR benchmark (Criteo / Avazu / KDD Cup
//! 2012) that is unavailable offline: field counts and statistics mirror
//! the real dataset, and records are a pure function of
//! `(profile, seed, index)` via the shared PRNG. ANY change here must be
//! mirrored in datagen.py; `rust/tests/data_parity.rs` pins the contract
//! against golden records exported at build time.

/// Latent dimensionality of the ground-truth click model.
pub const LATENT_K: usize = 8;

/// Default dataset seed (GLSVLSI'25 opening day; same as python).
pub const DEFAULT_SEED: u64 = 20_250_630;

#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub n_dense: usize,
    pub cards: Vec<usize>,
    pub zipf_alpha: f64,
    pub base_ctr: f64,
    pub gamma_dense: f64,
    pub gamma_field: f64,
    pub gamma_pair: f64,
    pub noise: f64,
}

impl Profile {
    pub fn n_sparse(&self) -> usize {
        self.cards.len()
    }

    /// Interacting field pairs — deterministic rule `(31j + l) % 7 == 0`
    /// over j < l (shared with python).
    pub fn pairs(&self) -> Vec<(usize, usize)> {
        let n = self.n_sparse();
        let mut out = Vec::new();
        for j in 0..n {
            for l in (j + 1)..n {
                if (31 * j + l) % 7 == 0 {
                    out.push((j, l));
                }
            }
        }
        out
    }
}

/// Per-field cardinalities: `min(150 · 1.45^(j%8), 2000)` (shared rule).
fn cards(n: usize) -> Vec<usize> {
    (0..n)
        .map(|j| {
            let c = (150.0 * 1.45f64.powi((j % 8) as i32)) as usize;
            c.min(2000)
        })
        .collect()
}

/// Look up a profile by name ("criteo" | "avazu" | "kdd").
pub fn profile(name: &str) -> crate::Result<Profile> {
    Ok(match name {
        "criteo" => Profile {
            name: "criteo",
            n_dense: 13,
            cards: cards(26),
            zipf_alpha: 1.25,
            base_ctr: 0.256,
            gamma_dense: 0.3,
            gamma_field: 0.45,
            gamma_pair: 0.55,
            noise: 0.6,
        },
        "avazu" => Profile {
            name: "avazu",
            n_dense: 0,
            cards: cards(22),
            zipf_alpha: 1.30,
            base_ctr: 0.17,
            gamma_dense: 0.0,
            gamma_field: 0.5,
            gamma_pair: 0.55,
            noise: 0.6,
        },
        "kdd" => Profile {
            name: "kdd",
            n_dense: 3,
            cards: cards(10),
            zipf_alpha: 1.35,
            base_ctr: 0.045,
            gamma_dense: 0.25,
            gamma_field: 0.5,
            gamma_pair: 0.6,
            noise: 0.5,
        },
        other => crate::bail!("unknown dataset profile `{other}`"),
    })
}

pub const ALL_PROFILES: [&str; 3] = ["criteo", "avazu", "kdd"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_counts_mirror_real_benchmarks() {
        let c = profile("criteo").unwrap();
        assert_eq!((c.n_dense, c.n_sparse()), (13, 26));
        let a = profile("avazu").unwrap();
        assert_eq!((a.n_dense, a.n_sparse()), (0, 22));
        let k = profile("kdd").unwrap();
        assert_eq!((k.n_dense, k.n_sparse()), (3, 10));
    }

    #[test]
    fn cards_match_python_rule() {
        let c = profile("criteo").unwrap();
        assert_eq!(c.cards[0], 150);
        assert_eq!(c.cards[1], (150.0 * 1.45f64) as usize);
        assert!(c.cards.iter().all(|&x| x <= 2000));
        // rule repeats every 8 fields
        assert_eq!(c.cards[8], c.cards[0]);
    }

    #[test]
    fn pair_rule_is_stable() {
        let c = profile("criteo").unwrap();
        let pairs = c.pairs();
        assert!(!pairs.is_empty());
        for &(j, l) in &pairs {
            assert!(j < l);
            assert_eq!((31 * j + l) % 7, 0);
        }
    }

    #[test]
    fn unknown_profile_errors() {
        assert!(profile("movielens").is_err());
    }
}
