//! Technology parameters for the ReRAM PIM cost model.
//!
//! The paper characterizes ReRAM with MNSIM 2.0 and buffers with CACTI 7
//! at 32 nm (§4.1). Neither tool ships in this offline environment, so
//! this module holds an analytical parameter set assembled from the
//! published literature those tools encode:
//!
//! * crossbar / cell geometry and read/write pulses — MNSIM 2.0 (Zhu'20),
//!   ISAAC (Shafiee ISCA'16), PRIME (Chi ISCA'16) ranges;
//! * ADC — 8-bit SAR @ 1.2 GS/s ≈ 2 mW, area 0.0012 mm² (ISAAC), scaled
//!   ~2× per bit (power/area) as in MNSIM's ADC table;
//! * DAC — 1-bit drivers are ~free; multi-bit scale linearly;
//! * transposable array & MBSA — Wan ISSCC'20 / Zheng DAC'23 style
//!   overheads relative to a standard array.
//!
//! Absolute numbers carry the usual modeling uncertainty; Table 3
//! reports *ratios* between designs that share these constants, which is
//! what the substitution preserves (DESIGN.md §1).
//!
//! Units everywhere: latency **ns**, energy **pJ**, area **mm²**,
//! power derived as pJ/ns = mW.

/// One peripheral/array component's steady-state characteristics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Component {
    /// latency of one operation (ns)
    pub latency_ns: f64,
    /// energy of one operation (pJ)
    pub energy_pj: f64,
    /// silicon area (mm²)
    pub area_mm2: f64,
    /// static leakage power (mW)
    pub leakage_mw: f64,
}

/// Full technology parameter set (32 nm defaults).
#[derive(Clone, Debug)]
pub struct TechParams {
    /// feature size (nm) — informational; constants below are at 32 nm
    pub f_nm: f64,
    /// ReRAM cell area in F² (4F² crosspoint)
    pub cell_area_f2: f64,
    /// one analog read cycle of a crossbar (wordline charge + settle), ns
    pub xbar_read_ns: f64,
    /// read energy per active cell per cycle, pJ
    pub cell_read_pj: f64,
    /// SET/RESET programming pulse, ns (per row written in parallel)
    pub write_pulse_ns: f64,
    /// write energy per cell programmed, pJ
    pub cell_write_pj: f64,
    /// wordline driver (1-bit DAC) energy per line per cycle, pJ
    pub driver_pj: f64,
    /// sample-and-hold per column, pJ per cycle
    pub sh_pj: f64,
    /// shift-and-add digital accumulate per column result, pJ
    pub shift_add_pj: f64,
    /// shift-and-add latency per partial, ns (pipelined)
    pub shift_add_ns: f64,
    /// 8-bit reference ADC: per-conversion latency/energy and area
    pub adc8_ns: f64,
    pub adc8_pj: f64,
    pub adc8_area_mm2: f64,
    /// how many columns share one ADC (time-multiplexed)
    pub cols_per_adc: usize,
    /// MBSA: energy per bit-AND-accumulate lane per cycle, pJ
    pub mbsa_lane_pj: f64,
    /// MBSA cycle, ns
    pub mbsa_cycle_ns: f64,
    /// functional unit (activation etc.) per element, pJ / ns
    pub func_pj: f64,
    pub func_ns: f64,
    /// NoC/bus energy per byte moved between tiles, pJ
    pub noc_byte_pj: f64,
    /// NoC per-hop latency, ns
    pub noc_hop_ns: f64,
    /// eDRAM/SRAM buffer base parameters (CACTI-like fits; buffer.rs)
    pub buf_pj_per_byte: f64,
    pub buf_base_ns: f64,
    /// whole-chip static/infrastructure power density (clock tree, NoC
    /// routers, controller, imperfect power gating), mW per mm² —
    /// calibrated so a full tile array lands near ISAAC's ~0.76 W/mm²
    pub static_mw_per_mm2: f64,
}

impl Default for TechParams {
    fn default() -> Self {
        TechParams {
            f_nm: 32.0,
            cell_area_f2: 4.0,
            xbar_read_ns: 3.1,      // ISAAC: ~100ns / 32 bit-serial steps
            cell_read_pj: 0.0002,   // ~0.2 fJ per cell per cycle
            write_pulse_ns: 50.8,   // SET/RESET pulse (MNSIM range 50–100)
            cell_write_pj: 0.94,    // ~1 pJ/cell program
            driver_pj: 0.0035,
            sh_pj: 0.001,
            shift_add_pj: 0.023,
            shift_add_ns: 0.25,
            adc8_ns: 0.83,          // 1.2 GS/s SAR
            adc8_pj: 1.67,          // 2 mW at 1.2 GS/s
            adc8_area_mm2: 0.0012,
            cols_per_adc: 8,
            mbsa_lane_pj: 0.0051,
            mbsa_cycle_ns: 1.0,
            func_pj: 0.12,
            func_ns: 0.5,
            noc_byte_pj: 1.2,
            noc_hop_ns: 1.6,
            buf_pj_per_byte: 0.85,
            buf_base_ns: 0.9,
            static_mw_per_mm2: 420.0,
        }
    }
}

impl TechParams {
    /// ADC characteristics at a given resolution. MNSIM-style scaling:
    /// energy/area ≈ ×2 per extra bit above (or below) the 8-bit
    /// reference; latency grows ~linearly with bits (SAR).
    pub fn adc(&self, bits: usize) -> Component {
        let rel = 2f64.powi(bits as i32 - 8);
        Component {
            latency_ns: self.adc8_ns * bits as f64 / 8.0,
            energy_pj: self.adc8_pj * rel,
            area_mm2: self.adc8_area_mm2 * rel,
            leakage_mw: 0.02 * rel,
        }
    }

    /// DAC / wordline driver at a given resolution (per line, per cycle).
    pub fn dac(&self, bits: usize) -> Component {
        let rel = bits as f64; // linear in levels driven
        Component {
            latency_ns: 0.2 * rel,
            energy_pj: self.driver_pj * rel,
            area_mm2: 1.7e-7 * rel,
            leakage_mw: 1e-5 * rel,
        }
    }

    /// Raw crossbar array area for r×c cells (mm²), cell + 30% wiring.
    pub fn xbar_area_mm2(&self, rows: usize, cols: usize) -> f64 {
        let f_m = self.f_nm * 1e-9;
        let cell_m2 = self.cell_area_f2 * f_m * f_m;
        let mm2 = cell_m2 * 1e6; // m² → mm²
        1.3 * mm2 * rows as f64 * cols as f64
    }

    /// One bit-serial analog read cycle over an r×c crossbar:
    /// latency (wordline + settle) and energy (cells + drivers + S/H).
    pub fn xbar_read_cycle(&self, rows: usize, cols: usize, dac_bits: usize) -> Component {
        let dac = self.dac(dac_bits);
        Component {
            latency_ns: self.xbar_read_ns + dac.latency_ns,
            energy_pj: self.cell_read_pj * (rows * cols) as f64
                + dac.energy_pj * rows as f64
                + self.sh_pj * cols as f64,
            area_mm2: 0.0,
            leakage_mw: 0.0,
        }
    }

    /// Program `rows` × `cols` cells (row-parallel writes): one pulse per
    /// row; energy per cell. This is the cost the DP/FM engines pay at
    /// *inference* time because their operands are activations (§3.2).
    pub fn xbar_write(&self, rows: usize, cols: usize) -> Component {
        Component {
            latency_ns: self.write_pulse_ns * rows as f64,
            energy_pj: self.cell_write_pj * (rows * cols) as f64,
            area_mm2: 0.0,
            leakage_mw: 0.0,
        }
    }

    /// Column-parallel write into a *transposed* array (Wan ISSCC'20):
    /// one vector programs as a single column pulse — this is what kills
    /// the row-serial buffering of the naive FM mapping.
    pub fn xbar_write_transposed(&self, rows: usize, cols: usize) -> Component {
        Component {
            latency_ns: self.write_pulse_ns, // one column pulse per vector
            energy_pj: self.cell_write_pj * (rows * cols) as f64 * 1.15,
            area_mm2: 0.0,
            leakage_mw: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_scaling_is_monotone() {
        let t = TechParams::default();
        let a4 = t.adc(4);
        let a6 = t.adc(6);
        let a8 = t.adc(8);
        assert!(a4.energy_pj < a6.energy_pj && a6.energy_pj < a8.energy_pj);
        assert!(a4.area_mm2 < a8.area_mm2);
        assert!(a4.latency_ns < a8.latency_ns);
        assert!((a8.energy_pj - t.adc8_pj).abs() < 1e-12);
    }

    #[test]
    fn xbar_area_scales_with_cells() {
        let t = TechParams::default();
        let a64 = t.xbar_area_mm2(64, 64);
        let a16 = t.xbar_area_mm2(16, 16);
        assert!((a64 / a16 - 16.0).abs() < 1e-9);
        // 64×64 @32nm ≈ 2.2e-5 mm² — sanity versus ISAAC-scale numbers
        assert!(a64 > 1e-6 && a64 < 1e-3, "{a64}");
    }

    #[test]
    fn writes_cost_more_than_reads() {
        let t = TechParams::default();
        let r = t.xbar_read_cycle(64, 64, 1);
        let w = t.xbar_write(64, 64);
        assert!(w.energy_pj > 100.0 * r.energy_pj);
        assert!(w.latency_ns > 100.0 * r.latency_ns);
    }

    #[test]
    fn transposed_write_is_column_parallel() {
        let t = TechParams::default();
        let row_serial = t.xbar_write(17, 64); // 17 vectors, row-by-row
        let transposed = t.xbar_write_transposed(64, 17);
        assert!(transposed.latency_ns < row_serial.latency_ns / 10.0);
    }
}
