//! Functional ReRAM crossbar model (paper Fig. 3a).
//!
//! Computes exactly what the L1 Pallas kernel computes
//! (`python/compile/kernels/crossbar_mvm.py` ⇔ `ref.py`): bit-serial
//! offset-binary MVM with per-row-tile ADC quantization and digital
//! shift-add recombination. `rust/tests/kernel_parity.rs` closes the
//! triangle against the compiled HLO artifact.
//!
//! Also counts the analog-cycle / conversion / write events so the cost
//! layer (mapping + sim) can price an operation without re-simulating.

use super::config::PimConfig;

/// Dense row-major i32 matrix (small helper; the sizes here are
/// crossbar-tile scale, no BLAS needed).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> MatI32 {
        MatI32 {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<i32>>) -> MatI32 {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c));
        MatI32 {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Event counts from one functional pass (consumed by the cost layer).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct XbarActivity {
    /// analog read cycles (each = one DAC step over one row tile)
    pub read_cycles: u64,
    /// ADC conversions performed
    pub adc_conversions: u64,
    /// digital shift-add operations
    pub shift_adds: u64,
    /// cells touched by programming
    pub cells_written: u64,
    /// row-pulses of programming
    pub write_pulses: u64,
    /// (tile, batch-row) MVMs whose ABFT checksum disagreed (S34);
    /// always 0 on clean hardware and on the per-vector reference, so
    /// the bit-identity `PartialEq` contract is unchanged
    pub faulty_tiles: u64,
}

impl XbarActivity {
    pub fn merge(&mut self, o: &XbarActivity) {
        self.read_cycles += o.read_cycles;
        self.adc_conversions += o.adc_conversions;
        self.shift_adds += o.shift_adds;
        self.cells_written += o.cells_written;
        self.write_pulses += o.write_pulses;
        self.faulty_tiles += o.faulty_tiles;
    }
}

/// ADC transfer function: mid-tread quantize + full-scale clip.
/// Mirrors ref.py::adc_transfer.
#[inline]
pub fn adc_transfer(v: i64, cfg: &PimConfig) -> i64 {
    let levels = (1i64 << cfg.adc_bits) - 1;
    let step = cfg.adc_step();
    let code = ((v + step / 2) / step).clamp(0, levels);
    code * step
}

/// A programmed crossbar bank holding one signed weight matrix as a
/// differential (positive/negative) pair of bit-plane stacks.
pub struct ProgrammedXbar {
    pub cfg: PimConfig,
    /// `[n_planes]` matrices of plane values in `[0, 2^cell_bits)`
    pos_planes: Vec<MatI32>,
    neg_planes: Vec<MatI32>,
    pub k: usize,
    pub n: usize,
    pub program_activity: XbarActivity,
    /// input-independent offset-correction accumulator (the dummy-row
    /// read of the all-`offset` vector), computed once at program time
    /// (§Perf: was a second full `mvm_raw` per `mvm_corrected` call)
    offset_corr: Vec<i64>,
}

impl ProgrammedXbar {
    /// Program a signed integer weight matrix (values within w_bits).
    /// K is padded internally to a multiple of cfg.xbar.
    pub fn program(wq: &MatI32, cfg: PimConfig) -> ProgrammedXbar {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        assert!(
            wq.data.iter().all(|&w| w.abs() <= wmax),
            "weights exceed w_bits range"
        );
        let k_pad = wq.rows.div_ceil(cfg.xbar) * cfg.xbar;
        let cell_mask = (1i32 << cfg.cell_bits) - 1;
        let mut pos_planes = Vec::with_capacity(cfg.n_planes());
        let mut neg_planes = Vec::with_capacity(cfg.n_planes());
        for p in 0..cfg.n_planes() {
            let mut pp = MatI32::zeros(k_pad, wq.cols);
            let mut np = MatI32::zeros(k_pad, wq.cols);
            for r in 0..wq.rows {
                for c in 0..wq.cols {
                    let w = wq.at(r, c);
                    let (wp, wn) = (w.max(0), (-w).max(0));
                    pp.set(r, c, (wp >> (p * cfg.cell_bits)) & cell_mask);
                    np.set(r, c, (wn >> (p * cfg.cell_bits)) & cell_mask);
                }
            }
            pos_planes.push(pp);
            neg_planes.push(np);
        }
        // Programming cost: every plane of both banks, row-parallel.
        let planes = cfg.n_planes() as u64;
        let program_activity = XbarActivity {
            cells_written: 2 * planes * (k_pad * wq.cols) as u64,
            write_pulses: 2 * planes * k_pad as u64,
            ..Default::default()
        };
        let mut xbar = ProgrammedXbar {
            cfg,
            pos_planes,
            neg_planes,
            k: k_pad,
            n: wq.cols,
            program_activity,
            offset_corr: Vec::new(),
        };
        // Dummy-row read: the correction term depends only on the
        // programmed weights, so simulate it once here (with throwaway
        // counters — programming is not a serving-time read).
        let offset = 1i32 << (xbar.cfg.x_bits - 1);
        let ones = vec![offset; k_pad];
        let mut act = XbarActivity::default();
        xbar.offset_corr = xbar.mvm_raw(&ones, &mut act);
        xbar
    }

    /// The cached input-independent offset-correction vector.
    pub fn offset_correction(&self) -> &[i64] {
        &self.offset_corr
    }

    /// Assert a [`super::fault::FaultMap`]'s stuck cells on the plane
    /// stacks — the reference-side mirror of the kernel's packed-array
    /// injection, so fault parity is testable differentially: a faulty
    /// `BatchedXbar` (pre-repair) must still match a faulty reference
    /// bit for bit via `mvm_raw`. Site translation: packed block
    /// `(p·2+s)·cell_bits+wb` is bit `wb` of plane `p` of the
    /// positive (`s==0`) or negative stack; word·64+bit is the tile
    /// row. Checksum-column sites and spare-slot tiles have no
    /// reference counterpart and are skipped. The cached offset
    /// correction is deliberately left at the pristine calibration
    /// (same contract as the kernel), so compare via `mvm_raw`, not
    /// `mvm_corrected`.
    pub fn apply_faults(&mut self, map: &super::fault::FaultMap) {
        let cell = self.cfg.cell_bits;
        let n_tiles = self.k / self.cfg.xbar;
        for (t, sites) in map.tiles.iter().enumerate().take(n_tiles) {
            for site in sites {
                if site.col == super::fault::CHK_COL {
                    continue;
                }
                let block = site.block as usize;
                let (p, rem) = (block / (2 * cell), block % (2 * cell));
                let (s, wb) = (rem / cell, rem % cell);
                let planes = if s == 0 {
                    &mut self.pos_planes
                } else {
                    &mut self.neg_planes
                };
                let plane = &mut planes[p];
                for bit in 0..64usize {
                    let stuck1 = site.set >> bit & 1 == 1;
                    let stuck0 = site.clear >> bit & 1 == 1;
                    if !stuck1 && !stuck0 {
                        continue;
                    }
                    let i = site.word as usize * 64 + bit;
                    debug_assert!(i < self.cfg.xbar, "pad bit holds no cell");
                    let r = t * self.cfg.xbar + i;
                    let col = site.col as usize;
                    let v = plane.at(r, col);
                    let nv = if stuck1 { v | (1 << wb) } else { v & !(1 << wb) };
                    plane.set(r, col, nv);
                }
            }
        }
    }

    /// Bit-serial MVM of one offset-binary input vector (values in
    /// [0, 2^x_bits)); returns the raw integer accumulator (pre-offset
    /// correction). Mirrors ref.py::pim_mvm_int_ref for B=1.
    pub fn mvm_raw(&self, x_u: &[i32], activity: &mut XbarActivity) -> Vec<i64> {
        let cfg = &self.cfg;
        assert!(x_u.len() <= self.k, "input longer than programmed K");
        let dac_mask = (1i32 << cfg.dac_bits) - 1;
        let n_tiles = self.k / cfg.xbar;
        let mut acc = vec![0i64; self.n];
        // §Perf: row-major accumulation with the chunk bits hoisted per
        // row (was column-major with per-element re-extraction — 8.6×).
        let mut partials = vec![0i64; self.n];
        let mut chunk_buf = vec![0i64; cfg.xbar];
        for t in 0..n_tiles {
            let r0 = t * cfg.xbar;
            let r1 = (r0 + cfg.xbar).min(x_u.len());
            for c in 0..cfg.n_chunks() {
                activity.read_cycles += 1;
                let cshift = c * cfg.dac_bits;
                for (i, &x) in x_u[r0..r1].iter().enumerate() {
                    chunk_buf[i] = ((x >> cshift) & dac_mask) as i64;
                }
                for p in 0..cfg.n_planes() {
                    let shift = (cshift + p * cfg.cell_bits) as u32;
                    for (planes, sign) in
                        [(&self.pos_planes, 1i64), (&self.neg_planes, -1i64)]
                    {
                        let plane = &planes[p];
                        partials.iter_mut().for_each(|v| *v = 0);
                        for (i, r) in (r0..r1).enumerate() {
                            let chunk = chunk_buf[i];
                            if chunk == 0 {
                                continue; // zero wordline drives no current
                            }
                            let row = plane.row(r);
                            for (col, &w) in row.iter().enumerate() {
                                partials[col] += chunk * w as i64;
                            }
                        }
                        activity.adc_conversions += self.n as u64;
                        activity.shift_adds += self.n as u64;
                        for (a, &partial) in acc.iter_mut().zip(partials.iter()) {
                            *a += sign * (adc_transfer(partial, cfg) << shift);
                        }
                    }
                }
            }
        }
        acc
    }

    /// Full linear op: quantized activations in, integer result with the
    /// offset correction applied (the dummy-row read). Matches
    /// ref.py::pim_linear_ref's integer core.
    pub fn mvm_corrected(&self, x_u: &[i32], activity: &mut XbarActivity) -> Vec<i64> {
        let acc = self.mvm_raw(x_u, activity);
        #[cfg(test)]
        {
            // The cached vector must always equal a fresh dummy-row read.
            let offset = 1i32 << (self.cfg.x_bits - 1);
            let ones = vec![offset; self.k];
            let mut act = XbarActivity::default();
            assert_eq!(
                self.mvm_raw(&ones, &mut act),
                self.offset_corr,
                "cached offset correction diverged from recomputation"
            );
        }
        acc.iter()
            .zip(&self.offset_corr)
            .map(|(a, c)| a - c)
            .collect()
    }
}

/// Symmetric per-tensor weight quantization (ref.py::quant_sym).
pub fn quant_sym(w: &[f32], bits: usize) -> (Vec<i32>, f32) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let amax = w.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    let scale = amax / qmax;
    let q = w
        .iter()
        .map(|&x| (x / scale).round().clamp(-qmax, qmax) as i32)
        .collect();
    (q, scale)
}

/// Offset-binary activation quantization (ref.py::quant_act_u8).
pub fn quant_act(x: &[f32], bits: usize) -> (Vec<i32>, f32) {
    let mut q = Vec::new();
    let scale = quant_act_into(x, bits, &mut q);
    (q, scale)
}

/// [`quant_act`] into a caller-owned buffer (cleared first) — the
/// allocation-free variant the batched serving path uses. Returns the
/// per-vector scale.
pub fn quant_act_into(x: &[f32], bits: usize, out: &mut Vec<i32>) -> f32 {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let offset = 1i32 << (bits - 1);
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let scale = amax / qmax;
    out.clear();
    out.extend(
        x.iter()
            .map(|&v| (v / scale).round().clamp(-qmax, qmax) as i32 + offset),
    );
    scale
}

/// Float-in/float-out PIM linear for one vector (ref.py::pim_linear_ref,
/// B=1): the functional contract the HLO artifact also satisfies.
pub fn pim_linear_vec(
    x: &[f32],
    w_scale: f32,
    xbar: &ProgrammedXbar,
    activity: &mut XbarActivity,
) -> Vec<f32> {
    let (mut x_u, x_scale) = quant_act(x, xbar.cfg.x_bits);
    x_u.resize(xbar.k, 1i32 << (xbar.cfg.x_bits - 1)); // pad at offset (=0.0)
    let out = xbar.mvm_corrected(&x_u, activity);
    out.iter()
        .map(|&v| v as f32 * x_scale * w_scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
        let mut m = MatI32::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let v = rng.below((2 * wmax + 1) as u64) as i32 - wmax;
                m.set(r, c, v);
            }
        }
        m
    }

    fn int_matmul(x: &[i32], w: &MatI32) -> Vec<i64> {
        (0..w.cols)
            .map(|c| {
                (0..w.rows)
                    .map(|r| x.get(r).copied().unwrap_or(0) as i64 * w.at(r, c) as i64)
                    .sum()
            })
            .collect()
    }

    #[test]
    fn feasible_config_is_bit_exact_with_integer_matmul() {
        // This is the same invariant the python test suite pins:
        // feasible ⇒ lossless ADC ⇒ crossbar MVM ≡ integer matmul.
        let mut rng = Rng::new(42);
        for cfg in PimConfig::enumerate_feasible() {
            let k = cfg.xbar * 2 - 3; // force padding
            let wq = random_mat(&mut rng, k, 9, (1 << (cfg.w_bits - 1)) - 1);
            let xbar = ProgrammedXbar::program(&wq, cfg);
            let x_u: Vec<i32> = (0..k)
                .map(|_| rng.below(1 << cfg.x_bits) as i32)
                .collect();
            let mut padded = x_u.clone();
            padded.resize(xbar.k, 0);
            let mut act = XbarActivity::default();
            let got = xbar.mvm_raw(&padded, &mut act);
            let want = int_matmul(&padded, &wq);
            assert_eq!(got, want, "cfg {cfg:?}");
            assert!(act.read_cycles > 0 && act.adc_conversions > 0);
        }
    }

    #[test]
    fn infeasible_config_loses_information() {
        let cfg = PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        };
        assert!(!cfg.feasible());
        let mut rng = Rng::new(7);
        let wq = random_mat(&mut rng, 64, 8, 127);
        let xbar = ProgrammedXbar::program(&wq, cfg);
        let x_u: Vec<i32> = (0..64).map(|_| rng.below(256) as i32).collect();
        let mut act = XbarActivity::default();
        let got = xbar.mvm_raw(&x_u, &mut act);
        let want = int_matmul(&x_u, &wq);
        assert_ne!(got, want);
    }

    #[test]
    fn offset_correction_recovers_signed_products() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(3);
        let wq = random_mat(&mut rng, cfg.xbar, 5, 127);
        let xbar = ProgrammedXbar::program(&wq, cfg);
        // signed activations in offset-binary
        let xs: Vec<i32> = (0..cfg.xbar).map(|_| rng.below(255) as i32 - 127).collect();
        let x_u: Vec<i32> = xs.iter().map(|&v| v + 128).collect();
        let mut act = XbarActivity::default();
        let got = xbar.mvm_corrected(&x_u, &mut act);
        let want = int_matmul(&xs, &wq);
        assert_eq!(got, want);
    }

    #[test]
    fn pim_linear_vec_close_to_fp() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(11);
        let k = 100;
        let n = 12;
        let wf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let (wq_flat, w_scale) = quant_sym(&wf, cfg.w_bits);
        let wq = MatI32 {
            rows: k,
            cols: n,
            data: wq_flat,
        };
        let xbar = ProgrammedXbar::program(&wq, cfg);
        let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let mut act = XbarActivity::default();
        let got = pim_linear_vec(&x, w_scale, &xbar, &mut act);
        // fp reference
        for c in 0..n {
            let want: f32 = (0..k).map(|r| x[r] * wf[r * n + c]).sum();
            let err = (got[c] - want).abs();
            assert!(err < 0.35, "col {c}: got {} want {want}", got[c]);
        }
    }

    #[test]
    fn program_activity_counts_cells() {
        let cfg = PimConfig::default(); // planes = 4
        let wq = MatI32::zeros(64, 10);
        let xbar = ProgrammedXbar::program(&wq, cfg);
        assert_eq!(xbar.program_activity.cells_written, 2 * 4 * 64 * 10);
        assert_eq!(xbar.program_activity.write_pulses, 2 * 4 * 64);
    }

    #[test]
    fn weights_out_of_range_panic() {
        let cfg = PimConfig::default().with_wbits(4);
        let mut wq = MatI32::zeros(4, 4);
        wq.set(0, 0, 100); // > 7
        let r = std::panic::catch_unwind(|| ProgrammedXbar::program(&wq, cfg));
        assert!(r.is_err());
    }
}
