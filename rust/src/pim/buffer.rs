//! CACTI-like on-chip buffer model (paper §4.1 uses CACTI 7 @ 32 nm).
//!
//! CACTI itself is unavailable offline; this module implements the
//! standard log-linear fits of SRAM access energy/latency/area versus
//! capacity that CACTI's output tables exhibit at a fixed technology
//! node. Fit anchors (32 nm, single bank, 64-bit port, from published
//! CACTI-7 tables): 4 KiB ≈ {0.20 ns, 5.5 pJ/access, 0.012 mm²};
//! 1 MiB ≈ {1.6 ns, 28 pJ/access, 1.2 mm²}. Between anchors we scale
//! latency ∝ √capacity (wordline/bitline RC), energy ∝ capacity^0.35,
//! area ∝ capacity (with a fixed periphery floor).

/// One SRAM/eDRAM buffer instance.
#[derive(Clone, Copy, Debug)]
pub struct Buffer {
    pub bytes: usize,
    pub access_ns: f64,
    pub access_pj: f64,
    pub area_mm2: f64,
    pub leakage_mw: f64,
}

const ANCHOR_BYTES: f64 = 4096.0;
const ANCHOR_NS: f64 = 0.20;
const ANCHOR_PJ: f64 = 5.5;
const ANCHOR_MM2: f64 = 0.012;
const ANCHOR_LEAK_MW: f64 = 0.08;

impl Buffer {
    /// Model a buffer of `bytes` capacity (clamped to ≥256 B).
    pub fn new(bytes: usize) -> Buffer {
        let b = (bytes.max(256)) as f64;
        let ratio = b / ANCHOR_BYTES;
        Buffer {
            bytes: bytes.max(256),
            access_ns: ANCHOR_NS * ratio.sqrt().max(0.5),
            access_pj: ANCHOR_PJ * ratio.powf(0.35).max(0.5),
            area_mm2: ANCHOR_MM2 * ratio.max(0.25),
            leakage_mw: ANCHOR_LEAK_MW * ratio.max(0.25),
        }
    }

    /// Cost of moving `n` bytes through this buffer (word-wide port).
    pub fn transfer(&self, n_bytes: usize) -> (f64, f64) {
        let accesses = (n_bytes.div_ceil(8)) as f64; // 64-bit port
        (accesses * self.access_ns, accesses * self.access_pj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_capacity() {
        let small = Buffer::new(4 << 10);
        let big = Buffer::new(1 << 20);
        assert!(big.access_ns > small.access_ns);
        assert!(big.access_pj > small.access_pj);
        assert!(big.area_mm2 > small.area_mm2);
    }

    #[test]
    fn anchor_values_hold() {
        let b = Buffer::new(4096);
        assert!((b.access_ns - 0.20).abs() < 1e-9);
        assert!((b.access_pj - 5.5).abs() < 1e-9);
        assert!((b.area_mm2 - 0.012).abs() < 1e-9);
    }

    #[test]
    fn megabyte_anchor_order_of_magnitude() {
        let b = Buffer::new(1 << 20);
        // √256 = 16 → 3.2ns; CACTI says ~1.6 — same order, fine for ratios
        assert!(b.access_ns > 1.0 && b.access_ns < 5.0, "{}", b.access_ns);
        assert!(b.access_pj > 20.0 && b.access_pj < 60.0, "{}", b.access_pj);
    }

    #[test]
    fn transfer_scales_with_bytes() {
        let b = Buffer::new(4096);
        let (t1, e1) = b.transfer(64);
        let (t2, e2) = b.transfer(128);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_buffers_clamp() {
        let b = Buffer::new(1);
        assert_eq!(b.bytes, 256);
        assert!(b.access_ns > 0.0);
    }
}
