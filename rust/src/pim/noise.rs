//! ReRAM non-ideality model (paper §1/§3.1; Yang ICCAD'21).
//!
//! Analog crossbars suffer stochastic conductance variation; its impact
//! on inference accuracy grows with cell precision (tighter conductance
//! levels), crossbar size (more accumulated variance per column) and
//! shrinks with ADC headroom. Recommender models are unusually sensitive
//! ("even a 0.2% shift in Log Loss can be critical"), which is why the
//! paper constrains its ReRAM space. The NAS accuracy surrogate adds
//! `logloss_penalty` for the chosen PIM genome.

use super::config::PimConfig;

/// Device-level variation parameters.
#[derive(Clone, Copy, Debug)]
pub struct NoiseModel {
    /// relative conductance sigma per level (device lognormal σ)
    pub sigma_g: f64,
    /// logloss sensitivity coefficient (calibrated; see nas::accuracy)
    pub sensitivity: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            sigma_g: 0.02,
            sensitivity: 0.08,
        }
    }
}

impl NoiseModel {
    /// Effective relative error of one column sum for a PIM config:
    ///
    ///   σ_col = σ_g · (2^cell − 1) / √rows
    ///
    /// Each of `xbar` cells contributes σ_g per conductance level used;
    /// a cell storing `cell_bits` bits packs 2^cell_bits levels into the
    /// same conductance window, so per-cell σ scales with (2^cell−1)
    /// levels. Independent cell errors accumulate as √rows across the
    /// column while full scale grows linearly in rows, leaving a net
    /// 1/√rows. (An earlier form wrote this as
    /// `col / (rows·levels) · levels` — the `levels` pair cancels
    /// algebraically; the closed form above is the same function, and
    /// the regression test below pins its values.)
    pub fn column_rel_sigma(&self, cfg: &PimConfig) -> f64 {
        let levels = ((1usize << cfg.cell_bits) - 1) as f64;
        self.sigma_g * levels / (cfg.xbar as f64).sqrt()
    }

    /// Expected LogLoss penalty for running a model on this config.
    /// Monotone in the relative column error; zero in the limit of an
    /// ideal array. This is the term Algorithm 1's criterion sees.
    pub fn logloss_penalty(&self, cfg: &PimConfig) -> f64 {
        self.sensitivity * self.column_rel_sigma(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_crossbars_have_lower_relative_column_error() {
        // √rows/rows = 1/√rows: accumulation is sublinear vs full scale.
        let n = NoiseModel::default();
        let small = n.column_rel_sigma(&PimConfig {
            xbar: 16,
            ..Default::default()
        });
        let big = n.column_rel_sigma(&PimConfig {
            xbar: 64,
            ..Default::default()
        });
        assert!(big < small);
    }

    #[test]
    fn penalty_is_positive_and_small() {
        let n = NoiseModel::default();
        let p = n.logloss_penalty(&PimConfig::default());
        assert!(p > 0.0 && p < 0.01, "{p}");
    }

    #[test]
    fn column_sigma_regression_values_are_pinned() {
        // σ_g·levels/√rows, exactly what the pre-simplification
        // expression computed — these three pins would catch any
        // accidental semantic change to the closed form.
        let n = NoiseModel::default();
        let cases = [
            (64usize, 2usize, 0.0075f64), // default: 0.02·3/8
            (64, 1, 0.0025),              // single-level cells: 0.02·1/8
            (16, 2, 0.015),               // small tile: 0.02·3/4
        ];
        for (xbar, cell_bits, want) in cases {
            let got = n.column_rel_sigma(&PimConfig {
                xbar,
                cell_bits,
                ..Default::default()
            });
            assert!(
                (got - want).abs() < 1e-12,
                "xbar {xbar} cell {cell_bits}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn more_cell_bits_do_not_reduce_noise() {
        let n = NoiseModel::default();
        let c1 = n.column_rel_sigma(&PimConfig {
            cell_bits: 1,
            ..Default::default()
        });
        let c2 = n.column_rel_sigma(&PimConfig {
            cell_bits: 2,
            ..Default::default()
        });
        assert!(c2 >= c1);
    }
}
