//! Device-fault model for the PIM serving path (S34): seeded,
//! deterministic stuck-at fault injection over the packed bit-plane
//! arrays of [`super::kernel::BatchedXbar`].
//!
//! Real ReRAM tiles ship with — and accumulate — defective cells:
//! stuck-at-0 (a device that cannot be SET), stuck-at-1 (cannot be
//! RESET), and whole column lines lost to an open bitline. The paper's
//! motivation ("even a 0.2% shift in Log Loss can be critical") is
//! exactly why a serving stack cannot ignore them: one stuck cell
//! silently corrupts every score routed through its tile. This module
//! provides the *injection* half of the tolerance story; detection
//! (ABFT column checksums) and repair (spare-tile remapping) live in
//! `pim/kernel.rs` and `mapping/banks.rs` (DESIGN.md §7.13).
//!
//! Determinism contract: a [`FaultMap`] is a pure function of
//! `(FaultSpec, label, FaultGeom)` — per-tile RNG substreams
//! (`seed_from_name(spec.seed, "fault/{label}") → seed_from_indexed(…,
//! "tile", t)`) make the drawn sites independent of tile iteration
//! order and reproducible across runs, hosts, and thread counts, so a
//! failing seed replays exactly.

use crate::util::rng::{seed_from_indexed, seed_from_name, Rng};

/// Sentinel column id marking a fault site on the tile's ABFT checksum
/// column (which is stored in a separate packed array from the data
/// columns — see `pim/kernel.rs`).
pub const CHK_COL: u32 = u32::MAX;

/// Injection parameters. Rates are *per physical cell*: in the
/// differential bit-plane mapping every `(row, column, plane, sign,
/// weight-bit)` position is one device, so `rate` is drawn once per
/// packed bit. All draws are seeded — two banks with the same spec,
/// label, and geometry corrupt identically.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// per-cell stuck-at probability (manufacturing defects)
    pub rate: f64,
    /// fraction of stuck cells that are stuck at 1 (the rest stick at 0)
    pub stuck1_frac: f64,
    /// per-(tile, column-line) probability of a stuck-open bitline —
    /// the whole column reads 0 (checksum column included)
    pub col_rate: f64,
    /// fire a second wave of stuck sites after this many MVM batches
    /// (the device twin of `CrashAfter`/`SlowAfter`); `None` = no drift
    pub drift_after: Option<u64>,
    /// per-cell rate of the drift wave
    pub drift_rate: f64,
    /// root seed; per-bank and per-tile substreams derive from it
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            rate: 0.0,
            stuck1_frac: 0.5,
            col_rate: 0.0,
            drift_after: None,
            drift_rate: 0.0,
            seed: 0xFA17,
        }
    }
}

impl FaultSpec {
    /// Stuck-cell-only spec at `rate` with the default 50/50 polarity.
    pub fn cells(rate: f64, seed: u64) -> FaultSpec {
        FaultSpec {
            rate,
            seed,
            ..FaultSpec::default()
        }
    }
}

/// Geometry of the packed arrays the faults land on, as seen by the
/// kernel: `blocks` is the number of `(plane, sign, weight-bit)` data
/// blocks, `chk_blocks` the (larger) checksum-plane block count,
/// `last_mask` the valid-row mask of the final word (tiles whose row
/// count is not a multiple of 64 have dead bits that hold no cell).
#[derive(Clone, Copy, Debug)]
pub struct FaultGeom {
    pub blocks: usize,
    pub chk_blocks: usize,
    pub n_tiles_phys: usize,
    pub cols: usize,
    pub n_words: usize,
    pub last_mask: u64,
}

impl FaultGeom {
    fn word_mask(&self, word: usize) -> u64 {
        if word + 1 == self.n_words {
            self.last_mask
        } else {
            u64::MAX
        }
    }
}

/// One word's worth of stuck cells: bits in `set` are stuck at 1, bits
/// in `clear` are stuck at 0. `col == CHK_COL` targets the checksum
/// array; `block` indexes the `(plane, sign, weight-bit)` block.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSite {
    pub block: u32,
    pub col: u32,
    pub word: u32,
    pub set: u64,
    pub clear: u64,
}

/// Concrete fault sites for one bank's physical tile array (spare
/// slots included — a spare can be born bad), plus the drift fuse.
#[derive(Clone, Debug, Default)]
pub struct FaultMap {
    /// programmed stuck sites, grouped by physical tile
    pub tiles: Vec<Vec<FaultSite>>,
    /// sites that appear when the drift fuse fires, per physical tile
    pub drift_tiles: Vec<Vec<FaultSite>>,
    drift_after: Option<u64>,
    mvms: u64,
    drifted: bool,
}

/// Draw the stuck sites of one tile into `out`. One `FaultSite` per
/// packed word with at least one stuck cell; polarity per cell.
fn draw_tile(
    rng: &mut Rng,
    rate: f64,
    stuck1_frac: f64,
    col_rate: f64,
    geom: &FaultGeom,
    out: &mut Vec<FaultSite>,
) {
    let mut cells = |blocks: usize, cols: &[u32], out: &mut Vec<FaultSite>| {
        for block in 0..blocks {
            for &col in cols {
                for word in 0..geom.n_words {
                    let valid = geom.word_mask(word);
                    let (mut set, mut clear) = (0u64, 0u64);
                    for bit in 0..64 {
                        if valid >> bit & 1 == 0 {
                            continue; // no cell behind a pad bit
                        }
                        if rng.chance(rate) {
                            if rng.chance(stuck1_frac) {
                                set |= 1 << bit;
                            } else {
                                clear |= 1 << bit;
                            }
                        }
                    }
                    if set | clear != 0 {
                        out.push(FaultSite {
                            block: block as u32,
                            col,
                            word: word as u32,
                            set,
                            clear,
                        });
                    }
                }
            }
        }
    };
    if rate > 0.0 {
        let data_cols: Vec<u32> = (0..geom.cols as u32).collect();
        cells(geom.blocks, &data_cols, out);
        cells(geom.chk_blocks, &[CHK_COL], out);
    }
    if col_rate > 0.0 {
        // stuck-open bitlines: the whole column reads 0 in every block
        let mut line = |blocks: usize, col: u32, out: &mut Vec<FaultSite>| {
            for block in 0..blocks {
                for word in 0..geom.n_words {
                    out.push(FaultSite {
                        block: block as u32,
                        col,
                        word: word as u32,
                        set: 0,
                        clear: geom.word_mask(word),
                    });
                }
            }
        };
        for col in 0..geom.cols as u32 {
            if rng.chance(col_rate) {
                line(geom.blocks, col, out);
            }
        }
        if rng.chance(col_rate) {
            line(geom.chk_blocks, CHK_COL, out);
        }
    }
}

impl FaultMap {
    /// Build the deterministic site map for one bank. `label` is the
    /// bank name — two banks with different labels draw independent
    /// substreams from the same spec seed.
    pub fn build(spec: &FaultSpec, label: &str, geom: &FaultGeom) -> FaultMap {
        let bank_seed = seed_from_name(spec.seed, &format!("fault/{label}"));
        let mut tiles = Vec::with_capacity(geom.n_tiles_phys);
        let mut drift_tiles = Vec::with_capacity(geom.n_tiles_phys);
        for t in 0..geom.n_tiles_phys {
            let mut rng = Rng::new(seed_from_indexed(bank_seed, "tile", t));
            let mut sites = Vec::new();
            draw_tile(
                &mut rng,
                spec.rate,
                spec.stuck1_frac,
                spec.col_rate,
                geom,
                &mut sites,
            );
            tiles.push(sites);
            let mut drng = Rng::new(seed_from_indexed(bank_seed, "drift", t));
            let mut dsites = Vec::new();
            if spec.drift_after.is_some() {
                draw_tile(
                    &mut drng,
                    spec.drift_rate,
                    spec.stuck1_frac,
                    0.0,
                    geom,
                    &mut dsites,
                );
            }
            drift_tiles.push(dsites);
        }
        FaultMap {
            tiles,
            drift_tiles,
            drift_after: spec.drift_after,
            mvms: 0,
            drifted: false,
        }
    }

    /// Advance the drift fuse by one MVM batch. Returns `true` exactly
    /// once — on the batch where the fuse crosses — so the caller
    /// applies the drift wave a single time.
    pub fn tick(&mut self) -> bool {
        self.mvms += 1;
        if self.drifted {
            return false;
        }
        match self.drift_after {
            Some(n) if self.mvms >= n => {
                self.drifted = true;
                true
            }
            _ => false,
        }
    }

    /// Whether the drift wave has already been applied.
    pub fn drifted(&self) -> bool {
        self.drifted
    }
}

/// Detection/repair outcome counters, drained up the stack each serve
/// batch (bank scratch → engine → coordinator metrics). `corrupt_rows`
/// counts batch rows served by a bank that detected corruption it
/// could not repair (flagged-approximate mode) — those responses are
/// *still responses* on the conservation ledger; the counter is a
/// quality annotation, not a ledger leg.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultCounts {
    /// detected (tile, batch-row) MVMs whose checksum disagreed
    pub tiles_faulty: u64,
    /// tiles successfully remapped onto a spare
    pub tiles_repaired: u64,
    /// batch rows served in flagged-approximate (unrepairable) mode
    pub corrupt_rows: u64,
}

impl FaultCounts {
    /// Fold another drain into this one (plain integer adds).
    pub fn merge(&mut self, o: &FaultCounts) {
        self.tiles_faulty += o.tiles_faulty;
        self.tiles_repaired += o.tiles_repaired;
        self.corrupt_rows += o.corrupt_rows;
    }

    /// Drain: return the accumulated counts and reset to zero.
    pub fn take(&mut self) -> FaultCounts {
        std::mem::take(self)
    }

    /// Anything to report?
    pub fn any(&self) -> bool {
        *self != FaultCounts::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> FaultGeom {
        FaultGeom {
            blocks: 8,
            chk_blocks: 12,
            n_tiles_phys: 3,
            cols: 5,
            n_words: 2,
            last_mask: (1u64 << 32) - 1, // 96-row tile: last word half-valid
        }
    }

    #[test]
    fn zero_rate_draws_nothing() {
        let m = FaultMap::build(&FaultSpec::default(), "b", &geom());
        assert!(m.tiles.iter().all(|t| t.is_empty()));
        assert!(m.drift_tiles.iter().all(|t| t.is_empty()));
    }

    #[test]
    fn build_is_deterministic_and_label_sensitive() {
        let spec = FaultSpec::cells(1e-2, 7);
        let g = geom();
        let a = FaultMap::build(&spec, "bank0", &g);
        let b = FaultMap::build(&spec, "bank0", &g);
        let c = FaultMap::build(&spec, "bank1", &g);
        assert_eq!(a.tiles, b.tiles);
        assert_ne!(a.tiles, c.tiles, "labels must draw independent streams");
        assert!(a.tiles.iter().any(|t| !t.is_empty()), "rate 1e-2 over ~50k cells");
    }

    #[test]
    fn sites_respect_the_valid_row_mask() {
        let spec = FaultSpec {
            rate: 0.2,
            col_rate: 0.3,
            ..FaultSpec::cells(0.2, 11)
        };
        let g = geom();
        let m = FaultMap::build(&spec, "b", &g);
        for sites in &m.tiles {
            for s in sites {
                let valid = g.word_mask(s.word as usize);
                assert_eq!(s.set & !valid, 0, "stuck-1 on a pad bit");
                assert_eq!(s.clear & !valid, 0, "stuck-0 on a pad bit");
                assert_eq!(s.set & s.clear, 0, "a cell cannot stick both ways");
                let blocks = if s.col == CHK_COL { g.chk_blocks } else { g.blocks };
                assert!((s.block as usize) < blocks);
            }
        }
    }

    #[test]
    fn polarity_follows_stuck1_frac() {
        let all1 = FaultSpec {
            stuck1_frac: 1.0,
            ..FaultSpec::cells(0.05, 3)
        };
        let m = FaultMap::build(&all1, "b", &geom());
        assert!(m.tiles.iter().flatten().all(|s| s.clear == 0));
        let all0 = FaultSpec {
            stuck1_frac: 0.0,
            ..FaultSpec::cells(0.05, 3)
        };
        let m = FaultMap::build(&all0, "b", &geom());
        assert!(m.tiles.iter().flatten().all(|s| s.set == 0));
    }

    #[test]
    fn column_line_faults_clear_every_block_of_the_column() {
        let spec = FaultSpec {
            rate: 0.0,
            col_rate: 1.0,
            ..FaultSpec::default()
        };
        let g = geom();
        let m = FaultMap::build(&spec, "b", &g);
        for sites in &m.tiles {
            // every data column in every block + the chk column
            let expect = (g.blocks * g.cols + g.chk_blocks) * g.n_words;
            assert_eq!(sites.len(), expect);
            assert!(sites.iter().all(|s| s.set == 0));
            for s in sites {
                assert_eq!(s.clear, g.word_mask(s.word as usize));
            }
        }
    }

    #[test]
    fn drift_fuse_fires_exactly_once() {
        let spec = FaultSpec {
            drift_after: Some(3),
            drift_rate: 0.05,
            ..FaultSpec::cells(0.0, 5)
        };
        let mut m = FaultMap::build(&spec, "b", &geom());
        assert!(m.drift_tiles.iter().any(|t| !t.is_empty()));
        assert!(!m.tick());
        assert!(!m.tick());
        assert!(m.tick(), "fuse crosses on batch 3");
        assert!(m.drifted());
        assert!(!m.tick(), "fires once");
    }

    #[test]
    fn no_drift_spec_never_fires() {
        let mut m = FaultMap::build(&FaultSpec::cells(0.0, 5), "b", &geom());
        for _ in 0..10 {
            assert!(!m.tick());
        }
    }

    #[test]
    fn counts_merge_take_any() {
        let mut a = FaultCounts {
            tiles_faulty: 2,
            tiles_repaired: 1,
            corrupt_rows: 0,
        };
        assert!(a.any());
        a.merge(&FaultCounts {
            tiles_faulty: 1,
            tiles_repaired: 0,
            corrupt_rows: 4,
        });
        assert_eq!(
            a,
            FaultCounts {
                tiles_faulty: 3,
                tiles_repaired: 1,
                corrupt_rows: 4
            }
        );
        let t = a.take();
        assert_eq!(t.tiles_faulty, 3);
        assert!(!a.any());
    }
}
