//! Batched, layout-optimized, multi-core crossbar execution core (S23/S25).
//!
//! [`super::crossbar::ProgrammedXbar::mvm_raw`] is the line-for-line
//! functional reference (one vector, scalar inner loops). This module is
//! the production kernel the serving path runs on: [`BatchedXbar`] stores
//! the same differential bit-plane stacks in an execution-friendly layout
//! and [`BatchedXbar::mvm_batch`] amortizes the tile/chunk/plane traversal
//! over a whole batch, optionally across worker threads. The contract is
//! **bit-identity**: for any [`PimConfig`] — feasible or not, any tile
//! height, any thread count — outputs (i64 accumulators) and
//! [`XbarActivity`] counts equal the per-vector reference exactly
//! (`rust/tests/xbar_kernel.rs`, `rust/tests/xbar_threads.rs`, re-checked
//! in-run by `autorac xbar-bench`).
//!
//! Why it is fast (DESIGN.md §7 "§Perf", §7.8):
//!
//! * **Multi-word bit-plane packing + popcount.** One weight column of
//!   one bit-plane is stored as `ceil(xbar/64)` `u64` row-mask words, so
//!   EVERY tile geometry — including experimental tiles wider than 64
//!   rows — takes the packed path: the chunk×plane inner product is
//!   `Σ_w popcount(x_word[w] & w_word[w]) << (xb+wb)`, i.e. at most
//!   `dac_bits · cell_bits · n_words` AND+popcount ops per column
//!   instead of an `xbar`-long multiply-accumulate. (The old blocked
//!   i64 fallback for tiles > 64 rows is gone.)
//! * **Batch amortization.** Weight words are loaded once per
//!   (tile, chunk, plane, sign, column) and reused by every batch lane;
//!   input chunk bits are extracted once per (tile, chunk) into the
//!   scratch arena.
//! * **Tile-parallel execution.** [`XbarScratch::with_threads`] splits
//!   the independent (tile, chunk) work units across scoped worker
//!   threads, each accumulating into its own per-lane arena; the lanes
//!   are then folded with plain integer addition, which commutes
//!   exactly — so any thread count produces bit-identical outputs AND
//!   activity counts (§7.8's determinism argument).
//! * **Lossless-ADC fast path.** `PimConfig::feasible()` guarantees the
//!   full-scale column sum fits the ADC (`adc_step() == 1`), which makes
//!   [`super::crossbar::adc_transfer`] the identity on every reachable
//!   partial — the kernel skips the transfer entirely while still
//!   counting the conversions.
//! * **Program-time offset correction.** The input-independent dummy-row
//!   vector is computed once at [`BatchedXbar::program`] time, so
//!   [`BatchedXbar::mvm_corrected_batch`] is one kernel pass plus a
//!   subtraction (the reference used to pay a second full MVM per call).
//!
//! The hot path is allocation-free after warmup: all per-call buffers
//! (including every thread lane's) live in the caller-owned
//! [`XbarScratch`] arena.

use super::config::PimConfig;
use super::crossbar::{adc_transfer, MatI32, XbarActivity};

/// Rows per packed word: one `u64` row-mask covers 64 tile rows; a tile
/// of `xbar` rows needs `ceil(xbar / PACK_WORD_BITS)` words per column
/// per weight bit.
pub const PACK_WORD_BITS: usize = 64;

/// Stack capacity (in `u64` words) for one column's hoisted weight
/// words (`cell_bits × n_words` of them). Covers every realistic
/// geometry — `cell_bits ≤ 2` and tiles up to 512 rows; anything bigger
/// spills to the heap arena instead (same results, one memcpy more).
const WW_STACK: usize = 16;

/// Minimum number of inner word-operations (`units × planes × 2 ×
/// cols × b × dac·cell·n_words`) before [`BatchedXbar::mvm_batch`] fans
/// work out to scoped worker threads. Each call that crosses it spawns
/// and joins its workers (`std::thread::scope` — scoped borrows instead
/// of a persistent queue), so the threshold is set where the compute
/// dwarfs the ~tens-of-µs spawn cost; below it (e.g. a 1-column scoring
/// head) the serial path runs. Purely a performance knob — results are
/// bit-identical either way.
const PAR_MIN_OPS: usize = 1 << 17;

/// One worker thread's private slice of the arena: input bit-masks, a
/// partial output accumulator, and partial activity counters. Folded
/// into the caller's output/activity after the scope joins.
#[derive(Default)]
struct Lane {
    xmasks: Vec<u64>,
    wwbuf: Vec<u64>,
    out: Vec<i64>,
    activity: XbarActivity,
}

/// Reusable scratch arena for [`BatchedXbar::mvm_batch`]: per-call
/// buffers plus the activity counters the pass accumulates into
/// (mirroring the `&mut XbarActivity` the reference takes). Create once,
/// pass to every call; no allocations happen after the first call with
/// the largest batch. [`XbarScratch::with_threads`] turns on
/// tile-parallel execution (bit-identical results at any thread count).
#[derive(Default)]
pub struct XbarScratch {
    /// event counters accumulated by every pass using this arena
    pub activity: XbarActivity,
    /// worker threads `mvm_batch` may fan out to (0 and 1 = serial)
    threads: usize,
    /// main-lane input bit-masks for the current (tile, chunk):
    /// `[b × dac_bits × n_words]` words, word `w` bit `i` = input bit of
    /// tile row `w·64 + i`
    xmasks: Vec<u64>,
    /// main-lane per-column weight words (`cell_bits × n_words`), loaded
    /// once per column and reused by every batch lane
    wwbuf: Vec<u64>,
    /// extra-thread arenas (partial outputs + counters), reused across calls
    lanes: Vec<Lane>,
}

impl XbarScratch {
    /// Arena that lets `mvm_batch` split tile execution across up to
    /// `threads` OS threads (the calling thread counts as one). 0 and 1
    /// both mean serial. Thread count never changes a single output or
    /// activity bit — it is purely a wall-clock knob.
    pub fn with_threads(threads: usize) -> XbarScratch {
        XbarScratch {
            threads,
            ..XbarScratch::default()
        }
    }

    /// Configured worker-thread cap (0/1 = serial).
    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// A programmed crossbar bank in batched-execution layout: differential
/// bit-plane stacks stored column-blocked and packed into `u64` row-mask
/// words (multi-word when the tile has more than 64 rows), plus the
/// cached offset-correction vector.
pub struct BatchedXbar {
    pub cfg: PimConfig,
    /// programmed rows (K padded to a multiple of `cfg.xbar`)
    pub k: usize,
    /// output columns
    pub n: usize,
    n_tiles: usize,
    /// `u64` words per column per weight bit: `ceil(xbar / 64)`
    n_words: usize,
    /// `feasible()` ⇒ `adc_transfer` is the identity on every reachable
    /// partial sum — skip it (outputs unchanged, counts unchanged)
    lossless: bool,
    /// packed layout:
    /// `words[((((p·2+s)·cell_bits + wb)·n_tiles + t)·n + col)·n_words + w]`
    /// is the row-mask of weight-bit `wb` of plane `p`, sign `s`, tile
    /// `t`, column `col`, covering tile rows `w·64 .. w·64+64`
    packed: Vec<u64>,
    /// raw accumulator of the all-`offset` input (the dummy-row read),
    /// computed once at program time
    offset_corr: Vec<i64>,
    pub program_activity: XbarActivity,
}

impl BatchedXbar {
    /// Program a signed integer weight matrix (values within `w_bits`).
    /// Same contract and programming activity as
    /// [`super::crossbar::ProgrammedXbar::program`]; only the storage
    /// layout differs.
    pub fn program(wq: &MatI32, cfg: PimConfig) -> BatchedXbar {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        assert!(
            wq.data.iter().all(|&w| w.abs() <= wmax),
            "weights exceed w_bits range"
        );
        let k_pad = wq.rows.div_ceil(cfg.xbar) * cfg.xbar;
        let n_tiles = k_pad / cfg.xbar;
        let n_words = cfg.xbar.div_ceil(PACK_WORD_BITS);
        let n = wq.cols;
        let planes = cfg.n_planes();
        let cell = cfg.cell_bits;
        let cell_mask = (1i32 << cell) - 1;

        let mut packed = vec![0u64; planes * 2 * cell * n_tiles * n * n_words];
        for r in 0..wq.rows {
            let (t, i) = (r / cfg.xbar, r % cfg.xbar);
            let (word, bit) = (i / PACK_WORD_BITS, i % PACK_WORD_BITS);
            for c in 0..n {
                let w = wq.at(r, c);
                for (s, mag) in [(0usize, w.max(0)), (1, (-w).max(0))] {
                    for p in 0..planes {
                        let pv = (mag >> (p * cell)) & cell_mask;
                        if pv == 0 {
                            continue;
                        }
                        for wb in 0..cell {
                            if (pv >> wb) & 1 == 1 {
                                let idx = (((((p * 2 + s) * cell + wb) * n_tiles
                                    + t)
                                    * n
                                    + c)
                                    * n_words)
                                    + word;
                                packed[idx] |= 1u64 << bit;
                            }
                        }
                    }
                }
            }
        }

        let program_activity = XbarActivity {
            cells_written: 2 * planes as u64 * (k_pad * n) as u64,
            write_pulses: 2 * planes as u64 * k_pad as u64,
            ..Default::default()
        };
        let mut xb = BatchedXbar {
            cfg,
            k: k_pad,
            n,
            n_tiles,
            n_words,
            lossless: cfg.feasible(),
            packed,
            offset_corr: Vec::new(),
            program_activity,
        };
        // Dummy-row read: the offset correction is input-independent, so
        // simulate it once here instead of once per corrected MVM.
        let offset = 1i32 << (cfg.x_bits - 1);
        let ones = vec![offset; k_pad];
        let mut corr = vec![0i64; n];
        let mut scratch = XbarScratch::default();
        xb.mvm_batch(&ones, 1, &mut corr, &mut scratch);
        xb.offset_corr = corr;
        xb
    }

    /// The cached input-independent offset-correction vector (raw
    /// accumulator of the all-`offset` input).
    pub fn offset_correction(&self) -> &[i64] {
        &self.offset_corr
    }

    /// Batched bit-serial MVM: `xs` is row-major `[b × k]` (each vector
    /// padded to `k` by the caller, offset-binary in `[0, 2^x_bits)`),
    /// `out` is `[b × n]` raw accumulators (overwritten). Bit-identical
    /// to calling [`super::crossbar::ProgrammedXbar::mvm_raw`] on each
    /// row, including the counts accumulated into `scratch.activity` —
    /// at any `XbarScratch::with_threads` setting.
    pub fn mvm_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        assert_eq!(xs.len(), b * self.k, "xs must be [b × k] (pad each row to k)");
        assert_eq!(out.len(), b * self.n, "out must be [b × n]");
        out.iter_mut().for_each(|v| *v = 0);
        // NB: no early-out on n == 0 — the reference still counts
        // read_cycles for a zero-column bank, and so must we.
        if b == 0 {
            return;
        }
        // Independent work units: one (tile, chunk) pair each. Anything
        // a unit adds to `out`/activity commutes exactly (integer sums),
        // so partitioning the unit range is invisible in the result.
        let units = self.n_tiles * self.cfg.n_chunks();
        let ops = units
            * self.cfg.n_planes()
            * 2
            * self.n
            * b
            * (self.cfg.dac_bits * self.cfg.cell_bits * self.n_words);
        let threads = scratch.threads.clamp(1, units.max(1));
        if threads == 1 || ops < PAR_MIN_OPS {
            self.run_units(
                0..units,
                xs,
                b,
                out,
                &mut scratch.xmasks,
                &mut scratch.wwbuf,
                &mut scratch.activity,
            );
            return;
        }
        // Fan out: contiguous unit spans, one per thread. The calling
        // thread takes span 0 and accumulates straight into `out`; each
        // worker accumulates into its own zeroed lane arena. When `units`
        // does not divide evenly, only as many lanes as have a non-empty
        // span are kept — no thread is ever spawned to do nothing.
        let per = units.div_ceil(threads);
        let n_lanes = units.div_ceil(per) - 1;
        scratch.lanes.resize_with(n_lanes, Lane::default);
        std::thread::scope(|sc| {
            for (w, lane) in scratch.lanes.iter_mut().enumerate() {
                let lo = (w + 1) * per;
                let hi = ((w + 2) * per).min(units);
                debug_assert!(lo < hi, "empty lane span must not be spawned");
                sc.spawn(move || {
                    lane.out.clear();
                    lane.out.resize(b * self.n, 0);
                    lane.activity = XbarActivity::default();
                    self.run_units(
                        lo..hi,
                        xs,
                        b,
                        &mut lane.out,
                        &mut lane.xmasks,
                        &mut lane.wwbuf,
                        &mut lane.activity,
                    );
                });
            }
            self.run_units(
                0..per,
                xs,
                b,
                out,
                &mut scratch.xmasks,
                &mut scratch.wwbuf,
                &mut scratch.activity,
            );
        });
        // Order-independent reduction: lane partials and counters fold
        // in with plain integer addition (commutative and associative
        // exactly), so the fold order — and the thread count — cannot
        // change a bit.
        for lane in &scratch.lanes {
            for (o, &p) in out.iter_mut().zip(&lane.out) {
                *o += p;
            }
            scratch.activity.merge(&lane.activity);
        }
    }

    /// [`BatchedXbar::mvm_batch`] plus the cached offset correction:
    /// matches [`super::crossbar::ProgrammedXbar::mvm_corrected`] per row.
    pub fn mvm_corrected_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        self.mvm_batch(xs, b, out, scratch);
        for j in 0..b {
            for (o, &c) in out[j * self.n..(j + 1) * self.n]
                .iter_mut()
                .zip(&self.offset_corr)
            {
                *o -= c;
            }
        }
    }

    /// AND+popcount core over a contiguous range of (tile, chunk) work
    /// units. Accumulates into `out` (not zeroed here) and `activity`;
    /// `xmasks` and `wwbuf` are this lane's input-bit and weight-word
    /// arenas.
    fn run_units(
        &self,
        units: std::ops::Range<usize>,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        xmasks: &mut Vec<u64>,
        wwbuf: &mut Vec<u64>,
        activity: &mut XbarActivity,
    ) {
        let cfg = &self.cfg;
        let (dac, cell, xbar, n, nw) =
            (cfg.dac_bits, cfg.cell_bits, cfg.xbar, self.n, self.n_words);
        let n_chunks = cfg.n_chunks();
        // per-(plane,sign,wb) stride between weight-bit blocks
        let wb_stride = self.n_tiles * n * nw;
        xmasks.clear();
        xmasks.resize(b * dac * nw, 0);
        // one column's hoisted weight words: stack for every realistic
        // geometry, heap arena for hand-built exotic ones
        let mut ww_stack = [0u64; WW_STACK];
        for u in units {
            let (t, c) = (u / n_chunks, u % n_chunks);
            let r0 = t * xbar;
            activity.read_cycles += b as u64;
            let cshift = c * dac;
            // Input bit extraction, once per (tile, chunk) per lane.
            for j in 0..b {
                let row = &xs[j * self.k + r0..j * self.k + r0 + xbar];
                for xb in 0..dac {
                    let base = (j * dac + xb) * nw;
                    for (w, m) in xmasks[base..base + nw].iter_mut().enumerate() {
                        let lo = w * PACK_WORD_BITS;
                        let hi = (lo + PACK_WORD_BITS).min(xbar);
                        let mut mask = 0u64;
                        for (i, &x) in row[lo..hi].iter().enumerate() {
                            mask |= (((x >> (cshift + xb)) & 1) as u64) << i;
                        }
                        *m = mask;
                    }
                }
            }
            for p in 0..cfg.n_planes() {
                let shift = (cshift + p * cell) as u32;
                for s in 0..2usize {
                    let sign = if s == 0 { 1i64 } else { -1i64 };
                    activity.adc_conversions += (b * n) as u64;
                    activity.shift_adds += (b * n) as u64;
                    // base of (plane p, sign s, weight-bit 0, tile t)
                    let plane_base = (((p * 2 + s) * cell) * self.n_tiles + t) * n;
                    for col in 0..n {
                        let col_base = (plane_base + col) * nw;
                        // Load this column's cell·nw weight words once;
                        // every batch lane and input bit reuses them
                        // (the "loaded once per column" contract).
                        let ww_col: &[u64] = if cell * nw <= WW_STACK {
                            for wb in 0..cell {
                                ww_stack[wb * nw..(wb + 1) * nw].copy_from_slice(
                                    &self.packed[col_base + wb * wb_stride..][..nw],
                                );
                            }
                            &ww_stack[..cell * nw]
                        } else {
                            wwbuf.clear();
                            for wb in 0..cell {
                                wwbuf.extend_from_slice(
                                    &self.packed[col_base + wb * wb_stride..][..nw],
                                );
                            }
                            wwbuf
                        };
                        for j in 0..b {
                            let xm_base = j * dac * nw;
                            let mut v = 0i64;
                            for xb in 0..dac {
                                let xm = &xmasks[xm_base + xb * nw..][..nw];
                                for wb in 0..cell {
                                    let ww = &ww_col[wb * nw..(wb + 1) * nw];
                                    let mut pc = 0u64;
                                    for (&a, &w) in xm.iter().zip(ww) {
                                        pc += (a & w).count_ones() as u64;
                                    }
                                    v += (pc as i64) << (xb + wb);
                                }
                            }
                            let q = if self.lossless {
                                v
                            } else {
                                adc_transfer(v, cfg)
                            };
                            out[j * n + col] += sign * (q << shift);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::crossbar::ProgrammedXbar;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
        let mut m = MatI32::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
            }
        }
        m
    }

    fn random_inputs(rng: &mut Rng, b: usize, k: usize, x_bits: usize) -> Vec<i32> {
        (0..b * k)
            .map(|_| rng.below(1u64 << x_bits) as i32)
            .collect()
    }

    /// Outputs and activity of the per-vector reference on `b` rows.
    fn reference(
        xbar: &ProgrammedXbar,
        xs: &[i32],
        b: usize,
    ) -> (Vec<i64>, XbarActivity) {
        let mut act = XbarActivity::default();
        let mut out = Vec::with_capacity(b * xbar.n);
        for j in 0..b {
            out.extend(xbar.mvm_raw(&xs[j * xbar.k..(j + 1) * xbar.k], &mut act));
        }
        (out, act)
    }

    #[test]
    fn packed_path_matches_reference_on_default_config() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(1);
        let wq = random_mat(&mut rng, 100, 17, 127); // K padded 100 → 128
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!((bx.k, bx.n), (refx.k, refx.n));
        assert_eq!(bx.program_activity, refx.program_activity);
        for b in [1usize, 7, 32] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, b);
            let mut out = vec![0i64; b * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            assert_eq!(out, want, "b={b}");
            assert_eq!(scratch.activity, want_act, "b={b}");
        }
    }

    #[test]
    fn lossy_adc_config_still_bit_identical() {
        let cfg = PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        };
        assert!(!cfg.feasible());
        let mut rng = Rng::new(2);
        let wq = random_mat(&mut rng, 64, 11, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = random_inputs(&mut rng, 5, bx.k, cfg.x_bits);
        let (want, want_act) = reference(&refx, &xs, 5);
        let mut out = vec![0i64; 5 * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 5, &mut out, &mut scratch);
        assert_eq!(out, want);
        assert_eq!(scratch.activity, want_act);
    }

    #[test]
    fn wide_tiles_take_the_multi_word_packed_path() {
        // xbar > 64 used to hit a blocked i64 fallback; it now packs
        // into ceil(xbar/64) words. 128·1·1 = 128 ≤ 255 is feasible
        // (lossless), 128·1·3 is lossy — both must match the reference.
        for cfg in [
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 2,
                adc_bits: 8,
                ..Default::default()
            },
            // non-multiple-of-64 width: last word is partial
            PimConfig {
                xbar: 96,
                dac_bits: 2,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
            // three words per column
            PimConfig {
                xbar: 192,
                dac_bits: 1,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
        ] {
            let mut rng = Rng::new(3);
            let wq = random_mat(&mut rng, cfg.xbar + 2, 6, 127); // ragged pad
            let refx = ProgrammedXbar::program(&wq, cfg);
            let bx = BatchedXbar::program(&wq, cfg);
            assert_eq!(bx.n_words, cfg.xbar.div_ceil(64), "cfg {cfg:?}");
            let xs = random_inputs(&mut rng, 4, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, 4);
            let mut out = vec![0i64; 4 * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, 4, &mut out, &mut scratch);
            assert_eq!(out, want, "cfg {cfg:?}");
            assert_eq!(scratch.activity, want_act, "cfg {cfg:?}");
        }
    }

    #[test]
    fn threaded_execution_is_bit_identical_to_serial() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(6);
        let wq = random_mat(&mut rng, 300, 24, 127); // 5 tiles → real spans
        let bx = BatchedXbar::program(&wq, cfg);
        let b = 16;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let mut serial = vec![0i64; b * bx.n];
        let mut s1 = XbarScratch::with_threads(1);
        bx.mvm_batch(&xs, b, &mut serial, &mut s1);
        for threads in [2usize, 3, 8, 64] {
            let mut out = vec![0i64; b * bx.n];
            let mut st = XbarScratch::with_threads(threads);
            // this workload clears PAR_MIN_OPS (40 units × 4 planes × 2
            // signs × 24 cols × b=16 × 2 word-ops ≈ 2^18), so the
            // parallel path actually runs
            bx.mvm_batch(&xs, b, &mut out, &mut st);
            assert_eq!(out, serial, "threads={threads}");
            assert_eq!(st.activity, s1.activity, "threads={threads}");
        }
    }

    #[test]
    fn small_workloads_stay_serial_but_identical() {
        // below PAR_MIN_OPS the kernel silently runs serial — results
        // must still match a threads=1 arena bit for bit
        let cfg = PimConfig::default();
        let mut rng = Rng::new(8);
        let wq = random_mat(&mut rng, 40, 3, 127);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = random_inputs(&mut rng, 2, bx.k, cfg.x_bits);
        let mut a = vec![0i64; 2 * bx.n];
        let mut b1 = vec![0i64; 2 * bx.n];
        let mut sa = XbarScratch::with_threads(4);
        let mut sb = XbarScratch::default();
        bx.mvm_batch(&xs, 2, &mut a, &mut sa);
        bx.mvm_batch(&xs, 2, &mut b1, &mut sb);
        assert_eq!(a, b1);
        assert_eq!(sa.activity, sb.activity);
    }

    #[test]
    fn corrected_batch_matches_reference_corrected() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(4);
        let wq = random_mat(&mut rng, cfg.xbar, 9, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!(bx.offset_correction(), refx.offset_correction());
        let b = 3;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_corrected_batch(&xs, b, &mut out, &mut scratch);
        for j in 0..b {
            let mut act = XbarActivity::default();
            let want = refx.mvm_corrected(&xs[j * bx.k..(j + 1) * bx.k], &mut act);
            assert_eq!(&out[j * bx.n..(j + 1) * bx.n], &want[..], "row {j}");
        }
    }

    #[test]
    fn scratch_is_reusable_across_batch_sizes() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(5);
        let wq = random_mat(&mut rng, 64, 4, 127);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut scratch = XbarScratch::with_threads(2);
        let mut last = Vec::new();
        for b in [8usize, 1, 3] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let mut out = vec![0i64; b * bx.n];
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            last = out;
        }
        assert_eq!(last.len(), 3 * bx.n);
        assert!(scratch.activity.read_cycles > 0);
    }

    #[test]
    fn zero_batch_is_a_noop() {
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 3);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&[], 0, &mut out, &mut scratch);
        assert_eq!(scratch.activity, XbarActivity::default());
    }

    #[test]
    fn zero_column_bank_still_counts_reads() {
        // n == 0 must not short-circuit: the reference charges the
        // read cycles of driving the (column-less) wordlines regardless
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 0);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = vec![0i32; bx.k];
        let mut act = XbarActivity::default();
        let want = refx.mvm_raw(&xs, &mut act);
        assert!(want.is_empty());
        assert!(act.read_cycles > 0);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 1, &mut out, &mut scratch);
        assert_eq!(scratch.activity, act);
    }

    #[test]
    fn weights_out_of_range_panic() {
        let cfg = PimConfig::default().with_wbits(4);
        let mut wq = MatI32::zeros(4, 4);
        wq.set(0, 0, 100);
        let r = std::panic::catch_unwind(|| BatchedXbar::program(&wq, cfg));
        assert!(r.is_err());
    }
}
