//! Batched, layout-optimized, multi-core crossbar execution core
//! (S23/S25), with device-fault tolerance (S34).
//!
//! [`super::crossbar::ProgrammedXbar::mvm_raw`] is the line-for-line
//! functional reference (one vector, scalar inner loops). This module is
//! the production kernel the serving path runs on: [`BatchedXbar`] stores
//! the same differential bit-plane stacks in an execution-friendly layout
//! and [`BatchedXbar::mvm_batch`] amortizes the tile/chunk/plane traversal
//! over a whole batch, optionally across worker threads. The contract is
//! **bit-identity**: for any [`PimConfig`] — feasible or not, any tile
//! height, any thread count — outputs (i64 accumulators) and
//! [`XbarActivity`] counts equal the per-vector reference exactly
//! (`rust/tests/xbar_kernel.rs`, `rust/tests/xbar_threads.rs`, re-checked
//! in-run by `autorac xbar-bench`).
//!
//! Why it is fast (DESIGN.md §7 "§Perf", §7.8):
//!
//! * **Multi-word bit-plane packing + popcount.** One weight column of
//!   one bit-plane is stored as `ceil(xbar/64)` `u64` row-mask words, so
//!   EVERY tile geometry — including experimental tiles wider than 64
//!   rows — takes the packed path: the chunk×plane inner product is
//!   `Σ_w popcount(x_word[w] & w_word[w]) << (xb+wb)`, i.e. at most
//!   `dac_bits · cell_bits · n_words` AND+popcount ops per column
//!   instead of an `xbar`-long multiply-accumulate. (The old blocked
//!   i64 fallback for tiles > 64 rows is gone.)
//! * **Batch amortization.** Weight words are loaded once per
//!   (tile, chunk, plane, sign, column) and reused by every batch lane;
//!   input chunk bits are extracted once per (tile, chunk) into the
//!   scratch arena.
//! * **Tile-parallel execution.** [`XbarScratch::with_threads`] splits
//!   the independent (tile, chunk) work units across scoped worker
//!   threads, each accumulating into its own per-lane arena; the lanes
//!   are then folded with plain integer addition, which commutes
//!   exactly — so any thread count produces bit-identical outputs AND
//!   activity counts (§7.8's determinism argument).
//! * **Lossless-ADC fast path.** `PimConfig::feasible()` guarantees the
//!   full-scale column sum fits the ADC (`adc_step() == 1`), which makes
//!   [`super::crossbar::adc_transfer`] the identity on every reachable
//!   partial — the kernel skips the transfer entirely while still
//!   counting the conversions.
//! * **Program-time offset correction.** The input-independent dummy-row
//!   vector is computed once at [`BatchedXbar::program`] time, so
//!   [`BatchedXbar::mvm_corrected_batch`] is one kernel pass plus a
//!   subtraction (the reference used to pay a second full MVM per call).
//!
//! Fault tolerance (DESIGN.md §7.13): [`BatchedXbar::program_with`]
//! can inject a seeded [`FaultMap`] of stuck-at cells at program time
//! (the arrays then compute on corrupted planes exactly as real
//! hardware would), verifies every batch against an ABFT column
//! checksum — one extra column per tile holding the weight row-sums,
//! exact on the lossless path, so a clean tile can NEVER flag — and
//! repairs flagged tiles by reprogramming their pristine image onto a
//! reserved spare slot through the `tile_map` indirection (standard
//! program-verify: a spare whose own stuck cells corrupt the image is
//! burned and the next one tried). The offset correction is always the
//! *pristine* calibration, so a repaired tile serves bit-identically
//! to fault-free hardware.
//!
//! The hot path is allocation-free after warmup: all per-call buffers
//! (including every thread lane's and the ABFT accumulators) live in
//! the caller-owned [`XbarScratch`] arena.

use super::config::PimConfig;
use super::crossbar::{adc_transfer, MatI32, XbarActivity};
use super::fault::{FaultGeom, FaultMap, FaultSpec, CHK_COL};

/// Rows per packed word: one `u64` row-mask covers 64 tile rows; a tile
/// of `xbar` rows needs `ceil(xbar / PACK_WORD_BITS)` words per column
/// per weight bit.
pub const PACK_WORD_BITS: usize = 64;

/// Stack capacity (in `u64` words) for one column's hoisted weight
/// words (`cell_bits × n_words` of them). Covers every realistic
/// geometry — `cell_bits ≤ 2` and tiles up to 512 rows; anything bigger
/// spills to the heap arena instead (same results, one memcpy more).
const WW_STACK: usize = 16;

/// Minimum number of inner word-operations (`units × planes × 2 ×
/// cols × b × dac·cell·n_words`) before [`BatchedXbar::mvm_batch`] fans
/// work out to scoped worker threads. Each call that crosses it spawns
/// and joins its workers (`std::thread::scope` — scoped borrows instead
/// of a persistent queue), so the threshold is set where the compute
/// dwarfs the ~tens-of-µs spawn cost; below it (e.g. a 1-column scoring
/// head) the serial path runs. Purely a performance knob — results are
/// bit-identical either way.
const PAR_MIN_OPS: usize = 1 << 17;

/// One worker thread's private slice of the arena: input bit-masks, a
/// partial output accumulator, partial ABFT tile accumulators, and
/// partial activity counters. Folded into the caller's output/activity
/// after the scope joins.
#[derive(Default)]
struct Lane {
    xmasks: Vec<u64>,
    wwbuf: Vec<u64>,
    out: Vec<i64>,
    tile_sum: Vec<i64>,
    tile_chk: Vec<i64>,
    activity: XbarActivity,
}

/// Reusable scratch arena for [`BatchedXbar::mvm_batch`]: per-call
/// buffers plus the activity counters the pass accumulates into
/// (mirroring the `&mut XbarActivity` the reference takes). Create once,
/// pass to every call; no allocations happen after the first call with
/// the largest batch. [`XbarScratch::with_threads`] turns on
/// tile-parallel execution (bit-identical results at any thread count).
#[derive(Default)]
pub struct XbarScratch {
    /// event counters accumulated by every pass using this arena
    pub activity: XbarActivity,
    /// logical tiles whose ABFT checksum disagreed on the LAST pass
    /// (ascending, deduped); empty on clean hardware — the repair loop
    /// in `mapping/banks.rs` consumes this
    pub flagged: Vec<u32>,
    /// worker threads `mvm_batch` may fan out to (0 and 1 = serial)
    threads: usize,
    /// main-lane input bit-masks for the current (tile, chunk):
    /// `[b × dac_bits × n_words]` words, word `w` bit `i` = input bit of
    /// tile row `w·64 + i`
    xmasks: Vec<u64>,
    /// main-lane per-column weight words (`cell_bits × n_words`), loaded
    /// once per column and reused by every batch lane
    wwbuf: Vec<u64>,
    /// main-lane ABFT accumulators, `[n_tiles × b]`: summed data-column
    /// contributions and checksum-column outputs per (tile, batch row)
    tile_sum: Vec<i64>,
    tile_chk: Vec<i64>,
    /// extra-thread arenas (partial outputs + counters), reused across calls
    lanes: Vec<Lane>,
}

impl XbarScratch {
    /// Arena that lets `mvm_batch` split tile execution across up to
    /// `threads` OS threads (the calling thread counts as one). 0 and 1
    /// both mean serial. Thread count never changes a single output or
    /// activity bit — it is purely a wall-clock knob.
    pub fn with_threads(threads: usize) -> XbarScratch {
        XbarScratch {
            threads,
            ..XbarScratch::default()
        }
    }

    /// Configured worker-thread cap (0/1 = serial).
    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// Build options for [`BatchedXbar::program_with`]. [`Default`] (ABFT
/// on, no spares, no faults) is what [`BatchedXbar::program`] uses.
#[derive(Clone, Debug)]
pub struct XbarOptions {
    /// verify every batch against the tile checksum column. Only
    /// active on lossless (`PimConfig::feasible`) configs — the
    /// checksum identity is exact there and only there; on lossy ADCs
    /// the flag is silently ignored.
    pub abft: bool,
    /// spare physical tile slots reserved for repair
    pub spare_tiles: usize,
    /// stuck-at fault injection; `None` = pristine device
    pub fault: Option<FaultSpec>,
    /// bank label seeding the per-bank fault substream
    pub label: String,
}

impl Default for XbarOptions {
    fn default() -> XbarOptions {
        XbarOptions {
            abft: true,
            spare_tiles: 0,
            fault: None,
            label: "xbar".to_string(),
        }
    }
}

/// A programmed crossbar bank in batched-execution layout: differential
/// bit-plane stacks stored column-blocked and packed into `u64` row-mask
/// words (multi-word when the tile has more than 64 rows), plus the
/// cached offset-correction vector, the ABFT checksum column, and the
/// logical→physical tile map that spare-tile repair retargets.
pub struct BatchedXbar {
    pub cfg: PimConfig,
    /// programmed rows (K padded to a multiple of `cfg.xbar`)
    pub k: usize,
    /// output columns
    pub n: usize,
    /// logical tiles (`k / cfg.xbar`)
    n_tiles: usize,
    /// physical tile slots: logical tiles + reserved spares
    n_tiles_phys: usize,
    /// `u64` words per column per weight bit: `ceil(xbar / 64)`
    n_words: usize,
    /// `feasible()` ⇒ `adc_transfer` is the identity on every reachable
    /// partial sum — skip it (outputs unchanged, counts unchanged)
    lossless: bool,
    /// ABFT verification active (requires `lossless`)
    abft: bool,
    /// checksum bit-planes: row-sums outgrow `w_bits`, so the checksum
    /// column carries its own (wider) plane count
    chk_planes: usize,
    /// packed layout:
    /// `words[((((p·2+s)·cell_bits + wb)·n_tiles_phys + t)·n + col)·n_words + w]`
    /// is the row-mask of weight-bit `wb` of plane `p`, sign `s`,
    /// physical tile `t`, column `col`, covering tile rows
    /// `w·64 .. w·64+64`. Spare slots sit above the logical tiles and
    /// are zero until a repair programs them.
    packed: Vec<u64>,
    /// packed checksum column (row-sums of the weight matrix), one per
    /// physical tile: `chk[(block·n_tiles_phys + t)·n_words + w]` with
    /// `block = (p·2+s)·cell_bits + wb`, `p < chk_planes`
    chk: Vec<u64>,
    /// logical tile → physical slot; identity until a repair remaps an
    /// entry onto a spare
    tile_map: Vec<u32>,
    /// unallocated spare slots (popped lowest-first)
    spare_free: Vec<u32>,
    /// pristine images for spare reprogramming; kept only when faults
    /// are injected or spares reserved (fault-free banks pay nothing)
    clean_packed: Vec<u64>,
    clean_chk: Vec<u64>,
    /// injected fault sites + drift fuse
    fault: Option<FaultMap>,
    /// ground truth per physical slot: a stuck site changed (or may
    /// have changed) a stored bit vs the pristine content
    corrupt_phys: Vec<bool>,
    /// raw accumulator of the all-`offset` input (the dummy-row read),
    /// computed once at program time on the PRISTINE image — device
    /// calibration happens on verified hardware, and repaired tiles
    /// must reproduce it exactly (§7.13)
    offset_corr: Vec<i64>,
    pub program_activity: XbarActivity,
}

impl BatchedXbar {
    /// Program a signed integer weight matrix (values within `w_bits`).
    /// Same contract and programming activity as
    /// [`super::crossbar::ProgrammedXbar::program`]; only the storage
    /// layout differs. ABFT verification is on (when the config is
    /// lossless); no spares, no faults — see [`BatchedXbar::program_with`].
    pub fn program(wq: &MatI32, cfg: PimConfig) -> BatchedXbar {
        BatchedXbar::program_with(wq, cfg, &XbarOptions::default())
    }

    /// [`BatchedXbar::program`] with fault-tolerance options: ABFT
    /// on/off, reserved spare slots, and seeded stuck-at injection.
    /// The offset correction and the pristine images are captured
    /// BEFORE faults apply (calibration-on-verified-hardware model).
    pub fn program_with(
        wq: &MatI32,
        cfg: PimConfig,
        opts: &XbarOptions,
    ) -> BatchedXbar {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        assert!(
            wq.data.iter().all(|&w| w.abs() <= wmax),
            "weights exceed w_bits range"
        );
        let k_pad = wq.rows.div_ceil(cfg.xbar) * cfg.xbar;
        let n_tiles = k_pad / cfg.xbar;
        let n_tiles_phys = n_tiles + opts.spare_tiles;
        let n_words = cfg.xbar.div_ceil(PACK_WORD_BITS);
        let n = wq.cols;
        let planes = cfg.n_planes();
        let cell = cfg.cell_bits;
        let cell_mask = (1i32 << cell) - 1;

        let mut packed = vec![0u64; planes * 2 * cell * n_tiles_phys * n * n_words];
        for r in 0..wq.rows {
            let (t, i) = (r / cfg.xbar, r % cfg.xbar);
            let (word, bit) = (i / PACK_WORD_BITS, i % PACK_WORD_BITS);
            for c in 0..n {
                let w = wq.at(r, c);
                for (s, mag) in [(0usize, w.max(0)), (1, (-w).max(0))] {
                    for p in 0..planes {
                        let pv = (mag >> (p * cell)) & cell_mask;
                        if pv == 0 {
                            continue;
                        }
                        for wb in 0..cell {
                            if (pv >> wb) & 1 == 1 {
                                let idx = (((((p * 2 + s) * cell + wb)
                                    * n_tiles_phys
                                    + t)
                                    * n
                                    + c)
                                    * n_words)
                                    + word;
                                packed[idx] |= 1u64 << bit;
                            }
                        }
                    }
                }
            }
        }

        // ABFT checksum column: row r holds Σ_col W[r, col], packed
        // like a data column but with enough bit-planes for the
        // row-sum dynamic range (it exceeds w_bits). The checksum
        // identity — Σ_col out[col] == checksum output, per tile per
        // batch row — is exact on the lossless path because both sides
        // are the same integer bilinear form (distributivity); lossy
        // ADCs quantize per-column partials and the identity breaks,
        // so ABFT is gated on `feasible()`.
        let abft = opts.abft && cfg.feasible();
        let mut chk_planes = 0usize;
        let mut chk = Vec::new();
        if abft {
            let mut rowsum = vec![0i64; k_pad];
            for r in 0..wq.rows {
                for c in 0..n {
                    rowsum[r] += wq.at(r, c) as i64;
                }
            }
            let maxmag = rowsum.iter().map(|v| v.unsigned_abs()).max().unwrap_or(0);
            let mag_bits = (64 - maxmag.leading_zeros()) as usize;
            chk_planes = mag_bits.div_ceil(cell);
            chk = vec![0u64; chk_planes * 2 * cell * n_tiles_phys * n_words];
            for (r, &v) in rowsum.iter().enumerate() {
                let (t, i) = (r / cfg.xbar, r % cfg.xbar);
                let (word, bit) = (i / PACK_WORD_BITS, i % PACK_WORD_BITS);
                for (s, mag) in [(0usize, v.max(0)), (1, (-v).max(0))] {
                    for p in 0..chk_planes {
                        let pv = (mag >> (p * cell)) & cell_mask as i64;
                        if pv == 0 {
                            continue;
                        }
                        for wb in 0..cell {
                            if (pv >> wb) & 1 == 1 {
                                let idx = (((p * 2 + s) * cell + wb)
                                    * n_tiles_phys
                                    + t)
                                    * n_words
                                    + word;
                                chk[idx] |= 1u64 << bit;
                            }
                        }
                    }
                }
            }
        }

        // Programming activity mirrors the reference (data planes only:
        // the checksum column and spare slots are redundancy overhead,
        // not part of the data-plane write contract the parity tests pin).
        let program_activity = XbarActivity {
            cells_written: 2 * planes as u64 * (k_pad * n) as u64,
            write_pulses: 2 * planes as u64 * k_pad as u64,
            ..Default::default()
        };
        let mut xb = BatchedXbar {
            cfg,
            k: k_pad,
            n,
            n_tiles,
            n_tiles_phys,
            n_words,
            lossless: cfg.feasible(),
            abft,
            chk_planes,
            packed,
            chk,
            tile_map: (0..n_tiles as u32).collect(),
            spare_free: (n_tiles as u32..n_tiles_phys as u32).rev().collect(),
            clean_packed: Vec::new(),
            clean_chk: Vec::new(),
            fault: None,
            corrupt_phys: vec![false; n_tiles_phys],
            offset_corr: Vec::new(),
            program_activity,
        };
        // Dummy-row read: the offset correction is input-independent, so
        // simulate it once here — on the PRISTINE image, before any
        // fault applies — instead of once per corrected MVM.
        let offset = 1i32 << (cfg.x_bits - 1);
        let ones = vec![offset; k_pad];
        let mut corr = vec![0i64; n];
        let mut scratch = XbarScratch::default();
        xb.mvm_batch(&ones, 1, &mut corr, &mut scratch);
        xb.offset_corr = corr;
        // Pristine copies: the repair source. Kept whenever repair or
        // injection is possible; a plain fault-free bank skips the 2×
        // memory.
        if opts.fault.is_some() || opts.spare_tiles > 0 {
            xb.clean_packed = xb.packed.clone();
            xb.clean_chk = xb.chk.clone();
        }
        if let Some(spec) = &opts.fault {
            let map = FaultMap::build(spec, &opts.label, &xb.fault_geom());
            xb.install_faults(map);
        }
        xb
    }

    /// The packed-array geometry fault sites are drawn over.
    fn fault_geom(&self) -> FaultGeom {
        let rem = self.cfg.xbar % PACK_WORD_BITS;
        FaultGeom {
            blocks: self.data_blocks(),
            chk_blocks: self.chk_blocks(),
            n_tiles_phys: self.n_tiles_phys,
            cols: self.n,
            n_words: self.n_words,
            last_mask: if rem == 0 { u64::MAX } else { (1u64 << rem) - 1 },
        }
    }

    fn data_blocks(&self) -> usize {
        self.cfg.n_planes() * 2 * self.cfg.cell_bits
    }

    fn chk_blocks(&self) -> usize {
        self.chk_planes * 2 * self.cfg.cell_bits
    }

    fn data_idx(&self, block: usize, phys: usize, col: usize, word: usize) -> usize {
        ((block * self.n_tiles_phys + phys) * self.n + col) * self.n_words + word
    }

    fn chk_idx(&self, block: usize, phys: usize, word: usize) -> usize {
        (block * self.n_tiles_phys + phys) * self.n_words + word
    }

    /// Install an explicit fault map: capture pristine copies if not
    /// already kept, then assert every slot's stuck cells (ground truth
    /// recorded per physical slot). Exposed for tests/benches needing
    /// precise site control; `program_with` is the production entry.
    #[doc(hidden)]
    pub fn install_faults(&mut self, map: FaultMap) {
        assert_eq!(
            map.tiles.len(),
            self.n_tiles_phys,
            "fault map geometry mismatch"
        );
        if self.clean_packed.is_empty() {
            self.clean_packed = self.packed.clone();
            self.clean_chk = self.chk.clone();
        }
        self.fault = Some(map);
        for slot in 0..self.n_tiles_phys {
            self.apply_slot_sites(slot, false);
        }
    }

    /// Assert the stuck cells recorded for physical `slot` onto the
    /// live arrays (`drift` selects the drift wave). Returns `true`
    /// when any stored bit actually changed — a stuck cell that agrees
    /// with the programmed value is harmless, exactly like hardware.
    fn apply_slot_sites(&mut self, slot: usize, drift: bool) -> bool {
        let Some(map) = &self.fault else {
            return false;
        };
        let list = if drift { &map.drift_tiles } else { &map.tiles };
        let Some(sites) = list.get(slot) else {
            return false;
        };
        let (np, n, nw) = (self.n_tiles_phys, self.n, self.n_words);
        let mut changed = false;
        for site in sites {
            let w = site.word as usize;
            let (arr, idx) = if site.col == CHK_COL {
                let idx = (site.block as usize * np + slot) * nw + w;
                (&mut self.chk, idx)
            } else {
                let idx = ((site.block as usize * np + slot) * n
                    + site.col as usize)
                    * nw
                    + w;
                (&mut self.packed, idx)
            };
            let old = arr[idx];
            let new = (old | site.set) & !site.clear;
            if new != old {
                arr[idx] = new;
                changed = true;
            }
        }
        if changed {
            self.corrupt_phys[slot] = true;
        }
        changed
    }

    /// Advance the drift fuse by one batch (the device twin of
    /// `CrashAfter`/`SlowAfter`). When the fuse crosses, the drift wave
    /// of stuck cells asserts itself on every physical slot — including
    /// spares and already-repaired tiles, exactly like aging hardware.
    /// Returns `true` iff the wave changed at least one stored bit.
    pub fn tick_drift(&mut self) -> bool {
        let fired = match &mut self.fault {
            Some(m) => m.tick(),
            None => return false,
        };
        if !fired {
            return false;
        }
        let mut any = false;
        for slot in 0..self.n_tiles_phys {
            any |= self.apply_slot_sites(slot, true);
        }
        any
    }

    /// Repair logical tile `t`: reprogram its pristine image onto a
    /// spare slot (copy clean words, let the spare's own stuck cells
    /// assert, then read back — standard ReRAM program-verify). A spare
    /// that fails verification is burned and the next one tried. On
    /// success `tile_map[t]` points at a verified-clean slot and the
    /// tile serves bit-identically to fault-free hardware; `false`
    /// means no good spare is left (callers degrade to
    /// flagged-approximate mode).
    pub fn repair_tile(&mut self, t: usize) -> bool {
        assert!(t < self.n_tiles, "repair targets a logical tile");
        if self.clean_packed.is_empty() {
            return false; // fault-free build kept no pristine image
        }
        while let Some(spare) = self.spare_free.pop() {
            let s = spare as usize;
            for block in 0..self.data_blocks() {
                for col in 0..self.n {
                    for w in 0..self.n_words {
                        let src = self.data_idx(block, t, col, w);
                        let dst = self.data_idx(block, s, col, w);
                        self.packed[dst] = self.clean_packed[src];
                    }
                }
            }
            for block in 0..self.chk_blocks() {
                for w in 0..self.n_words {
                    let src = self.chk_idx(block, t, w);
                    let dst = self.chk_idx(block, s, w);
                    self.chk[dst] = self.clean_chk[src];
                }
            }
            self.corrupt_phys[s] = false;
            let mut bad = self.apply_slot_sites(s, false);
            if self.fault.as_ref().is_some_and(|m| m.drifted()) {
                bad |= self.apply_slot_sites(s, true);
            }
            if !bad {
                self.tile_map[t] = spare;
                return true;
            }
            // program-verify failed: this spare corrupts the image —
            // burn it and try the next
        }
        false
    }

    /// Logical tile count.
    pub fn tiles(&self) -> usize {
        self.n_tiles
    }

    /// Spare slots still available for repair.
    pub fn spares_free(&self) -> usize {
        self.spare_free.len()
    }

    /// Whether ABFT checksum verification runs on this bank.
    pub fn abft_on(&self) -> bool {
        self.abft
    }

    /// Ground truth for tests: logical tiles whose currently-mapped
    /// physical slot may hold content differing from the pristine
    /// image (conservative — a drift wave that happens to restore a
    /// bit keeps the slot marked).
    pub fn corrupt_logical_tiles(&self) -> Vec<usize> {
        (0..self.n_tiles)
            .filter(|&t| self.corrupt_phys[self.tile_map[t] as usize])
            .collect()
    }

    /// Test/bench hook: XOR-flip one packed data bit of logical tile
    /// `t` under the current mapping (a guaranteed single-cell
    /// corruption), keeping pristine copies so repair stays possible.
    /// `block` is the `(plane, sign, weight-bit)` block index; `bit`
    /// must address a valid row of the tile.
    #[doc(hidden)]
    pub fn corrupt_bit(
        &mut self,
        t: usize,
        block: usize,
        col: usize,
        word: usize,
        bit: usize,
    ) {
        assert!(t < self.n_tiles && block < self.data_blocks());
        assert!(col < self.n && word < self.n_words);
        assert!(word * PACK_WORD_BITS + bit < self.cfg.xbar, "pad bit holds no cell");
        if self.clean_packed.is_empty() {
            self.clean_packed = self.packed.clone();
            self.clean_chk = self.chk.clone();
        }
        let phys = self.tile_map[t] as usize;
        let idx = self.data_idx(block, phys, col, word);
        self.packed[idx] ^= 1u64 << bit;
        self.corrupt_phys[phys] = true;
    }

    /// The cached input-independent offset-correction vector (raw
    /// accumulator of the all-`offset` input, pristine calibration).
    pub fn offset_correction(&self) -> &[i64] {
        &self.offset_corr
    }

    /// Batched bit-serial MVM: `xs` is row-major `[b × k]` (each vector
    /// padded to `k` by the caller, offset-binary in `[0, 2^x_bits)`),
    /// `out` is `[b × n]` raw accumulators (overwritten). Bit-identical
    /// to calling [`super::crossbar::ProgrammedXbar::mvm_raw`] on each
    /// row, including the counts accumulated into `scratch.activity` —
    /// at any `XbarScratch::with_threads` setting. When ABFT is active,
    /// every (tile, batch-row) MVM is verified against the checksum
    /// column: mismatching tiles land in `scratch.flagged` and bump
    /// `activity.faulty_tiles` (both stay empty/zero on clean
    /// hardware — the checksum identity is exact, zero false positives).
    pub fn mvm_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        assert_eq!(xs.len(), b * self.k, "xs must be [b × k] (pad each row to k)");
        assert_eq!(out.len(), b * self.n, "out must be [b × n]");
        out.iter_mut().for_each(|v| *v = 0);
        scratch.flagged.clear();
        // NB: no early-out on n == 0 — the reference still counts
        // read_cycles for a zero-column bank, and so must we.
        if b == 0 {
            return;
        }
        let verify = self.abft && self.n > 0;
        if verify {
            scratch.tile_sum.clear();
            scratch.tile_sum.resize(self.n_tiles * b, 0);
            scratch.tile_chk.clear();
            scratch.tile_chk.resize(self.n_tiles * b, 0);
        }
        // Independent work units: one (tile, chunk) pair each. Anything
        // a unit adds to `out`/activity commutes exactly (integer sums),
        // so partitioning the unit range is invisible in the result.
        let units = self.n_tiles * self.cfg.n_chunks();
        let ops = units
            * self.cfg.n_planes()
            * 2
            * self.n
            * b
            * (self.cfg.dac_bits * self.cfg.cell_bits * self.n_words);
        let threads = scratch.threads.clamp(1, units.max(1));
        if threads == 1 || ops < PAR_MIN_OPS {
            self.run_units(
                0..units,
                xs,
                b,
                out,
                &mut scratch.xmasks,
                &mut scratch.wwbuf,
                &mut scratch.activity,
                verify,
                &mut scratch.tile_sum,
                &mut scratch.tile_chk,
            );
            self.verify_tiles(b, scratch);
            return;
        }
        // Fan out: contiguous unit spans, one per thread. The calling
        // thread takes span 0 and accumulates straight into `out`; each
        // worker accumulates into its own zeroed lane arena. When `units`
        // does not divide evenly, only as many lanes as have a non-empty
        // span are kept — no thread is ever spawned to do nothing.
        let per = units.div_ceil(threads);
        let n_lanes = units.div_ceil(per) - 1;
        scratch.lanes.resize_with(n_lanes, Lane::default);
        let n_tiles = self.n_tiles;
        std::thread::scope(|sc| {
            for (w, lane) in scratch.lanes.iter_mut().enumerate() {
                let lo = (w + 1) * per;
                let hi = ((w + 2) * per).min(units);
                debug_assert!(lo < hi, "empty lane span must not be spawned");
                sc.spawn(move || {
                    lane.out.clear();
                    lane.out.resize(b * self.n, 0);
                    lane.activity = XbarActivity::default();
                    lane.tile_sum.clear();
                    lane.tile_chk.clear();
                    if verify {
                        lane.tile_sum.resize(n_tiles * b, 0);
                        lane.tile_chk.resize(n_tiles * b, 0);
                    }
                    self.run_units(
                        lo..hi,
                        xs,
                        b,
                        &mut lane.out,
                        &mut lane.xmasks,
                        &mut lane.wwbuf,
                        &mut lane.activity,
                        verify,
                        &mut lane.tile_sum,
                        &mut lane.tile_chk,
                    );
                });
            }
            self.run_units(
                0..per,
                xs,
                b,
                out,
                &mut scratch.xmasks,
                &mut scratch.wwbuf,
                &mut scratch.activity,
                verify,
                &mut scratch.tile_sum,
                &mut scratch.tile_chk,
            );
        });
        // Order-independent reduction: lane partials and counters fold
        // in with plain integer addition (commutative and associative
        // exactly), so the fold order — and the thread count — cannot
        // change a bit. The ABFT accumulators fold the same way, which
        // is what makes detection thread-count-invariant.
        for lane in &scratch.lanes {
            for (o, &p) in out.iter_mut().zip(&lane.out) {
                *o += p;
            }
            scratch.activity.merge(&lane.activity);
            if verify {
                for (o, &p) in scratch.tile_sum.iter_mut().zip(&lane.tile_sum) {
                    *o += p;
                }
                for (o, &p) in scratch.tile_chk.iter_mut().zip(&lane.tile_chk) {
                    *o += p;
                }
            }
        }
        self.verify_tiles(b, scratch);
    }

    /// Compare the folded per-(tile, batch-row) accumulators against
    /// the checksum outputs; record mismatching tiles. Exactness
    /// argument (§7.13): on the lossless path both sides equal the same
    /// integer bilinear form over the STORED bits — equal whenever the
    /// stored bits are the programmed ones, i.e. a clean tile can never
    /// flag; a single corrupted cell shifts `tile_sum` by
    /// `±2^(p·cell+wb) · x[row]` and leaves `tile_chk` alone, so a
    /// single-fault tile flags exactly when its output is wrong.
    fn verify_tiles(&self, b: usize, scratch: &mut XbarScratch) {
        if !(self.abft && self.n > 0) || scratch.tile_sum.is_empty() {
            return;
        }
        for t in 0..self.n_tiles {
            let mut bad = 0u64;
            for j in 0..b {
                if scratch.tile_sum[t * b + j] != scratch.tile_chk[t * b + j] {
                    bad += 1;
                }
            }
            if bad > 0 {
                scratch.flagged.push(t as u32);
                scratch.activity.faulty_tiles += bad;
            }
        }
    }

    /// [`BatchedXbar::mvm_batch`] plus the cached offset correction:
    /// matches [`super::crossbar::ProgrammedXbar::mvm_corrected`] per row.
    pub fn mvm_corrected_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        self.mvm_batch(xs, b, out, scratch);
        for j in 0..b {
            for (o, &c) in out[j * self.n..(j + 1) * self.n]
                .iter_mut()
                .zip(&self.offset_corr)
            {
                *o -= c;
            }
        }
    }

    /// AND+popcount core over a contiguous range of (tile, chunk) work
    /// units. Accumulates into `out` (not zeroed here) and `activity`;
    /// `xmasks` and `wwbuf` are this lane's input-bit and weight-word
    /// arenas. With `verify`, also accumulates each tile's summed data
    /// contributions into `tile_sum` and its checksum-column output
    /// into `tile_chk` (`[n_tiles × b]` each; the checksum path is a
    /// wide digital accumulator — no ADC step — and charges no
    /// activity: redundancy, not data-plane work).
    #[allow(clippy::too_many_arguments)]
    fn run_units(
        &self,
        units: std::ops::Range<usize>,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        xmasks: &mut Vec<u64>,
        wwbuf: &mut Vec<u64>,
        activity: &mut XbarActivity,
        verify: bool,
        tile_sum: &mut [i64],
        tile_chk: &mut [i64],
    ) {
        let cfg = &self.cfg;
        let (dac, cell, xbar, n, nw) =
            (cfg.dac_bits, cfg.cell_bits, cfg.xbar, self.n, self.n_words);
        let n_chunks = cfg.n_chunks();
        // per-(plane,sign,wb) stride between weight-bit blocks
        let wb_stride = self.n_tiles_phys * n * nw;
        xmasks.clear();
        xmasks.resize(b * dac * nw, 0);
        // one column's hoisted weight words: stack for every realistic
        // geometry, heap arena for hand-built exotic ones
        let mut ww_stack = [0u64; WW_STACK];
        for u in units {
            let (t, c) = (u / n_chunks, u % n_chunks);
            let phys = self.tile_map[t] as usize;
            let r0 = t * xbar;
            let tb = t * b;
            activity.read_cycles += b as u64;
            let cshift = c * dac;
            // Input bit extraction, once per (tile, chunk) per lane.
            for j in 0..b {
                let row = &xs[j * self.k + r0..j * self.k + r0 + xbar];
                for xb in 0..dac {
                    let base = (j * dac + xb) * nw;
                    for (w, m) in xmasks[base..base + nw].iter_mut().enumerate() {
                        let lo = w * PACK_WORD_BITS;
                        let hi = (lo + PACK_WORD_BITS).min(xbar);
                        let mut mask = 0u64;
                        for (i, &x) in row[lo..hi].iter().enumerate() {
                            mask |= (((x >> (cshift + xb)) & 1) as u64) << i;
                        }
                        *m = mask;
                    }
                }
            }
            for p in 0..cfg.n_planes() {
                let shift = (cshift + p * cell) as u32;
                for s in 0..2usize {
                    let sign = if s == 0 { 1i64 } else { -1i64 };
                    activity.adc_conversions += (b * n) as u64;
                    activity.shift_adds += (b * n) as u64;
                    // base of (plane p, sign s, weight-bit 0, tile phys)
                    let plane_base =
                        (((p * 2 + s) * cell) * self.n_tiles_phys + phys) * n;
                    for col in 0..n {
                        let col_base = (plane_base + col) * nw;
                        // Load this column's cell·nw weight words once;
                        // every batch lane and input bit reuses them
                        // (the "loaded once per column" contract).
                        let ww_col: &[u64] = if cell * nw <= WW_STACK {
                            for wb in 0..cell {
                                ww_stack[wb * nw..(wb + 1) * nw].copy_from_slice(
                                    &self.packed[col_base + wb * wb_stride..][..nw],
                                );
                            }
                            &ww_stack[..cell * nw]
                        } else {
                            wwbuf.clear();
                            for wb in 0..cell {
                                wwbuf.extend_from_slice(
                                    &self.packed[col_base + wb * wb_stride..][..nw],
                                );
                            }
                            wwbuf
                        };
                        for j in 0..b {
                            let xm_base = j * dac * nw;
                            let mut v = 0i64;
                            for xb in 0..dac {
                                let xm = &xmasks[xm_base + xb * nw..][..nw];
                                for wb in 0..cell {
                                    let ww = &ww_col[wb * nw..(wb + 1) * nw];
                                    let mut pc = 0u64;
                                    for (&a, &w) in xm.iter().zip(ww) {
                                        pc += (a & w).count_ones() as u64;
                                    }
                                    v += (pc as i64) << (xb + wb);
                                }
                            }
                            let q = if self.lossless {
                                v
                            } else {
                                adc_transfer(v, cfg)
                            };
                            let contrib = sign * (q << shift);
                            out[j * n + col] += contrib;
                            if verify {
                                tile_sum[tb + j] += contrib;
                            }
                        }
                    }
                }
            }
            // Checksum-column read for this (tile, chunk): same packed
            // inner product over the (wider) checksum planes, no ADC
            // transfer (lossless path only), no activity charges.
            if verify {
                for p in 0..self.chk_planes {
                    let shift = (cshift + p * cell) as u32;
                    for s in 0..2usize {
                        let sign = if s == 0 { 1i64 } else { -1i64 };
                        for j in 0..b {
                            let xm_base = j * dac * nw;
                            let mut v = 0i64;
                            for xb in 0..dac {
                                let xm = &xmasks[xm_base + xb * nw..][..nw];
                                for wb in 0..cell {
                                    let base =
                                        self.chk_idx((p * 2 + s) * cell + wb, phys, 0);
                                    let cw = &self.chk[base..][..nw];
                                    let mut pc = 0u64;
                                    for (&a, &w) in xm.iter().zip(cw) {
                                        pc += (a & w).count_ones() as u64;
                                    }
                                    v += (pc as i64) << (xb + wb);
                                }
                            }
                            tile_chk[tb + j] += sign * (v << shift);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::crossbar::ProgrammedXbar;
    use crate::pim::fault::FaultSite;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
        let mut m = MatI32::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
            }
        }
        m
    }

    fn random_inputs(rng: &mut Rng, b: usize, k: usize, x_bits: usize) -> Vec<i32> {
        (0..b * k)
            .map(|_| rng.below(1u64 << x_bits) as i32)
            .collect()
    }

    /// Outputs and activity of the per-vector reference on `b` rows.
    fn reference(
        xbar: &ProgrammedXbar,
        xs: &[i32],
        b: usize,
    ) -> (Vec<i64>, XbarActivity) {
        let mut act = XbarActivity::default();
        let mut out = Vec::with_capacity(b * xbar.n);
        for j in 0..b {
            out.extend(xbar.mvm_raw(&xs[j * xbar.k..(j + 1) * xbar.k], &mut act));
        }
        (out, act)
    }

    #[test]
    fn packed_path_matches_reference_on_default_config() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(1);
        let wq = random_mat(&mut rng, 100, 17, 127); // K padded 100 → 128
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!((bx.k, bx.n), (refx.k, refx.n));
        assert_eq!(bx.program_activity, refx.program_activity);
        for b in [1usize, 7, 32] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, b);
            let mut out = vec![0i64; b * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            assert_eq!(out, want, "b={b}");
            assert_eq!(scratch.activity, want_act, "b={b}");
            // ABFT runs on this (feasible) config and must stay silent
            assert!(bx.abft_on());
            assert!(scratch.flagged.is_empty(), "clean hardware flagged");
        }
    }

    #[test]
    fn lossy_adc_config_still_bit_identical() {
        let cfg = PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        };
        assert!(!cfg.feasible());
        let mut rng = Rng::new(2);
        let wq = random_mat(&mut rng, 64, 11, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        // the checksum identity needs the lossless path: ABFT gates off
        assert!(!bx.abft_on());
        let xs = random_inputs(&mut rng, 5, bx.k, cfg.x_bits);
        let (want, want_act) = reference(&refx, &xs, 5);
        let mut out = vec![0i64; 5 * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 5, &mut out, &mut scratch);
        assert_eq!(out, want);
        assert_eq!(scratch.activity, want_act);
    }

    #[test]
    fn wide_tiles_take_the_multi_word_packed_path() {
        // xbar > 64 used to hit a blocked i64 fallback; it now packs
        // into ceil(xbar/64) words. 128·1·1 = 128 ≤ 255 is feasible
        // (lossless), 128·1·3 is lossy — both must match the reference.
        for cfg in [
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 2,
                adc_bits: 8,
                ..Default::default()
            },
            // non-multiple-of-64 width: last word is partial
            PimConfig {
                xbar: 96,
                dac_bits: 2,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
            // three words per column
            PimConfig {
                xbar: 192,
                dac_bits: 1,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
        ] {
            let mut rng = Rng::new(3);
            let wq = random_mat(&mut rng, cfg.xbar + 2, 6, 127); // ragged pad
            let refx = ProgrammedXbar::program(&wq, cfg);
            let bx = BatchedXbar::program(&wq, cfg);
            assert_eq!(bx.n_words, cfg.xbar.div_ceil(64), "cfg {cfg:?}");
            let xs = random_inputs(&mut rng, 4, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, 4);
            let mut out = vec![0i64; 4 * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, 4, &mut out, &mut scratch);
            assert_eq!(out, want, "cfg {cfg:?}");
            assert_eq!(scratch.activity, want_act, "cfg {cfg:?}");
            assert!(scratch.flagged.is_empty(), "cfg {cfg:?}");
        }
    }

    #[test]
    fn threaded_execution_is_bit_identical_to_serial() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(6);
        let wq = random_mat(&mut rng, 300, 24, 127); // 5 tiles → real spans
        let bx = BatchedXbar::program(&wq, cfg);
        let b = 16;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let mut serial = vec![0i64; b * bx.n];
        let mut s1 = XbarScratch::with_threads(1);
        bx.mvm_batch(&xs, b, &mut serial, &mut s1);
        for threads in [2usize, 3, 8, 64] {
            let mut out = vec![0i64; b * bx.n];
            let mut st = XbarScratch::with_threads(threads);
            // this workload clears PAR_MIN_OPS (40 units × 4 planes × 2
            // signs × 24 cols × b=16 × 2 word-ops ≈ 2^18), so the
            // parallel path actually runs
            bx.mvm_batch(&xs, b, &mut out, &mut st);
            assert_eq!(out, serial, "threads={threads}");
            assert_eq!(st.activity, s1.activity, "threads={threads}");
            assert_eq!(st.flagged, s1.flagged, "threads={threads}");
        }
    }

    #[test]
    fn small_workloads_stay_serial_but_identical() {
        // below PAR_MIN_OPS the kernel silently runs serial — results
        // must still match a threads=1 arena bit for bit
        let cfg = PimConfig::default();
        let mut rng = Rng::new(8);
        let wq = random_mat(&mut rng, 40, 3, 127);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = random_inputs(&mut rng, 2, bx.k, cfg.x_bits);
        let mut a = vec![0i64; 2 * bx.n];
        let mut b1 = vec![0i64; 2 * bx.n];
        let mut sa = XbarScratch::with_threads(4);
        let mut sb = XbarScratch::default();
        bx.mvm_batch(&xs, 2, &mut a, &mut sa);
        bx.mvm_batch(&xs, 2, &mut b1, &mut sb);
        assert_eq!(a, b1);
        assert_eq!(sa.activity, sb.activity);
    }

    #[test]
    fn corrected_batch_matches_reference_corrected() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(4);
        let wq = random_mat(&mut rng, cfg.xbar, 9, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!(bx.offset_correction(), refx.offset_correction());
        let b = 3;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_corrected_batch(&xs, b, &mut out, &mut scratch);
        for j in 0..b {
            let mut act = XbarActivity::default();
            let want = refx.mvm_corrected(&xs[j * bx.k..(j + 1) * bx.k], &mut act);
            assert_eq!(&out[j * bx.n..(j + 1) * bx.n], &want[..], "row {j}");
        }
    }

    #[test]
    fn scratch_is_reusable_across_batch_sizes() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(5);
        let wq = random_mat(&mut rng, 64, 4, 127);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut scratch = XbarScratch::with_threads(2);
        let mut last = Vec::new();
        for b in [8usize, 1, 3] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let mut out = vec![0i64; b * bx.n];
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            last = out;
        }
        assert_eq!(last.len(), 3 * bx.n);
        assert!(scratch.activity.read_cycles > 0);
    }

    #[test]
    fn zero_batch_is_a_noop() {
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 3);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&[], 0, &mut out, &mut scratch);
        assert_eq!(scratch.activity, XbarActivity::default());
    }

    #[test]
    fn zero_column_bank_still_counts_reads() {
        // n == 0 must not short-circuit: the reference charges the
        // read cycles of driving the (column-less) wordlines regardless
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 0);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = vec![0i32; bx.k];
        let mut act = XbarActivity::default();
        let want = refx.mvm_raw(&xs, &mut act);
        assert!(want.is_empty());
        assert!(act.read_cycles > 0);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 1, &mut out, &mut scratch);
        assert_eq!(scratch.activity, act);
    }

    #[test]
    fn weights_out_of_range_panic() {
        let cfg = PimConfig::default().with_wbits(4);
        let mut wq = MatI32::zeros(4, 4);
        wq.set(0, 0, 100);
        let r = std::panic::catch_unwind(|| BatchedXbar::program(&wq, cfg));
        assert!(r.is_err());
    }

    // ----------------------------------------------------------------
    // Fault tolerance (S34)
    // ----------------------------------------------------------------

    /// Build a 3-tile bank with spares and a known input batch.
    fn faulty_fixture(
        spares: usize,
    ) -> (BatchedXbar, BatchedXbar, Vec<i32>, usize) {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(40);
        let wq = random_mat(&mut rng, 3 * cfg.xbar, 12, 127);
        let clean = BatchedXbar::program(&wq, cfg);
        let faulty = BatchedXbar::program_with(
            &wq,
            cfg,
            &XbarOptions {
                spare_tiles: spares,
                ..XbarOptions::default()
            },
        );
        let b = 4;
        let xs = random_inputs(&mut rng, b, clean.k, cfg.x_bits);
        (clean, faulty, xs, b)
    }

    fn run(bx: &BatchedXbar, xs: &[i32], b: usize) -> (Vec<i64>, XbarScratch) {
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(xs, b, &mut out, &mut scratch);
        (out, scratch)
    }

    #[test]
    fn injected_bit_is_detected_and_repaired_bit_identical() {
        let (clean, mut bx, xs, b) = faulty_fixture(2);
        let (want, _) = run(&clean, &xs, b);
        // corrupt one cell of tile 1 (block 0 = plane 0, sign +, wb 0)
        bx.corrupt_bit(1, 0, 3, 0, 17);
        assert_eq!(bx.corrupt_logical_tiles(), vec![1]);
        let (out, scratch) = run(&bx, &xs, b);
        // the flipped bit lands on a random weight/input — detection
        // must flag tile 1 whenever any row's output moved
        let moved = out != want;
        assert_eq!(!scratch.flagged.is_empty(), moved);
        if moved {
            assert_eq!(scratch.flagged, vec![1]);
            assert!(scratch.activity.faulty_tiles > 0);
        }
        // repair onto a spare: verified clean, scores bit-identical
        assert!(bx.repair_tile(1));
        assert_eq!(bx.spares_free(), 1);
        assert!(bx.corrupt_logical_tiles().is_empty());
        let (fixed, s2) = run(&bx, &xs, b);
        assert_eq!(fixed, want);
        assert!(s2.flagged.is_empty());
        assert_eq!(s2.activity.faulty_tiles, 0);
    }

    #[test]
    fn repair_without_spares_reports_failure() {
        let (_, mut bx, _, _) = faulty_fixture(0);
        bx.corrupt_bit(0, 0, 0, 0, 0);
        assert!(!bx.repair_tile(0), "no spare slot to repair onto");
        // and a pristine default bank keeps no clean image at all
        let wq = MatI32::zeros(64, 2);
        let mut plain = BatchedXbar::program(&wq, PimConfig::default());
        assert!(!plain.repair_tile(0));
    }

    #[test]
    fn born_bad_spare_is_burned_and_the_next_tried() {
        let (clean, mut bx, xs, b) = faulty_fixture(2);
        // hand-build a map: spare slot 3 (first popped) has a stuck-1
        // cell on a bit position where tile 0's clean image has a 0 —
        // program-verify must burn it and fall through to slot 4.
        // Find such a position in tile 0's clean content.
        let mut site = None;
        'scan: for block in 0..bx.data_blocks() {
            for col in 0..bx.n {
                let idx = bx.data_idx(block, 0, col, 0);
                for bit in 0..PACK_WORD_BITS.min(bx.cfg.xbar) {
                    if bx.packed[idx] >> bit & 1 == 0 {
                        site = Some((block as u32, col as u32, bit));
                        break 'scan;
                    }
                }
            }
        }
        let (block, col, bit) = site.expect("a zero bit exists");
        let mut map = FaultMap::default();
        map.tiles = vec![Vec::new(); 5];
        map.drift_tiles = vec![Vec::new(); 5];
        map.tiles[3].push(FaultSite {
            block,
            col,
            word: 0,
            set: 1 << bit,
            clear: 0,
        });
        bx.install_faults(map);
        // data tiles are untouched by this map…
        let (out, scratch) = run(&bx, &xs, b);
        let (want, _) = run(&clean, &xs, b);
        assert_eq!(out, want);
        assert!(scratch.flagged.is_empty());
        // …but corrupting tile 0 forces a repair that must skip the
        // bad spare (slot 3) and verify onto slot 4
        bx.corrupt_bit(0, block as usize, col as usize, 0, bit);
        assert!(bx.repair_tile(0));
        assert_eq!(bx.spares_free(), 0, "bad spare burned, good one used");
        let (fixed, s2) = run(&bx, &xs, b);
        assert_eq!(fixed, want);
        assert!(s2.flagged.is_empty());
    }

    #[test]
    fn drift_fuse_corrupts_after_n_batches() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(41);
        let wq = random_mat(&mut rng, 2 * cfg.xbar, 8, 127);
        let clean = BatchedXbar::program(&wq, cfg);
        let mut bx = BatchedXbar::program_with(
            &wq,
            cfg,
            &XbarOptions {
                spare_tiles: 4,
                fault: Some(FaultSpec {
                    rate: 0.0,
                    drift_after: Some(2),
                    drift_rate: 2e-3,
                    ..FaultSpec::cells(0.0, 9)
                }),
                label: "drift-test".into(),
                ..XbarOptions::default()
            },
        );
        let b = 3;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let (want, _) = run(&clean, &xs, b);
        // batches 1 and 2: pristine
        for _ in 0..2 {
            let (out, scratch) = run(&bx, &xs, b);
            assert_eq!(out, want);
            assert!(scratch.flagged.is_empty());
            bx.tick_drift();
        }
        // the fuse crossed on the second tick: the wave has landed
        assert!(
            !bx.corrupt_logical_tiles().is_empty(),
            "drift at 2e-3 over ~16k logical-tile cells must hit"
        );
        let (_, scratch) = run(&bx, &xs, b);
        // repair what flagged — drift hits spare slots too, so
        // program-verify may burn them all; both outcomes are legal,
        // but a fully-verified repair must restore bit-identity
        let mut all_fixed = true;
        for &t in &scratch.flagged {
            all_fixed &= bx.repair_tile(t as usize);
        }
        let (fixed, s2) = run(&bx, &xs, b);
        if all_fixed && s2.flagged.is_empty() {
            assert_eq!(fixed, want);
        }
    }

    #[test]
    fn fault_free_options_build_is_bit_identical_to_plain_program() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(42);
        let wq = random_mat(&mut rng, 150, 10, 127);
        let a = BatchedXbar::program(&wq, cfg);
        // spares reserved but unused; rate-0 fault spec draws nothing
        let b_ = BatchedXbar::program_with(
            &wq,
            cfg,
            &XbarOptions {
                spare_tiles: 3,
                fault: Some(FaultSpec::cells(0.0, 1)),
                ..XbarOptions::default()
            },
        );
        assert_eq!(a.offset_correction(), b_.offset_correction());
        let xs = random_inputs(&mut rng, 5, a.k, cfg.x_bits);
        let (wa, sa) = run(&a, &xs, 5);
        let (wb, sb) = run(&b_, &xs, 5);
        assert_eq!(wa, wb);
        assert_eq!(sa.activity, sb.activity);
    }

    #[test]
    fn stuck_open_column_is_detected_and_repaired() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(43);
        let mut wq = random_mat(&mut rng, cfg.xbar, 6, 127);
        for r in 0..cfg.xbar {
            wq.set(r, 0, 1); // known nonzero column: Σ_r x[r] ≥ 0, > 0 a.s.
        }
        let clean = BatchedXbar::program(&wq, cfg);
        let mut bx = BatchedXbar::program_with(
            &wq,
            cfg,
            &XbarOptions {
                spare_tiles: 1,
                ..XbarOptions::default()
            },
        );
        // stuck-open bitline on data column 0 of tile 0: the column
        // reads 0 in every block, the checksum column is intact — the
        // checksum keeps the lost charge and the tile must flag.
        // (A fault clearing BOTH the data and checksum columns to zero
        // makes 0 == 0 pass — an inherent single-checksum ABFT blind
        // spot, covered by the col_rate sweep in tests/fault_prop.rs
        // via the ground-truth subset property instead.)
        let mut map = FaultMap::default();
        map.tiles = vec![Vec::new(); 2];
        map.drift_tiles = vec![Vec::new(); 2];
        for block in 0..bx.data_blocks() as u32 {
            map.tiles[0].push(FaultSite {
                block,
                col: 0,
                word: 0,
                set: 0,
                clear: u64::MAX,
            });
        }
        bx.install_faults(map);
        let xs = random_inputs(&mut rng, 2, bx.k, cfg.x_bits);
        let (want, _) = run(&clean, &xs, 2);
        let (out, scratch) = run(&bx, &xs, 2);
        assert_ne!(out, want, "an open bitline zeroes real charge");
        assert_eq!(scratch.flagged, vec![0]);
        // the spare carries no sites: repair restores bit-identity
        assert!(bx.repair_tile(0));
        let (fixed, s2) = run(&bx, &xs, 2);
        assert_eq!(fixed, want);
        assert!(s2.flagged.is_empty());
    }
}
