//! Batched, layout-optimized crossbar execution core (S23).
//!
//! [`super::crossbar::ProgrammedXbar::mvm_raw`] is the line-for-line
//! functional reference (one vector, scalar inner loops). This module is
//! the production kernel the serving path runs on: [`BatchedXbar`] stores
//! the same differential bit-plane stacks in an execution-friendly layout
//! and [`BatchedXbar::mvm_batch`] amortizes the tile/chunk/plane traversal
//! over a whole batch. The contract is **bit-identity**: for any
//! [`PimConfig`] — feasible or not — outputs (i64 accumulators) and
//! [`XbarActivity`] counts equal the per-vector reference exactly
//! (`rust/tests/xbar_kernel.rs`, re-checked in-run by `autorac
//! xbar-bench`).
//!
//! Why it is fast (DESIGN.md §7 "§Perf"):
//!
//! * **Bit-plane packing + popcount.** A crossbar tile has ≤ 64 rows
//!   (`xbar ∈ {16,32,64}`), so one weight column of one bit-plane fits a
//!   single `u64` word over the tile's rows. Splitting each `cell_bits`
//!   plane into its constituent bits (and each `dac_bits` chunk into its
//!   input bits) turns the chunk×plane inner product into
//!   `Σ popcount(x_word & w_word) << (xb+wb)` — at most `dac_bits ·
//!   cell_bits ≤ 4` AND+popcount ops per column instead of an `xbar`-long
//!   multiply-accumulate. Tiles wider than 64 rows fall back to a blocked
//!   i64 path over column-contiguous (transposed) plane storage.
//! * **Batch amortization.** Weight words are loaded once per
//!   (tile, chunk, plane, sign, column) and reused by every batch lane;
//!   input chunk bits are extracted once per (tile, chunk) into the
//!   scratch arena.
//! * **Lossless-ADC fast path.** `PimConfig::feasible()` guarantees the
//!   full-scale column sum fits the ADC (`adc_step() == 1`), which makes
//!   [`super::crossbar::adc_transfer`] the identity on every reachable
//!   partial — the kernel skips the transfer entirely while still
//!   counting the conversions.
//! * **Program-time offset correction.** The input-independent dummy-row
//!   vector is computed once at [`BatchedXbar::program`] time, so
//!   [`BatchedXbar::mvm_corrected_batch`] is one kernel pass plus a
//!   subtraction (the reference used to pay a second full MVM per call).
//!
//! The hot path is allocation-free after warmup: all per-call buffers
//! live in the caller-owned [`XbarScratch`] arena.

use super::config::PimConfig;
use super::crossbar::{adc_transfer, MatI32, XbarActivity};

/// Largest tile height the packed (popcount) layout supports: one `u64`
/// word per column per bit-plane. Every size in
/// [`super::config::XBAR_SIZES`] qualifies; larger experimental tiles
/// use the blocked path.
pub const PACK_MAX_XBAR: usize = 64;

/// Layout decision, shared by `program` and `mvm_batch`: the packed path
/// additionally requires the 2-wide word buffers to cover every bit
/// (`CELL_OPTIONS`/`DAC_OPTIONS` cap at 2; hand-built exotic configs
/// fall back to the blocked path rather than truncating).
fn use_packed(cfg: &PimConfig) -> bool {
    cfg.xbar <= PACK_MAX_XBAR && cfg.cell_bits <= 2 && cfg.dac_bits <= 2
}

/// Reusable scratch arena for [`BatchedXbar::mvm_batch`]: per-call
/// buffers plus the activity counters the pass accumulates into
/// (mirroring the `&mut XbarActivity` the reference takes). Create once,
/// pass to every call; no allocations happen after the first call with
/// the largest batch.
#[derive(Default)]
pub struct XbarScratch {
    /// event counters accumulated by every pass using this arena
    pub activity: XbarActivity,
    /// packed path: input bit-masks for the current (tile, chunk) —
    /// `[b × dac_bits]` words, bit `i` = input bit of tile row `i`
    xmasks: Vec<u64>,
    /// blocked path: chunk values of the current (tile, chunk) — `[b × xbar]`
    chunks: Vec<i64>,
}

/// A programmed crossbar bank in batched-execution layout: differential
/// bit-plane stacks stored column-blocked (packed into `u64` bit-words
/// when the tile fits, transposed i32 blocks otherwise), plus the cached
/// offset-correction vector.
pub struct BatchedXbar {
    pub cfg: PimConfig,
    /// programmed rows (K padded to a multiple of `cfg.xbar`)
    pub k: usize,
    /// output columns
    pub n: usize,
    n_tiles: usize,
    /// `feasible()` ⇒ `adc_transfer` is the identity on every reachable
    /// partial sum — skip it (outputs unchanged, counts unchanged)
    lossless: bool,
    /// packed layout (tiles ≤ [`PACK_MAX_XBAR`] rows):
    /// `words[(((p·2+s)·cell_bits + wb)·n_tiles + t)·n + col]` is the
    /// `u64` row-mask of weight-bit `wb` of plane `p`, sign `s`, tile
    /// `t`, column `col`
    packed: Vec<u64>,
    /// blocked fallback (tiles > [`PACK_MAX_XBAR`] rows):
    /// `vals[((p·2+s)·n_tiles + t)·(n·xbar) + col·xbar + i]` is the
    /// plane value at tile row `i` — column-contiguous for the dot loop
    blocked: Vec<i32>,
    /// raw accumulator of the all-`offset` input (the dummy-row read),
    /// computed once at program time
    offset_corr: Vec<i64>,
    pub program_activity: XbarActivity,
}

impl BatchedXbar {
    /// Program a signed integer weight matrix (values within `w_bits`).
    /// Same contract and programming activity as
    /// [`super::crossbar::ProgrammedXbar::program`]; only the storage
    /// layout differs.
    pub fn program(wq: &MatI32, cfg: PimConfig) -> BatchedXbar {
        let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
        assert!(
            wq.data.iter().all(|&w| w.abs() <= wmax),
            "weights exceed w_bits range"
        );
        let k_pad = wq.rows.div_ceil(cfg.xbar) * cfg.xbar;
        let n_tiles = k_pad / cfg.xbar;
        let n = wq.cols;
        let planes = cfg.n_planes();
        let cell = cfg.cell_bits;
        let cell_mask = (1i32 << cell) - 1;
        let pack = use_packed(&cfg);

        let mut packed = Vec::new();
        let mut blocked = Vec::new();
        if pack {
            packed.resize(planes * 2 * cell * n_tiles * n, 0u64);
        } else {
            blocked.resize(planes * 2 * n_tiles * n * cfg.xbar, 0i32);
        }
        for r in 0..wq.rows {
            let (t, i) = (r / cfg.xbar, r % cfg.xbar);
            for c in 0..n {
                let w = wq.at(r, c);
                for (s, mag) in [(0usize, w.max(0)), (1, (-w).max(0))] {
                    for p in 0..planes {
                        let pv = (mag >> (p * cell)) & cell_mask;
                        if pv == 0 {
                            continue;
                        }
                        if pack {
                            for wb in 0..cell {
                                if (pv >> wb) & 1 == 1 {
                                    let idx = (((p * 2 + s) * cell + wb) * n_tiles
                                        + t)
                                        * n
                                        + c;
                                    packed[idx] |= 1u64 << i;
                                }
                            }
                        } else {
                            let idx = ((p * 2 + s) * n_tiles + t) * (n * cfg.xbar)
                                + c * cfg.xbar
                                + i;
                            blocked[idx] = pv;
                        }
                    }
                }
            }
        }

        let program_activity = XbarActivity {
            cells_written: 2 * planes as u64 * (k_pad * n) as u64,
            write_pulses: 2 * planes as u64 * k_pad as u64,
            ..Default::default()
        };
        let mut xb = BatchedXbar {
            cfg,
            k: k_pad,
            n,
            n_tiles,
            lossless: cfg.feasible(),
            packed,
            blocked,
            offset_corr: Vec::new(),
            program_activity,
        };
        // Dummy-row read: the offset correction is input-independent, so
        // simulate it once here instead of once per corrected MVM.
        let offset = 1i32 << (cfg.x_bits - 1);
        let ones = vec![offset; k_pad];
        let mut corr = vec![0i64; n];
        let mut scratch = XbarScratch::default();
        xb.mvm_batch(&ones, 1, &mut corr, &mut scratch);
        xb.offset_corr = corr;
        xb
    }

    /// The cached input-independent offset-correction vector (raw
    /// accumulator of the all-`offset` input).
    pub fn offset_correction(&self) -> &[i64] {
        &self.offset_corr
    }

    /// Batched bit-serial MVM: `xs` is row-major `[b × k]` (each vector
    /// padded to `k` by the caller, offset-binary in `[0, 2^x_bits)`),
    /// `out` is `[b × n]` raw accumulators (overwritten). Bit-identical
    /// to calling [`super::crossbar::ProgrammedXbar::mvm_raw`] on each
    /// row, including the counts accumulated into `scratch.activity`.
    pub fn mvm_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        assert_eq!(xs.len(), b * self.k, "xs must be [b × k] (pad each row to k)");
        assert_eq!(out.len(), b * self.n, "out must be [b × n]");
        out.iter_mut().for_each(|v| *v = 0);
        // NB: no early-out on n == 0 — the reference still counts
        // read_cycles for a zero-column bank, and so must we.
        if b == 0 {
            return;
        }
        if use_packed(&self.cfg) {
            self.mvm_batch_packed(xs, b, out, scratch);
        } else {
            self.mvm_batch_blocked(xs, b, out, scratch);
        }
    }

    /// [`BatchedXbar::mvm_batch`] plus the cached offset correction:
    /// matches [`super::crossbar::ProgrammedXbar::mvm_corrected`] per row.
    pub fn mvm_corrected_batch(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        self.mvm_batch(xs, b, out, scratch);
        for j in 0..b {
            for (o, &c) in out[j * self.n..(j + 1) * self.n]
                .iter_mut()
                .zip(&self.offset_corr)
            {
                *o -= c;
            }
        }
    }

    /// AND+popcount path: every tile row fits one `u64` word.
    fn mvm_batch_packed(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        let cfg = &self.cfg;
        let (dac, cell, xbar, n) = (cfg.dac_bits, cfg.cell_bits, cfg.xbar, self.n);
        debug_assert!(cell <= 2 && dac <= 2, "packed path word buffer is 2-wide");
        scratch.xmasks.clear();
        scratch.xmasks.resize(b * dac, 0);
        for t in 0..self.n_tiles {
            let r0 = t * xbar;
            for c in 0..cfg.n_chunks() {
                scratch.activity.read_cycles += b as u64;
                let cshift = c * dac;
                // Input bit extraction, once per (tile, chunk) per lane.
                for j in 0..b {
                    let row = &xs[j * self.k + r0..j * self.k + r0 + xbar];
                    for xb in 0..dac {
                        let mut m = 0u64;
                        for (i, &x) in row.iter().enumerate() {
                            m |= (((x >> (cshift + xb)) & 1) as u64) << i;
                        }
                        scratch.xmasks[j * dac + xb] = m;
                    }
                }
                for p in 0..cfg.n_planes() {
                    let shift = (cshift + p * cell) as u32;
                    for s in 0..2usize {
                        let sign = if s == 0 { 1i64 } else { -1i64 };
                        scratch.activity.adc_conversions += (b * n) as u64;
                        scratch.activity.shift_adds += (b * n) as u64;
                        let row_base = ((p * 2 + s) * cell) * self.n_tiles + t;
                        for col in 0..n {
                            // ≤ 2 weight words per column (cell_bits ≤ 2)
                            let mut ww = [0u64; 2];
                            for (wb, w) in ww.iter_mut().take(cell).enumerate() {
                                *w = self.packed
                                    [(row_base + wb * self.n_tiles) * n + col];
                            }
                            for j in 0..b {
                                let mut v = 0i64;
                                for xb in 0..dac {
                                    let m = scratch.xmasks[j * dac + xb];
                                    for (wb, &w) in
                                        ww.iter().take(cell).enumerate()
                                    {
                                        v += ((m & w).count_ones() as i64)
                                            << (xb + wb);
                                    }
                                }
                                let q = if self.lossless {
                                    v
                                } else {
                                    adc_transfer(v, cfg)
                                };
                                out[j * n + col] += sign * (q << shift);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Blocked i64 fallback for tiles wider than [`PACK_MAX_XBAR`] rows:
    /// column-contiguous plane storage, per-column dot products.
    fn mvm_batch_blocked(
        &self,
        xs: &[i32],
        b: usize,
        out: &mut [i64],
        scratch: &mut XbarScratch,
    ) {
        let cfg = &self.cfg;
        let (xbar, n) = (cfg.xbar, self.n);
        let dac_mask = (1i32 << cfg.dac_bits) - 1;
        scratch.chunks.clear();
        scratch.chunks.resize(b * xbar, 0);
        for t in 0..self.n_tiles {
            let r0 = t * xbar;
            for c in 0..cfg.n_chunks() {
                scratch.activity.read_cycles += b as u64;
                let cshift = c * cfg.dac_bits;
                for j in 0..b {
                    let row = &xs[j * self.k + r0..j * self.k + r0 + xbar];
                    for (i, &x) in row.iter().enumerate() {
                        scratch.chunks[j * xbar + i] = ((x >> cshift) & dac_mask) as i64;
                    }
                }
                for p in 0..cfg.n_planes() {
                    let shift = (cshift + p * cfg.cell_bits) as u32;
                    for s in 0..2usize {
                        let sign = if s == 0 { 1i64 } else { -1i64 };
                        scratch.activity.adc_conversions += (b * n) as u64;
                        scratch.activity.shift_adds += (b * n) as u64;
                        let plane = &self.blocked
                            [((p * 2 + s) * self.n_tiles + t) * (n * xbar)..]
                            [..n * xbar];
                        for col in 0..n {
                            let wcol = &plane[col * xbar..(col + 1) * xbar];
                            for j in 0..b {
                                let ch = &scratch.chunks[j * xbar..(j + 1) * xbar];
                                let mut v = 0i64;
                                for (&cv, &w) in ch.iter().zip(wcol) {
                                    v += cv * w as i64;
                                }
                                let q = if self.lossless {
                                    v
                                } else {
                                    adc_transfer(v, cfg)
                                };
                                out[j * n + col] += sign * (q << shift);
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::crossbar::ProgrammedXbar;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, rows: usize, cols: usize, wmax: i32) -> MatI32 {
        let mut m = MatI32::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
            }
        }
        m
    }

    fn random_inputs(rng: &mut Rng, b: usize, k: usize, x_bits: usize) -> Vec<i32> {
        (0..b * k)
            .map(|_| rng.below(1u64 << x_bits) as i32)
            .collect()
    }

    /// Outputs and activity of the per-vector reference on `b` rows.
    fn reference(
        xbar: &ProgrammedXbar,
        xs: &[i32],
        b: usize,
    ) -> (Vec<i64>, XbarActivity) {
        let mut act = XbarActivity::default();
        let mut out = Vec::with_capacity(b * xbar.n);
        for j in 0..b {
            out.extend(xbar.mvm_raw(&xs[j * xbar.k..(j + 1) * xbar.k], &mut act));
        }
        (out, act)
    }

    #[test]
    fn packed_path_matches_reference_on_default_config() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(1);
        let wq = random_mat(&mut rng, 100, 17, 127); // K padded 100 → 128
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!((bx.k, bx.n), (refx.k, refx.n));
        assert_eq!(bx.program_activity, refx.program_activity);
        for b in [1usize, 7, 32] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, b);
            let mut out = vec![0i64; b * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            assert_eq!(out, want, "b={b}");
            assert_eq!(scratch.activity, want_act, "b={b}");
        }
    }

    #[test]
    fn lossy_adc_config_still_bit_identical() {
        let cfg = PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        };
        assert!(!cfg.feasible());
        let mut rng = Rng::new(2);
        let wq = random_mat(&mut rng, 64, 11, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = random_inputs(&mut rng, 5, bx.k, cfg.x_bits);
        let (want, want_act) = reference(&refx, &xs, 5);
        let mut out = vec![0i64; 5 * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 5, &mut out, &mut scratch);
        assert_eq!(out, want);
        assert_eq!(scratch.activity, want_act);
    }

    #[test]
    fn blocked_fallback_matches_reference() {
        // xbar > PACK_MAX_XBAR exercises the blocked path; 128·1·1 = 128
        // ≤ 255 is even feasible (lossless blocked), 128·1·3 is lossy.
        for cfg in [
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 1,
                adc_bits: 8,
                ..Default::default()
            },
            PimConfig {
                xbar: 128,
                dac_bits: 1,
                cell_bits: 2,
                adc_bits: 8,
                ..Default::default()
            },
        ] {
            let mut rng = Rng::new(3);
            let wq = random_mat(&mut rng, 130, 6, 127); // pads 130 → 256
            let refx = ProgrammedXbar::program(&wq, cfg);
            let bx = BatchedXbar::program(&wq, cfg);
            let xs = random_inputs(&mut rng, 4, bx.k, cfg.x_bits);
            let (want, want_act) = reference(&refx, &xs, 4);
            let mut out = vec![0i64; 4 * bx.n];
            let mut scratch = XbarScratch::default();
            bx.mvm_batch(&xs, 4, &mut out, &mut scratch);
            assert_eq!(out, want, "cfg {cfg:?}");
            assert_eq!(scratch.activity, want_act, "cfg {cfg:?}");
        }
    }

    #[test]
    fn corrected_batch_matches_reference_corrected() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(4);
        let wq = random_mat(&mut rng, cfg.xbar, 9, 127);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        assert_eq!(bx.offset_correction(), refx.offset_correction());
        let b = 3;
        let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::default();
        bx.mvm_corrected_batch(&xs, b, &mut out, &mut scratch);
        for j in 0..b {
            let mut act = XbarActivity::default();
            let want = refx.mvm_corrected(&xs[j * bx.k..(j + 1) * bx.k], &mut act);
            assert_eq!(&out[j * bx.n..(j + 1) * bx.n], &want[..], "row {j}");
        }
    }

    #[test]
    fn scratch_is_reusable_across_batch_sizes() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(5);
        let wq = random_mat(&mut rng, 64, 4, 127);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut scratch = XbarScratch::default();
        let mut last = Vec::new();
        for b in [8usize, 1, 3] {
            let xs = random_inputs(&mut rng, b, bx.k, cfg.x_bits);
            let mut out = vec![0i64; b * bx.n];
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            last = out;
        }
        assert_eq!(last.len(), 3 * bx.n);
        assert!(scratch.activity.read_cycles > 0);
    }

    #[test]
    fn zero_batch_is_a_noop() {
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 3);
        let bx = BatchedXbar::program(&wq, cfg);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&[], 0, &mut out, &mut scratch);
        assert_eq!(scratch.activity, XbarActivity::default());
    }

    #[test]
    fn zero_column_bank_still_counts_reads() {
        // n == 0 must not short-circuit: the reference charges the
        // read cycles of driving the (column-less) wordlines regardless
        let cfg = PimConfig::default();
        let wq = MatI32::zeros(64, 0);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let bx = BatchedXbar::program(&wq, cfg);
        let xs = vec![0i32; bx.k];
        let mut act = XbarActivity::default();
        let want = refx.mvm_raw(&xs, &mut act);
        assert!(want.is_empty());
        assert!(act.read_cycles > 0);
        let mut out: Vec<i64> = Vec::new();
        let mut scratch = XbarScratch::default();
        bx.mvm_batch(&xs, 1, &mut out, &mut scratch);
        assert_eq!(scratch.activity, act);
    }

    #[test]
    fn weights_out_of_range_panic() {
        let cfg = PimConfig::default().with_wbits(4);
        let mut wq = MatI32::zeros(4, 4);
        wq.set(0, 0, 100);
        let r = std::panic::catch_unwind(|| BatchedXbar::program(&wq, cfg));
        assert!(r.is_err());
    }
}
