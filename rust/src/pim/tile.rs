//! Compute-tile structural model (paper Fig. 4f).
//!
//! A tile hosts one engine (MVM / DP / FM): a set of physical crossbar
//! arrays with their peripheral ADCs/DACs, I/O registers, a data buffer
//! for intermediate outputs, a functional unit for activations, and a
//! slice of the controller/scheduler. The mapping layer decides how many
//! arrays a tile needs; this module prices the silicon (area, leakage)
//! and exposes per-event costs to the simulator.

use super::buffer::Buffer;
use super::config::PimConfig;
use super::params::TechParams;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// standard MVM engine (FC / EFC / DSI and the DP sub-FCs)
    Mvm,
    /// DP engine: crossbars written with activations at inference time
    Dp,
    /// FM engine: transposed array + MBSA
    Fm,
}

/// Structural description of one tile (produced by the mapping layer).
#[derive(Clone, Debug)]
pub struct TileSpec {
    pub kind: EngineKind,
    pub cfg: PimConfig,
    /// physical crossbar arrays (already includes the ×2 differential
    /// pair and ×n_planes bit-plane replication)
    pub n_arrays: usize,
    /// input register / buffer bytes
    pub in_buf_bytes: usize,
    /// output / intermediate buffer bytes
    pub out_buf_bytes: usize,
    /// MBSA lanes (FM tiles only)
    pub mbsa_lanes: usize,
}

/// Priced tile.
#[derive(Clone, Debug)]
pub struct Tile {
    pub spec: TileSpec,
    pub area_mm2: f64,
    pub leakage_mw: f64,
    pub in_buf: Buffer,
    pub out_buf: Buffer,
}

/// Controller + scheduler overhead as a fraction of compute area.
const CONTROL_OVERHEAD: f64 = 0.10;
/// MBSA lane area (mm²) — AND gate + accumulator register at 32 nm.
const MBSA_LANE_MM2: f64 = 2.4e-6;

impl Tile {
    pub fn build(spec: TileSpec, tech: &TechParams) -> Tile {
        let cfg = &spec.cfg;
        let xbar_area = tech.xbar_area_mm2(cfg.xbar, cfg.xbar) * spec.n_arrays as f64;
        let n_adc = (cfg.xbar.div_ceil(tech.cols_per_adc)) * spec.n_arrays;
        let adc = tech.adc(cfg.adc_bits);
        let dac = tech.dac(cfg.dac_bits);
        let n_dac = cfg.xbar * spec.n_arrays;
        let in_buf = Buffer::new(spec.in_buf_bytes);
        let out_buf = Buffer::new(spec.out_buf_bytes);
        let mbsa_area = spec.mbsa_lanes as f64 * MBSA_LANE_MM2;
        let compute_area = xbar_area
            + adc.area_mm2 * n_adc as f64
            + dac.area_mm2 * n_dac as f64
            + mbsa_area;
        let area_mm2 = (compute_area + in_buf.area_mm2 + out_buf.area_mm2)
            * (1.0 + CONTROL_OVERHEAD);
        let leakage_mw = adc.leakage_mw * n_adc as f64
            + dac.leakage_mw * n_dac as f64
            + in_buf.leakage_mw
            + out_buf.leakage_mw;
        Tile {
            spec,
            area_mm2,
            leakage_mw,
            in_buf,
            out_buf,
        }
    }

    /// ADC instances on this tile (time-multiplexed across columns).
    pub fn n_adcs(&self, tech: &TechParams) -> usize {
        self.spec.cfg.xbar.div_ceil(tech.cols_per_adc) * self.spec.n_arrays
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: EngineKind, n_arrays: usize) -> TileSpec {
        TileSpec {
            kind,
            cfg: PimConfig::default(),
            n_arrays,
            in_buf_bytes: 4096,
            out_buf_bytes: 8192,
            mbsa_lanes: if kind == EngineKind::Fm { 64 } else { 0 },
        }
    }

    #[test]
    fn area_scales_linearly_with_arrays_above_buffer_floor() {
        let t = TechParams::default();
        let a1 = Tile::build(spec(EngineKind::Mvm, 1), &t).area_mm2;
        let a4 = Tile::build(spec(EngineKind::Mvm, 4), &t).area_mm2;
        let a7 = Tile::build(spec(EngineKind::Mvm, 7), &t).area_mm2;
        assert!(a4 > a1 && a7 > a4);
        // marginal cost per extra array is constant (buffers are a floor)
        assert!(((a7 - a4) - (a4 - a1)).abs() < 1e-9, "a1={a1} a4={a4} a7={a7}");
    }

    #[test]
    fn adc_area_dominates_crossbar_area() {
        // Known PIM property (ISAAC: ADCs ≈ 58% of tile power/area).
        let t = TechParams::default();
        let tile = Tile::build(spec(EngineKind::Mvm, 1), &t);
        let xbar = t.xbar_area_mm2(64, 64);
        let adc_total = t.adc(8).area_mm2 * tile.n_adcs(&t) as f64;
        assert!(adc_total > xbar, "adc {adc_total} vs xbar {xbar}");
    }

    #[test]
    fn fm_tile_includes_mbsa() {
        let t = TechParams::default();
        let fm = Tile::build(spec(EngineKind::Fm, 1), &t);
        let mvm = Tile::build(spec(EngineKind::Mvm, 1), &t);
        assert!(fm.area_mm2 > mvm.area_mm2);
    }

    #[test]
    fn smaller_adc_is_cheaper() {
        let t = TechParams::default();
        let mut s = spec(EngineKind::Mvm, 2);
        s.cfg.adc_bits = 4;
        s.cfg.xbar = 16; // keep feasible
        let cheap = Tile::build(s.clone(), &t);
        s.cfg.adc_bits = 8;
        let costly = Tile::build(s, &t);
        assert!(cheap.area_mm2 < costly.area_mm2);
        assert!(cheap.leakage_mw < costly.leakage_mw);
    }
}
