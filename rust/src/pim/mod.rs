//! ReRAM PIM substrate (S7): device/periphery cost models and the
//! functional crossbar the behavioral simulator and the kernel-parity
//! tests share. Constants and substitution rationale: params.rs.

pub mod buffer;
pub mod config;
pub mod crossbar;
pub mod fault;
pub mod kernel;
pub mod mbsa;
pub mod noise;
pub mod params;
pub mod tile;
pub mod transposed;

pub use buffer::Buffer;
pub use config::PimConfig;
pub use crossbar::{
    adc_transfer, quant_act, quant_act_into, quant_sym, MatI32, ProgrammedXbar,
    XbarActivity,
};
pub use fault::{FaultCounts, FaultMap, FaultSpec};
pub use kernel::{BatchedXbar, XbarOptions, XbarScratch};
pub use mbsa::Mbsa;
pub use noise::NoiseModel;
pub use params::{Component, TechParams};
pub use tile::{EngineKind, Tile, TileSpec};
