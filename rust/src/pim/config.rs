//! PIM configuration — the searched ReRAM genome half (Table 1) plus the
//! integer quantities derived from it. Rust mirror of
//! `python/compile/kernels/ref.py::PimConfig`.

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PimConfig {
    /// crossbar rows/cols per tile (16/32/64)
    pub xbar: usize,
    /// DAC resolution (1/2)
    pub dac_bits: usize,
    /// memristor (cell) precision (1/2)
    pub cell_bits: usize,
    /// ADC resolution (4/6/8)
    pub adc_bits: usize,
    /// activation bits (fixed 8 in AutoRAC's space)
    pub x_bits: usize,
    /// weight bits for the operator currently mapped (4/8)
    pub w_bits: usize,
}

impl Default for PimConfig {
    fn default() -> Self {
        PimConfig {
            xbar: 64,
            dac_bits: 1,
            cell_bits: 2,
            adc_bits: 8,
            x_bits: 8,
            w_bits: 8,
        }
    }
}

pub const XBAR_SIZES: [usize; 3] = [16, 32, 64];
pub const DAC_OPTIONS: [usize; 2] = [1, 2];
pub const CELL_OPTIONS: [usize; 2] = [1, 2];
pub const ADC_OPTIONS: [usize; 3] = [4, 6, 8];

impl PimConfig {
    /// input bit-serial steps
    pub fn n_chunks(&self) -> usize {
        self.x_bits.div_ceil(self.dac_bits)
    }

    /// weight magnitude bit planes (sign via differential pair)
    pub fn n_planes(&self) -> usize {
        (self.w_bits - 1).div_ceil(self.cell_bits)
    }

    /// largest analog column sum a row-tile can produce
    pub fn adc_max_in(&self) -> i64 {
        (self.xbar as i64)
            * (((1i64 << self.dac_bits) - 1))
            * (((1i64 << self.cell_bits) - 1))
    }

    /// integer LSB of the ADC transfer function (≥1)
    pub fn adc_step(&self) -> i64 {
        let levels = (1i64 << self.adc_bits) - 1;
        1.max((self.adc_max_in() + levels - 1) / levels)
    }

    /// Paper §3.1: only DAC×cell×crossbar combinations whose full-scale
    /// column sum fits the ADC are allowed ("to avoid any loss during
    /// the analog-to-digital conversion process").
    pub fn feasible(&self) -> bool {
        self.adc_max_in() <= (1i64 << self.adc_bits) - 1
    }

    pub fn with_wbits(mut self, w_bits: usize) -> Self {
        self.w_bits = w_bits;
        self
    }

    /// Enumerate every feasible (xbar, dac, cell, adc) combination.
    pub fn enumerate_feasible() -> Vec<PimConfig> {
        let mut out = Vec::new();
        for &xbar in &XBAR_SIZES {
            for &dac_bits in &DAC_OPTIONS {
                for &cell_bits in &CELL_OPTIONS {
                    for &adc_bits in &ADC_OPTIONS {
                        let c = PimConfig {
                            xbar,
                            dac_bits,
                            cell_bits,
                            adc_bits,
                            ..PimConfig::default()
                        };
                        if c.feasible() {
                            out.push(c);
                        }
                    }
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("xbar", Json::Num(self.xbar as f64)),
            ("dac_bits", Json::Num(self.dac_bits as f64)),
            ("cell_bits", Json::Num(self.cell_bits as f64)),
            ("adc_bits", Json::Num(self.adc_bits as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<PimConfig> {
        Ok(PimConfig {
            xbar: j.req_usize("xbar")?,
            dac_bits: j.req_usize("dac_bits")?,
            cell_bits: j.req_usize("cell_bits")?,
            adc_bits: j.req_usize("adc_bits")?,
            ..PimConfig::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities_match_python() {
        let c = PimConfig::default(); // 64/1/2/8
        assert_eq!(c.n_chunks(), 8);
        assert_eq!(c.n_planes(), 4); // ceil(7/2)
        assert_eq!(c.adc_max_in(), 64 * 1 * 3);
        assert_eq!(c.adc_step(), 1);
        assert!(c.feasible());
    }

    #[test]
    fn feasibility_rule_matches_python() {
        // 64·3·3 = 576 > 255 → infeasible
        let c = PimConfig {
            xbar: 64,
            dac_bits: 2,
            cell_bits: 2,
            adc_bits: 8,
            ..Default::default()
        };
        assert!(!c.feasible());
        // 16·1·1 = 16 > 15 → infeasible at adc=4
        let c2 = PimConfig {
            xbar: 16,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 4,
            ..Default::default()
        };
        assert!(!c2.feasible());
        // but feasible at adc=6
        let c3 = PimConfig { adc_bits: 6, ..c2 };
        assert!(c3.feasible());
    }

    #[test]
    fn enumeration_is_nonempty_and_all_feasible() {
        let all = PimConfig::enumerate_feasible();
        assert!(!all.is_empty());
        assert!(all.iter().all(PimConfig::feasible));
        // spot known members
        assert!(all.contains(&PimConfig::default()));
    }

    #[test]
    fn json_roundtrip() {
        let c = PimConfig {
            xbar: 32,
            dac_bits: 2,
            cell_bits: 1,
            adc_bits: 8,
            ..Default::default()
        };
        let j = c.to_json();
        let c2 = PimConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn wbits_4_halves_planes() {
        let c8 = PimConfig::default();
        let c4 = c8.with_wbits(4);
        assert_eq!(c8.n_planes(), 4);
        assert_eq!(c4.n_planes(), 2); // ceil(3/2)
    }
}
